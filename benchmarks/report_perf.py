"""Before/after comparison of two dry-run result directories.

    PYTHONPATH=src python -m benchmarks.report_perf \
        --base benchmarks/results/dryrun --opt benchmarks/results/dryrun_v2
"""
import argparse
import glob
import json
import os


def load(dir_):
    out = {}
    for f in glob.glob(os.path.join(dir_, "*__single.json")):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("skipped") or "roofline" not in r:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", default="benchmarks/results/dryrun")
    ap.add_argument("--opt", default="benchmarks/results/dryrun_v2")
    args = ap.parse_args()
    base, opt = load(args.base), load(args.opt)

    print("| arch | shape | t_bound base→opt (ms) | × | bound base→opt | "
          "mem GiB base→opt |")
    print("|---|---|---|---|---|---|")
    total_speedup = []
    for key in sorted(base):
        if key not in opt:
            continue
        rb, ro = base[key]["roofline"], opt[key]["roofline"]
        tb = max(rb["t_compute_s"], rb["t_memory_s"], rb["t_collective_s"])
        to = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        x = tb / max(to, 1e-12)
        total_speedup.append(x)
        print(f"| {key[0]} | {key[1]} | {tb*1e3:.1f} → {to*1e3:.1f} "
              f"| {x:.1f}× | {rb['bottleneck']} → {ro['bottleneck']} "
              f"| {base[key]['device_mem_gb']:.1f} → "
              f"{opt[key]['device_mem_gb']:.1f} |")
    if total_speedup:
        import math
        geo = math.exp(sum(math.log(x) for x in total_speedup)
                       / len(total_speedup))
        print(f"\ngeomean bound-term speedup: {geo:.2f}× over "
              f"{len(total_speedup)} cells")


if __name__ == "__main__":
    main()
