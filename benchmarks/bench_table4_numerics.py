"""Table IV analogue: numerical-accuracy parity of the GEMM engines.

Paper: OPT perplexities on WikiText-2 are identical between the GPU
engine and FIGLUT-F, and within noise for FIGLUT-I (pre-aligned integer
mantissas).  Here: a trained small LM's perplexity under (a) dense
dequantized GEMM ("GPU"), (b) the LUT-based path (FIGLUT-F), (c) the
prealigned-integer reference (FIGLUT-I), all on the same 4-bit RTN
weights (the paper's setting), plus direct GEMM output-error rows.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import bcq
from repro.core.lut_gemm import bcq_xla_matmul, bcq_xla_matmul_fused
from repro.core.prealign import prealigned_bcq_matmul
from repro.kernels.lut_gemm import ref as lref
from repro.models import Model
from repro.quant import QuantSpec, quantize_model


def gemm_rows():
    rng = np.random.default_rng(0)
    W = jnp.array(rng.normal(size=(256, 512)).astype(np.float32))
    x = jnp.array(rng.normal(size=(8, 512)).astype(np.float32))
    wq = bcq.from_uniform(W, bits=4, group_size=128)
    y_gpu = lref.dense_ref(x, wq)

    rows = []
    for name, y in [
        ("FIGLUT-F/lut_read", lref.lut_ref(x, wq, mu=4, half_lut=True)),
        ("FIGLUT-F/bcq_xla", bcq_xla_matmul(x, wq)),
        ("FIGLUT-I/prealign_fp16mant", prealigned_bcq_matmul(x, wq, 11)),
    ]:
        rel = float(jnp.abs(y - y_gpu).max() / jnp.abs(y_gpu).max())
        rows.append((name, rel))
    return rows


def run():
    common.header("Table IV analogue — GEMM engine numerics parity")
    for name, rel in gemm_rows():
        print(f"table4_gemm,{name},max_rel_err={rel:.2e}")
        assert rel < 5e-3, (name, rel)

    model, params = common.tiny_lm()
    ppl_fp = common.perplexity(model, params)

    qparams, _ = quantize_model(params, QuantSpec(format="rtn", bits=4,
                                                  group_size=64), model.axes())
    m_f = Model(model.cfg.replace(quant=QuantSpec(backend="bcq_xla")))
    ppl_f = common.perplexity(m_f, qparams)

    m_dense = Model(model.cfg.replace(quant=QuantSpec(backend="dense")))
    ppl_gpu = common.perplexity(m_dense, qparams)

    print(f"table4_ppl,FP16-baseline,{ppl_fp:.3f}")
    print(f"table4_ppl,GPU(dense-dequant)-Q4RTN,{ppl_gpu:.3f}")
    print(f"table4_ppl,FIGLUT-F(bcq_xla)-Q4RTN,{ppl_f:.3f}")
    # paper's claim: engines agree with each other (not with FP — RTN adds
    # quantization error; engines must not add MORE error)
    assert abs(ppl_f - ppl_gpu) / ppl_gpu < 0.01, (ppl_f, ppl_gpu)
    return {"ppl_fp": ppl_fp, "ppl_gpu_q4": ppl_gpu, "ppl_figlut_q4": ppl_f}


if __name__ == "__main__":
    run()
