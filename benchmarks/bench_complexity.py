"""Table I analogue: computational-complexity accounting O(mnkq/mu).

Counts the actual operations each engine performs for one GEMM and
verifies the paper's complexity table:

    GPU    O(mnk)       (FP-FP after dequant)
    iFPU   O(mnkq)      (bit-serial adds)
    FIGNA  O(mnk)       (int mul-acc)
    FIGLUT O(mnkq/mu)   (LUT read-accumulates)

plus a wall-clock sanity row: the packed bcq_xla path vs dense matmul on
CPU (compression pays in memory, not CPU wall-time — noted).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import bcq
from repro.core.lut_gemm import bcq_xla_matmul, bcq_xla_matmul_fused


def op_counts(m, n, k, q, mu):
    return {
        "GPU(FP-FP)": m * n * k,
        "iFPU": m * n * k * q,
        "FIGNA": m * n * k,
        "FIGLUT": m * n * k * q // mu,
    }


def run():
    common.header("Table I analogue — op-count complexity")
    m, n, k, q, mu = 4096, 4096, 32, 3, 4
    counts = op_counts(m, n, k, q, mu)
    for eng, c in counts.items():
        print(f"table1,{eng},ops={c:.3e}")
    assert counts["FIGLUT"] == counts["iFPU"] // mu
    assert counts["FIGLUT"] < counts["GPU(FP-FP)"]  # q/mu < 1 for q=3,mu=4

    # wall-clock rows (CPU, informational)
    rng = np.random.default_rng(0)
    W = jnp.array(rng.normal(size=(1024, 1024)).astype(np.float32))
    x = jnp.array(rng.normal(size=(32, 1024)).astype(np.float32))
    wq = bcq.from_uniform(W, bits=4, group_size=128)
    dense = bcq.dequantize(wq)

    f_dense = jax.jit(lambda x: x @ dense.T)
    f_plane = jax.jit(lambda x: bcq_xla_matmul(x, wq))
    f_fused = jax.jit(lambda x: bcq_xla_matmul_fused(x, wq))
    common.bench("table1_wallclock,dense_f32_matmul",
                 lambda: jax.block_until_ready(f_dense(x)))
    common.bench("table1_wallclock,bcq_xla_per_plane",
                 lambda: jax.block_until_ready(f_plane(x)))
    common.bench("table1_wallclock,bcq_xla_fused_dequant",
                 lambda: jax.block_until_ready(f_fused(x)))
    print("table1,note,packed storage = %.1fx smaller than bf16 dense"
          % (1024 * 1024 * 2 / wq.nbytes()))
    return counts


if __name__ == "__main__":
    run()
