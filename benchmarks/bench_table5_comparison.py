"""Table V analogue: absolute accelerator comparison (model-calibrated).

GPU rows are the paper's own measurements (quoted, not modelled); the
accelerator rows come from our analytical model and are asserted against
the paper's numbers within tolerance — the calibration contract for
every other energy benchmark.
"""
from repro.core import energy_model as em
from benchmarks import common

PAPER = {            # Table V: (power W, TOPS/W)
    "iFPU": (0.67, 0.21),
    "FIGNA": (0.41, 0.33),
    "FIGLUT-I": (0.29, 0.47),
}
GPU_ROWS = [         # paper-quoted empirical rows (FP16-Q4 via LUT-GEMM etc.)
    ("A100 FP16-FP16", 40.27, 192, 0.21),
    ("A100 FP16-Q4(LUT-GEMM)", 1.85, 208, 0.01),
    ("H100 FP16-FP16", 62.08, 279, 0.22),
]


def run():
    common.header("Table V analogue — accelerator comparison (OPT-6.7B, "
                  "batch 32, Q4)")
    for name, tops, watts, topsw in GPU_ROWS:
        print(f"table5,{name},TOPS={tops},P={watts}W,TOPS/W={topsw} "
              f"[paper-quoted]")
    ok = True
    for eng, (p_w, p_tw) in PAPER.items():
        r = em.model_report(eng, "opt-6.7b", B=32, q=4)
        dp = r.power_W / p_w - 1
        dt = r.tops_per_w / p_tw - 1
        print(f"table5,{eng},TOPS={r.tops:.3f},P={r.power_W:.2f}W"
              f"(paper {p_w}; {dp:+.0%}),TOPS/W={r.tops_per_w:.2f}"
              f"(paper {p_tw}; {dt:+.0%})")
        ok &= abs(dp) < 0.35 and abs(dt) < 0.35
    # ordering is the hard claim: FIGLUT > FIGNA > iFPU > GPU-class
    r = {e: em.model_report(e, "opt-6.7b", B=32, q=4).tops_per_w
         for e in ("iFPU", "FIGNA", "FIGLUT-I")}
    assert r["FIGLUT-I"] > r["FIGNA"] > r["iFPU"] > 0.1
    assert ok, "calibration drifted beyond ±35% of Table V"
    return r


if __name__ == "__main__":
    run()
