"""Shared benchmark infrastructure.

``tiny_lm()`` trains (once, cached on disk) a small OPT-style LM on the
synthetic corpus so quantization benchmarks report *real perplexities* —
the CPU-scale analogue of the paper's OPT-family WikiText-2 evaluation.

``bench(name, fn)`` times a callable and returns the paper-harness CSV
row format: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.train import checkpoint as ckpt

RESULTS = os.path.join(os.path.dirname(__file__), "results")
TINY_DIR = os.path.join(RESULTS, "tiny_lm")

TINY_CFG = get_reduced("opt_6_7b").replace(
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
    d_ff=1024, vocab_size=2048, max_seq_len=256, remat=False,
    scan_layers=False)

_SEQ = 128
_BATCH = 16


def _pipeline(shard=0):
    return SyntheticLM(vocab_size=TINY_CFG.vocab_size, seq_len=_SEQ,
                       global_batch=_BATCH, seed=7, data_shard=shard)


def tiny_lm(steps: int = 400, force: bool = False):
    """(model, params) — trained once, checkpoint-cached."""
    model = Model(TINY_CFG)
    if not force and ckpt.latest_step(TINY_DIR) == steps:
        state, _, _ = ckpt.restore(TINY_DIR, steps)
        return model, state["params"]
    pipe = _pipeline()
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=steps,
                                weight_decay=0.01)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        p2, o2, m = adamw.apply_updates(params, grads, opt, opt_cfg)
        return p2, o2, loss

    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, loss = step(params, opt, batch)
        if i % 100 == 0:
            print(f"[tiny_lm] step {i}: loss {float(loss):.3f}")
    print(f"[tiny_lm] final loss {float(loss):.3f}")
    ckpt.save(TINY_DIR, steps, {"params": params})
    return model, params


def perplexity(model: Model, params, n_batches: int = 8) -> float:
    """exp(mean NLL) on held-out synthetic batches."""
    pipe = _pipeline()
    loss_fn = jax.jit(model.loss_fn)
    tot = 0.0
    for i in range(n_batches):
        batch = {k: jnp.asarray(v)
                 for k, v in pipe.batch_at(10_000 + i).items()}
        tot += float(loss_fn(params, batch))
    return float(np.exp(tot / n_batches))


def bench(name: str, fn, *, n: int = 5, warmup: int = 1, derived="") -> str:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    us = (time.perf_counter() - t0) / n * 1e6
    row = f"{name},{us:.1f},{derived}"
    print(row)
    return row


def header(title: str):
    print(f"\n### {title}")
