"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--skip-slow]``

Prints ``name,us_per_call,derived`` CSV rows (plus per-benchmark claim
checks that assert the paper's headline numbers within model tolerance).
"""
import argparse
import sys
import time
import traceback


BENCHES = [
    ("table1_complexity", "benchmarks.bench_complexity"),
    ("fig6_lut_power", "benchmarks.bench_fig6_lut_power"),
    ("fig8_fanout", "benchmarks.bench_fig8_fanout"),
    ("fig11_generator", "benchmarks.bench_fig11_generator"),
    ("fig13_area", "benchmarks.bench_fig13_area"),
    ("fig15_energy", "benchmarks.bench_fig15_energy"),
    ("fig16_topsw", "benchmarks.bench_fig16_topsw"),
    ("table5_comparison", "benchmarks.bench_table5_comparison"),
    ("kernels", "benchmarks.bench_kernels"),
    ("serve", "benchmarks.bench_serve"),                       # paged engine
    ("table4_numerics", "benchmarks.bench_table4_numerics"),   # trains tiny LM
    ("fig17_tradeoff", "benchmarks.bench_fig17_tradeoff"),     # reuses it
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--skip-slow", action="store_true",
                   help="skip the tiny-LM training benches")
    p.add_argument("--only", default="")
    args = p.parse_args()

    failures = []
    for name, mod_name in BENCHES:
        if args.only and args.only not in name:
            continue
        if args.skip_slow and name in ("table4_numerics", "fig17_tradeoff"):
            print(f"{name},SKIPPED,--skip-slow")
            continue
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
            print(f"{name},{(time.time()-t0)*1e6:.0f},PASS")
        except AssertionError as e:
            failures.append(name)
            print(f"{name},{(time.time()-t0)*1e6:.0f},CLAIM-CHECK-FAIL: {e}")
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"{name},{(time.time()-t0)*1e6:.0f},ERROR")
    if failures:
        print(f"\nFAILED: {failures}")
        sys.exit(1)
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
