"""Fig 13/14 analogue: area breakdown + TOPS/mm^2 across engines.

Paper claims checked:
  * FPE has the largest arithmetic area (FP mul + dequant); FIGLUT-F
    smaller (FP add not mul); integer engines smaller still (Fig 14);
  * LUT-based design reduces flip-flop area vs iFPU's deep serial pipes;
  * proposed engines reach up to ~1.5x FIGNA's TOPS/mm^2 at sub-4-bit
    (Fig 13); bit-serial engines lose at Q8 (2x cycles).
"""
from repro.core import energy_model as em
from benchmarks import common


def run():
    common.header("Fig 13/14 analogue — area & TOPS/mm^2")
    areas = {}
    for eng in ("FPE", "iFPU", "FIGNA", "FIGLUT-F", "FIGLUT-I"):
        a = em.engine_area_mm2(eng, q=4)
        areas[eng] = a
        print(f"fig14,q4,{eng},arith={a['arith_mm2']:.2f}mm2,"
              f"ff={a['ff_mm2']:.2f}mm2,total={a['total_mm2']:.2f}mm2")
    assert areas["FPE"]["arith_mm2"] > areas["FIGLUT-F"]["arith_mm2"]
    assert areas["FIGLUT-F"]["arith_mm2"] > areas["FIGLUT-I"]["arith_mm2"]
    assert areas["FIGLUT-I"]["ff_mm2"] < areas["iFPU"]["ff_mm2"]

    # TOPS/mm^2 on OPT models: throughput from the energy model's timing
    for model in ("opt-1.3b", "opt-6.7b", "opt-30b"):
        row = []
        for eng in ("FPE", "iFPU", "FIGNA", "FIGLUT-I"):
            r = em.model_report(eng, model, B=32, q=4)
            t_per_mm2 = r.tops / areas[eng]["total_mm2"]
            row.append((eng, t_per_mm2))
            print(f"fig13,{model},q4,{eng},TOPS/mm2={t_per_mm2:.3f}")
        d = dict(row)
        ratio = d["FIGLUT-I"] / d["FIGNA"]
        print(f"fig13,{model},FIGLUT/FIGNA_area_eff={ratio:.2f} (paper: up to ~1.5)")

    # Q8: bit-serial engines take 2x cycles -> area efficiency drops (paper)
    r4 = em.model_report("FIGLUT-I", "opt-6.7b", B=32, q=4)
    r8 = em.model_report("FIGLUT-I", "opt-6.7b", B=32, q=8)
    print(f"fig13,q8_penalty,FIGLUT TOPS q4={r4.tops:.3f} q8={r8.tops:.3f}")
    assert r8.tops < r4.tops
    return areas


if __name__ == "__main__":
    run()
