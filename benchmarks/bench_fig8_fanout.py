"""Fig 8/9 analogue: PE power vs LUT fan-out k; optimum at mu=4, k~32.

Paper: sharing one FFLUT among k RACs amortizes LUT static power (P_RAC
falls with k) until mux fan-out wiring dominates (P_RAC rises) — optimum
k = 32; and with ample k, mu=4 beats mu=2 (fewer RAC accumulates).
"""
import numpy as np

from repro.core import energy_model as em
from benchmarks import common


def p_rac(mu, k):
    read = em.fflut_read_energy(mu, 16, k)
    static = em.fflut_static_energy_per_cycle(mu, 16) / k
    acc = em.TECH.int_add_per_bit * 24
    gen = em.lut_generation_energy(mu, 16, True) / (64 * mu)
    return read + static + acc + gen


def run():
    common.header("Fig 8/9 analogue — power vs RACs-per-LUT (k)")
    ks = [1, 2, 4, 8, 16, 32, 64, 128]
    curves = {}
    for mu in (2, 4):
        # total power: n_rac fixed by throughput = 16384/mu RACs
        n_rac = 16384 // mu
        total = [n_rac * p_rac(mu, k) * em.TECH.freq_hz * 1e-12 for k in ks]
        curves[mu] = total
        for k, p in zip(ks, total):
            print(f"fig8,mu={mu},k={k},P={p:.3f}W")
    # mu=2 beats mu=4 at k=1 (smaller LUT), mu=4 wins at large k (paper)
    assert curves[2][0] < curves[4][0], "mu=2 should win unshared (k=1)"
    assert curves[4][-3] < curves[2][-3], "mu=4 should win at k=32"
    # P_RAC U-shape with optimum ~32 (Fig 9)
    prac4 = [p_rac(4, k) for k in ks]
    kopt = ks[int(np.argmin(prac4))]
    print(f"fig9,mu=4,argmin_k={kopt} (paper: 32)")
    assert kopt in (16, 32, 64)
    return curves


if __name__ == "__main__":
    run()
