"""Fig 17 analogue: TOPS/W vs perplexity under mixed-precision BCQ.

Paper claims checked (on our trained small LM + calibrated energy model):
  * same 4-bit: FIGLUT ~1.2x more energy-efficient than FIGNA at equal or
    better perplexity;
  * Q3: 1.6x energy efficiency with LOWER perplexity (non-uniform BCQ vs
    uniform OPTQ-class quantization);
  * **"When targeting the same perplexity, FIGLUT achieves 98% higher
    TOPS/W by performing 2.4-bit operations"** — mixed-precision 2.4-bit
    BCQ matches ~3-bit uniform quality at ~2x FIGNA-Q3's efficiency;
  * Table VI: BCQ4/BCQ3 stay close to the FP16 baseline.
"""
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import energy_model as em
from repro.core.mixed_precision import allocate_bits, average_bits
from repro.models import Model
from repro.quant import QuantSpec, quantize_model
from repro.quant.ptq import collect_linears
from repro.quant.optq import capture_calibration, optq_quantize_model


def run():
    common.header("Fig 17 / Table VI analogue — quality vs efficiency")
    model, params = common.tiny_lm()
    ppl_fp = common.perplexity(model, params)
    m_q = Model(model.cfg.replace(quant=QuantSpec(backend="bcq_xla")))
    gs = 64

    # calibration activations for the paper's OPTQ baseline
    pipe = common._pipeline()
    batches = [{k: jnp.asarray(v) for k, v in pipe.batch_at(20_000 + i).items()}
               for i in range(2)]
    calib = capture_calibration(model, params, batches)

    rows = []
    # uniform baselines (the FIGNA side): RTN and OPTQ [10] — the paper
    # evaluates FIGNA with OPTQ
    for bits in (2, 3, 4):
        eff = em.model_report("FIGNA", "opt-6.7b", B=32, q=bits).tops_per_w
        qp, _ = quantize_model(params, QuantSpec(format="rtn", bits=bits,
                                                 group_size=gs), model.axes())
        rows.append((f"FIGNA-RTN-Q{bits}", bits,
                     common.perplexity(m_q, qp), eff))
        qp = optq_quantize_model(params, model.axes(),
                                 lambda p, n: jnp.asarray(calib[p]),
                                 bits=bits, group_size=gs)
        ppl = common.perplexity(m_q, qp)
        rows.append((f"FIGNA-OPTQ-Q{bits}", bits, ppl, eff))

    # non-uniform BCQ at 2/3/4 bits (ShiftAddLLM-class -> FIGLUT)
    bytes_by_bits = {}
    for bits in (2, 3, 4):
        qp, man = quantize_model(params, QuantSpec(bits=bits, group_size=gs,
                                                   iters=4), model.axes())
        bytes_by_bits[bits] = man.quant_bytes
        ppl = common.perplexity(m_q, qp)
        eff = em.model_report("FIGLUT-I", "opt-6.7b", B=32, q=bits).tops_per_w
        rows.append((f"FIGLUT-BCQ-Q{bits}", bits, ppl, eff))

    # ternary (1.58-bit plane bundle): the below-2-bit end of the
    # tradeoff curve — strictly fewer weight bytes than generic BCQ2
    # (one alpha row, no offset) at the bit-serial engine's q=2 cost
    from repro.quant import TERNARY_BITS
    qp, man_t = quantize_model(
        params, QuantSpec(format="ternary", group_size=gs), model.axes())
    ppl_t = common.perplexity(m_q, qp)
    eff_t = em.model_report("FIGLUT-I", "opt-6.7b", B=32,
                            q=TERNARY_BITS).tops_per_w
    rows.append((f"FIGLUT-TERNARY-Q{TERNARY_BITS:.2f}", TERNARY_BITS,
                 ppl_t, eff_t))
    print(f"fig17,ternary_quant_bytes={man_t.quant_bytes},"
          f"bcq2_quant_bytes={bytes_by_bits[2]}")
    assert man_t.quant_bytes < bytes_by_bits[2], \
        (man_t.quant_bytes, bytes_by_bits[2])

    # mixed precision averaging ~2.4 bits
    lin = collect_linears(params, model.axes())
    bit_map = allocate_bits(lin, target_avg_bits=2.4, candidates=(2, 3, 4),
                            group_size=gs)  # lin is axes-filtered above
    avg = average_bits(bit_map, lin)
    qp, _ = quantize_model(params, QuantSpec(bits=2, group_size=gs, iters=4,
                                             overrides=bit_map), model.axes())
    ppl = common.perplexity(m_q, qp)
    eff = em.model_report("FIGLUT-I", "opt-6.7b", B=32, q=avg).tops_per_w
    rows.append((f"FIGLUT-BCQ-Q{avg:.2f}(mixed)", avg, ppl, eff))

    print(f"fig17,FP16-baseline,ppl={ppl_fp:.3f}")
    for name, bits, ppl, eff in rows:
        print(f"fig17,{name},bits={bits},ppl={ppl:.3f},TOPS/W={eff:.3f}")

    d = {name: (ppl, eff) for name, _, ppl, eff in rows}
    bcq3, figna3 = d["FIGLUT-BCQ-Q3"], d["FIGNA-OPTQ-Q3"]
    # paper: at Q3 FIGLUT has lower ppl AND ~1.6x efficiency
    assert bcq3[0] <= figna3[0] + 0.02, "BCQ3 ppl should beat uniform Q3"
    assert 1.3 < bcq3[1] / figna3[1] < 2.2
    # paper: mixed 2.4-bit ~doubles efficiency vs FIGNA-Q3 at similar ppl
    mixed = [v for k, v in d.items() if "mixed" in k][0]
    print(f"fig17,claim_check,mixed2.4_vs_FIGNA-Q3_eff="
          f"{mixed[1]/figna3[1]:.2f} (paper 1.98), ppl_delta="
          f"{mixed[0]-figna3[0]:+.3f}")
    assert mixed[1] / figna3[1] > 1.5
    # Table VI trend: BCQ4 close to FP
    assert d["FIGLUT-BCQ-Q4"][0] < ppl_fp * 1.10
    return rows


if __name__ == "__main__":
    run()
