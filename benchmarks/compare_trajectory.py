"""Perf-trajectory gate: compare a fresh ``BENCH_*.json`` against the
committed baseline and FAIL LOUDLY on regression.

The serving and kernel benches (``bench_serve --bench-json``,
``bench_kernels --bench-json``) emit a schema-versioned file of tracked
scalars; the repo commits a baseline per bench under
``benchmarks/baselines/``.  CI's ``perf-trajectory`` job re-runs the
benches and gates the diff here, so tokens/s, TTFT, KV bytes/token and
prefix-cache effectiveness have a committed history instead of only
living in uploaded artifacts (the ROADMAP's "no committed perf history
at all").

    PYTHONPATH=src python -m benchmarks.compare_trajectory \
        BENCH_serve.json benchmarks/baselines/BENCH_serve.json

Each tracked scalar in the BASELINE (the baseline's gate fields win —
a regressing run cannot loosen its own tolerances) carries:

  * ``value``     — the baseline measurement;
  * ``direction`` — ``"higher"`` (throughput-like) or ``"lower"``
    (latency/traffic-like): which way is better;
  * ``rel_tol``   — allowed relative degradation vs the baseline value
    (``0.8`` on wall-clock scalars absorbs CI-runner variance; ``0.0``
    pins deterministic scalars exactly);
  * ``abs_max`` / ``abs_min`` (optional) — absolute bounds that apply
    regardless of the baseline value (e.g. trace overhead <= 5%).

A scalar the baseline tracks but the current run no longer emits is a
failure too (coverage must not silently shrink); a new scalar in the
current run is reported as a candidate for the next baseline reseed.
Improvements always pass.  To reseed after an intentional change, copy
the fresh file over the committed baseline in the same PR and say why.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Tuple

SCHEMA_VERSION = 1


def load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if data.get("schema_version") != SCHEMA_VERSION:
        raise SystemExit(f"{path}: schema_version "
                         f"{data.get('schema_version')!r} != "
                         f"{SCHEMA_VERSION} (regenerate or migrate)")
    if "scalars" not in data or "bench" not in data:
        raise SystemExit(f"{path}: not a BENCH file (missing scalars/bench)")
    return data


def _check(name: str, cur: float, base: dict) -> Tuple[str, str]:
    """-> (status, detail); status in {"ok", "improved", "REGRESSED"}."""
    bv = float(base["value"])
    direction = base.get("direction", "higher")
    tol = float(base.get("rel_tol", 0.0))
    if direction not in ("higher", "lower"):
        return "REGRESSED", f"baseline has bad direction {direction!r}"
    if base.get("abs_max") is not None and cur > float(base["abs_max"]):
        return "REGRESSED", f"{cur:.6g} > abs_max {base['abs_max']:.6g}"
    if base.get("abs_min") is not None and cur < float(base["abs_min"]):
        return "REGRESSED", f"{cur:.6g} < abs_min {base['abs_min']:.6g}"
    if direction == "higher":
        floor = bv * (1.0 - tol) if bv >= 0 else bv * (1.0 + tol)
        if cur < floor:
            return "REGRESSED", (f"{cur:.6g} < {floor:.6g} "
                                 f"(baseline {bv:.6g}, rel_tol {tol})")
        return ("improved" if cur > bv else "ok"), ""
    ceil = bv * (1.0 + tol) if bv >= 0 else bv * (1.0 - tol)
    if cur > ceil:
        return "REGRESSED", (f"{cur:.6g} > {ceil:.6g} "
                             f"(baseline {bv:.6g}, rel_tol {tol})")
    return ("improved" if cur < bv else "ok"), ""


def compare(current: dict, baseline: dict) -> Tuple[List[str], List[dict]]:
    """-> (failures, report_rows).  Empty failures == gate passes."""
    failures: List[str] = []
    rows: List[dict] = []
    if current.get("bench") != baseline.get("bench"):
        failures.append(f"bench mismatch: current {current.get('bench')!r} "
                        f"vs baseline {baseline.get('bench')!r}")
        return failures, rows
    cur_scalars = current["scalars"]
    for name, base in sorted(baseline["scalars"].items()):
        cur = cur_scalars.get(name)
        if cur is None:
            failures.append(f"{name}: tracked scalar missing from the "
                            "current run (coverage regression)")
            rows.append({"scalar": name, "baseline": base["value"],
                         "current": None, "status": "MISSING"})
            continue
        status, detail = _check(name, float(cur["value"]), base)
        if status == "REGRESSED":
            failures.append(f"{name}: {detail}")
        rows.append({"scalar": name, "baseline": base["value"],
                     "current": cur["value"], "status": status,
                     "detail": detail})
    for name in sorted(set(cur_scalars) - set(baseline["scalars"])):
        rows.append({"scalar": name, "baseline": None,
                     "current": cur_scalars[name]["value"],
                     "status": "new (reseed baseline to track)"})
    return failures, rows


def _fmt(v) -> str:
    if v is None:
        return "-"
    return f"{v:.6g}" if isinstance(v, float) else str(v)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when a tracked perf scalar regresses beyond "
                    "its baseline tolerance")
    ap.add_argument("current", help="fresh BENCH_*.json from this run")
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    args = ap.parse_args(argv)
    current, baseline = load(args.current), load(args.baseline)
    failures, rows = compare(current, baseline)
    name = current.get("bench", "?")
    print(f"perf-trajectory[{name}]: {args.current} vs {args.baseline}")
    w = max([len(r["scalar"]) for r in rows] + [6])
    print(f"  {'scalar':<{w}} {'baseline':>12} {'current':>12}  status")
    for r in rows:
        print(f"  {r['scalar']:<{w}} {_fmt(r['baseline']):>12} "
              f"{_fmt(r['current']):>12}  {r['status']}"
              + (f" ({r['detail']})" if r.get("detail") else ""))
    if failures:
        print(f"\nPERF TRAJECTORY REGRESSION ({name}): "
              f"{len(failures)} tracked scalar(s) regressed beyond "
              "tolerance:")
        for f in failures:
            print(f"  !! {f}")
        print("If this regression is intentional, reseed the baseline "
              "(copy the fresh BENCH file over the committed one) in the "
              "same PR and explain why in the PR description.")
        return 1
    print(f"perf-trajectory[{name}]: PASS "
          f"({len(rows)} scalars within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
