"""Fig 15 analogue: normalized energy breakdown across bit precisions.

Paper claims checked on OPT-6.7B:
  * everything normalized to FPE at the same precision;
  * bit-serial engines (iFPU, FIGLUT) scale energy DOWN with sub-4-bit q;
    fixed-width engines (FPE, FIGNA) pay padded-Q4 cost at Q1-Q3;
  * FIGLUT-I has the lowest compute energy at every sub-4-bit precision;
  * iFPU's flip-flop-heavy pipeline gives it a worse energy profile than
    its area would suggest.
"""
from repro.core import energy_model as em
from benchmarks import common

ENGINES = ("FPE", "iFPU", "FIGNA", "FIGLUT-F", "FIGLUT-I")


def run():
    common.header("Fig 15 analogue — energy breakdown (normalized to FPE)")
    results = {}
    for q in (1, 2, 3, 4, 8):
        base = em.model_report("FPE", "opt-6.7b", B=32, q=q).total_J
        for eng in ENGINES:
            r = em.model_report(eng, "opt-6.7b", B=32, q=q)
            results[(eng, q)] = r.total_J / base
            print(f"fig15,q={q},{eng},compute={r.compute_J/base:.3f},"
                  f"sram={r.sram_J/base:.3f},dram={r.dram_J/base:.3f},"
                  f"total={r.total_J/base:.3f}")
    # bit-serial energy decreases with q; fixed-width stays flat sub-4-bit
    assert results[("FIGLUT-I", 2)] < results[("FIGLUT-I", 4)]
    assert results[("iFPU", 2)] < results[("iFPU", 4)]
    # FIGLUT-I cheapest at sub-4-bit
    for q in (1, 2, 3):
        others = [results[(e, q)] for e in ENGINES if e != "FIGLUT-I"]
        assert results[("FIGLUT-I", q)] <= min(others) * 1.02, q
    return results


if __name__ == "__main__":
    run()
