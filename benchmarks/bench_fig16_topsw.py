"""Fig 16 analogue: TOPS/W for sub-4-bit weights across OPT model sizes.

Headline paper claim checked: **"For the same 3-bit weight precision,
FIGLUT demonstrates 59% higher TOPS/W"** than FIGNA (which executes Q3 as
padded Q4).  Model tolerance ±25%.
"""
from repro.core import energy_model as em
from benchmarks import common

MODELS = ("opt-350m", "opt-1.3b", "opt-2.7b", "opt-6.7b", "opt-13b", "opt-30b")


def run():
    common.header("Fig 16 analogue — TOPS/W, sub-4-bit")
    ratios_q3 = []
    for model in MODELS:
        for q in (2, 3, 4):
            rows = {}
            for eng in ("FPE", "iFPU", "FIGNA", "FIGLUT-I"):
                r = em.model_report(eng, model, B=32, q=q)
                rows[eng] = r.tops_per_w
                print(f"fig16,{model},q={q},{eng},TOPS/W={r.tops_per_w:.3f}")
            # FIGLUT highest TOPS/W at every bit-width (paper claim)
            assert rows["FIGLUT-I"] == max(rows.values()), (model, q)
            if q == 3:
                ratios_q3.append(rows["FIGLUT-I"] / rows["FIGNA"])
    mean_ratio = sum(ratios_q3) / len(ratios_q3)
    print(f"fig16,claim_check,q3_FIGLUT_vs_FIGNA={mean_ratio:.2f} "
          f"(paper: 1.59; tolerance ±25%)")
    assert 1.59 * 0.75 < mean_ratio < 1.59 * 1.35, mean_ratio
    return mean_ratio


if __name__ == "__main__":
    run()
