"""Kernel micro-benchmarks: Pallas kernels (interpret mode) vs oracles.

Wall-times here are CPU-interpret numbers — NOT TPU performance — but
they pin correctness at benchmark scale and record the op-count ratios
the TPU roofline uses.  ``--bench-json`` writes the tracked-scalar file
for the perf-trajectory gate (``benchmarks.compare_trajectory``):
kernel max-errors, the tuned-vs-default speedup floor and the paged
pool-read ratio get a committed history.
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import tune as tune_mod
from repro.core import bcq
from repro.kernels.lut_gemm import lut_gemm, ref as lref
from repro.kernels.bcq_matmul import bcq_matmul
from repro.kernels.ternary_matmul import ternary_matmul, ternary_ref
from repro.quant.formats import quantize_ternary
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_int8,
                                           paged_attention_mla,
                                           paged_decode_int8_ref,
                                           paged_decode_mla_ref,
                                           paged_decode_ref, paged_prefill,
                                           paged_prefill_ref)


def _paged_decode_case(rng, *, b=4, h=8, hkv=4, d=32, nb=33, bs=8, pages=8):
    """A scrambled paged-decode problem: ragged live lengths, -1 pads.
    ``nb`` must cover the worst case (b * pages live blocks + trash)."""
    assert nb > b * pages, "pool too small for worst-case live blocks"
    k = jnp.array(rng.normal(size=(nb, bs, hkv, d)).astype(np.float32))
    v = jnp.array(rng.normal(size=(nb, bs, hkv, d)).astype(np.float32))
    q = jnp.array(rng.normal(size=(b, h, d)).astype(np.float32))
    tables = np.full((b, pages), -1, np.int32)
    pos = np.full((nb, bs), -1, np.int32)
    free = list(rng.permutation(np.arange(1, nb)))
    positions = np.zeros(b, np.int32)
    for row in range(b):
        live = int(rng.integers(1, pages * bs))
        positions[row] = live - 1
        for j in range(-(-live // bs)):
            blk = free.pop()
            tables[row, j] = blk
            pos[blk] = j * bs + np.arange(bs)
    return (q, k, v, jnp.asarray(pos), jnp.asarray(tables),
            jnp.asarray(positions))


def _paged_attention_bench(rng):
    """Fused paged decode (interpret) vs the gathered-view oracle:
    correctness + timing + the pool-read fraction of the gathered view's
    traffic (live blocks / (3 x table-addressable view))."""
    q, k, v, pos, tables, positions = _paged_decode_case(rng)
    want = paged_decode_ref(q, k, v, pos, tables, positions)
    got = paged_attention(q, k, v, pos, tables, positions, interpret=True)
    err = float(jnp.abs(got - want).max())
    live = int((np.asarray(tables) >= 0).sum())
    total = 3 * tables.shape[0] * tables.shape[1]
    print(f"kernels,paged_attention_maxerr={err:.2e},"
          f"kv_block_reads_fused={live},kv_block_reads_gathered={total},"
          f"ratio={live/total:.3f}")
    assert err < 1e-4
    assert live < total
    common.bench(
        "kernels,paged_attention_interpret",
        lambda: jax.block_until_ready(
            paged_attention(q, k, v, pos, tables, positions, interpret=True)),
        n=2)
    common.bench(
        "kernels,paged_gather_oracle",
        lambda: jax.block_until_ready(
            paged_decode_ref(q, k, v, pos, tables, positions)), n=2)
    return err, live / total


def _paged_variant_bench(rng):
    """The coverage-matrix variants vs their gathered oracles: int8-KV
    decode (per-slot scales folded in-kernel; bf16 compute sets the
    error scale), MLA latent decode, and the chunked-prefill flash
    kernel (float pool).  Returns the three max-errors."""
    q, k, v, pos, tables, positions = _paged_decode_case(rng)
    nb, bs, hkv, d = k.shape
    k8 = jnp.asarray(np.clip(np.round(rng.normal(size=k.shape) * 40),
                             -127, 127), jnp.int8)
    v8 = jnp.asarray(np.clip(np.round(rng.normal(size=v.shape) * 40),
                             -127, 127), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.05, (nb, bs, hkv)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.01, 0.05, (nb, bs, hkv)), jnp.float32)
    want8 = paged_decode_int8_ref(q, k8, v8, ks, vs, pos, tables, positions)
    got8 = paged_attention_int8(q, k8, v8, ks, vs, pos, tables, positions,
                                interpret=True)
    err8 = float(jnp.abs(got8.astype(jnp.float32)
                         - want8.astype(jnp.float32)).max())

    b, h = q.shape[0], q.shape[1]
    lora, dr = 16, 8
    ckv = jnp.asarray(rng.normal(size=(nb, bs, lora)), jnp.float32)
    krope = jnp.asarray(rng.normal(size=(nb, bs, dr)), jnp.float32)
    q_eff = jnp.asarray(rng.normal(size=(b, h, lora)), jnp.float32)
    q_rope = jnp.asarray(rng.normal(size=(b, h, dr)), jnp.float32)
    sc = (lora + dr) ** -0.5
    want_m = paged_decode_mla_ref(q_eff, q_rope, ckv, krope, pos, tables,
                                  positions, scale=sc)
    got_m = paged_attention_mla(q_eff, q_rope, ckv, krope, pos, tables,
                                positions, scale=sc, interpret=True)
    err_m = float(jnp.abs(got_m - want_m).max())

    c = 6
    qc = jnp.asarray(rng.normal(size=(b, c, h, d)), jnp.float32)
    cpos = (np.asarray(positions)[:, None]
            - np.arange(c - 1, -1, -1)[None]).astype(np.int32)
    cpos = jnp.asarray(np.where(cpos < 0, -1, cpos))
    want_p = paged_prefill_ref(qc, k, v, pos, tables, cpos)
    got_p = paged_prefill(qc, k, v, pos, tables, cpos, interpret=True)
    err_p = float(jnp.abs(got_p - want_p).max())

    print(f"kernels,paged_attention_int8_maxerr={err8:.2e},"
          f"paged_attention_mla_maxerr={err_m:.2e},"
          f"paged_prefill_maxerr={err_p:.2e}")
    assert err8 < 5e-2 and err_m < 1e-4 and err_p < 1e-4
    common.bench(
        "kernels,paged_attention_int8_interpret",
        lambda: jax.block_until_ready(
            paged_attention_int8(q, k8, v8, ks, vs, pos, tables, positions,
                                 interpret=True)), n=2)
    common.bench(
        "kernels,paged_prefill_interpret",
        lambda: jax.block_until_ready(
            paged_prefill(qc, k, v, pos, tables, cpos, interpret=True)),
        n=2)
    return err8, err_m, err_p


def _ternary_bench(rng):
    """The dedicated ternary kernel vs its gathered oracle and vs the
    generic 2-plane encoding it replaces: exact numerics (aligned
    launch geometry — both sides evaluate identical f32 ops) and the
    structural storage win (one alpha row, no offset row), plus
    interpret-mode wall-times against the generic lut_gemm at q=2."""
    M, N, B = 256, 512, 8
    W = jnp.array(rng.normal(size=(M, N)).astype(np.float32))
    x = jnp.array(rng.normal(size=(B, N)).astype(np.float32))
    wt = quantize_ternary(W, group_size=128)
    wq2 = bcq.quantize(W, bits=2, group_size=128, iters=2)

    want = ternary_ref(x, wt)
    got = ternary_matmul(x, wt, interpret=True, block_b=B, block_n=N)
    err = float(jnp.abs(got - want).max())

    # bit-exactness gate on the arithmetically exact case (pow2 alphas,
    # integer activations): every partial product is an exact f32, so
    # kernel == oracle holds regardless of reduction order/fusion
    wi = jnp.array(0.5 * rng.integers(-1, 2, size=(M, N)).astype(np.float32))
    xi = jnp.array(rng.integers(-8, 9, size=(B, N)).astype(np.float32))
    wti = quantize_ternary(wi, group_size=128)
    exact_err = float(jnp.abs(
        ternary_matmul(xi, wti, interpret=True, block_b=B)
        - ternary_ref(xi, wti)).max())
    bytes_ratio = wt.nbytes() / wq2.nbytes()
    print(f"kernels,ternary_matmul_maxerr={err:.2e},"
          f"ternary_matmul_exact_err={exact_err:.2e},"
          f"ternary_bytes={wt.nbytes()},bcq2_bytes={wq2.nbytes()},"
          f"bytes_ratio={bytes_ratio:.3f}")
    assert exact_err == 0.0, \
        "ternary kernel must be bit-exact vs the oracle on exact inputs"
    assert err < 1e-4, err   # float case: reduction-order ulps only
    # the layout's point: strictly fewer weight bytes than generic 2-bit
    assert wt.nbytes() < wq2.nbytes(), (wt.nbytes(), wq2.nbytes())
    common.bench(
        "kernels,ternary_matmul_interpret",
        lambda: jax.block_until_ready(
            ternary_matmul(x, wt, interpret=True)), n=2)
    common.bench(
        "kernels,lut_gemm_q2_interpret",
        lambda: jax.block_until_ready(lut_gemm(x, wq2, interpret=True)),
        n=2)
    return err, exact_err, bytes_ratio


def _tuned_vs_default(rng):
    """Autotune both kernels on a small shape and report the speedup of
    the measured winner over the heuristic default.  The heuristic is
    candidate 0 of the tuner's space, so the winner's median can never be
    slower — speedup >= 1.0 is a structural invariant, and > 1.0 means
    the space genuinely contains a better launch for this point."""
    M, N, B = 128, 256, 8
    W = jnp.array(rng.normal(size=(M, N)).astype(np.float32))
    x = jnp.array(rng.normal(size=(B, N)).astype(np.float32))
    wq = bcq.from_uniform(W, bits=4, group_size=128)
    wt = quantize_ternary(W, group_size=128)
    best_speedup = 0.0
    for kernel in ("lut_gemm", "bcq_matmul", "ternary_matmul"):
        w_in = wt if kernel == "ternary_matmul" else wq
        res = tune_mod.tune(kernel, x, w_in, mu=4, reps=3, warmup=1,
                            max_candidates=8, cache=None, interpret=True)
        print(f"kernels,{kernel}_default_ms={res.default_time*1e3:.3f},"
              f"tuned_ms={res.best_time*1e3:.3f},speedup={res.speedup:.2f},"
              f"config=\"{res.best.to_kwargs(kernel)}\"")
        best_speedup = max(best_speedup, res.speedup)
    assert best_speedup >= 1.0, f"tuned slower than default: {best_speedup}"
    return best_speedup


def _scalar(value, direction, rel_tol, **bounds):
    s = {"value": float(value), "direction": direction, "rel_tol": rel_tol}
    s.update(bounds)
    return s


def run(bench_json: str = ""):
    common.header("Kernel benches (interpret mode, correctness + timing)")
    rng = np.random.default_rng(0)
    M, N, B = 256, 512, 8
    W = jnp.array(rng.normal(size=(M, N)).astype(np.float32))
    x = jnp.array(rng.normal(size=(B, N)).astype(np.float32))
    wq = bcq.from_uniform(W, bits=4, group_size=128)
    want = lref.dense_ref(x, wq)

    y1 = lut_gemm(x, wq, interpret=True)
    err1 = float(jnp.abs(y1 - want).max())
    y2 = bcq_matmul(x, wq, interpret=True)
    err2 = float(jnp.abs(y2 - want).max())
    print(f"kernels,lut_gemm_maxerr={err1:.2e},bcq_matmul_maxerr={err2:.2e}")
    assert err1 < 1e-3 and err2 < 1e-3

    common.bench("kernels,lut_gemm_interpret",
                 lambda: jax.block_until_ready(lut_gemm(x, wq, interpret=True)),
                 n=2)
    common.bench("kernels,bcq_matmul_interpret",
                 lambda: jax.block_until_ready(bcq_matmul(x, wq, interpret=True)),
                 n=2)
    common.bench("kernels,dense_oracle",
                 lambda: jax.block_until_ready(lref.dense_ref(x, wq)), n=2)
    paged_err, read_ratio = _paged_attention_bench(rng)
    err_int8, err_mla, err_prefill = _paged_variant_bench(rng)
    err_t, exact_err_t, t_bytes_ratio = _ternary_bench(rng)
    speedup = _tuned_vs_default(rng)
    if bench_json:
        # max-errors gate with generous relative slack (FP noise varies
        # across BLAS builds) plus a hard abs_max safety net one decade
        # under the assert thresholds above; the block-read ratio and
        # the speedup floor are deterministic and pinned tight
        scalars = {
            "lut_gemm_maxerr": _scalar(err1, "lower", 3.0, abs_max=1e-3),
            "bcq_matmul_maxerr": _scalar(err2, "lower", 3.0, abs_max=1e-3),
            "paged_attention_maxerr":
                _scalar(paged_err, "lower", 3.0, abs_max=1e-4),
            # int8's bound reflects bf16 compute + running-vs-global
            # softmax rounding, not a kernel defect
            "paged_attention_int8_maxerr":
                _scalar(err_int8, "lower", 3.0, abs_max=5e-2),
            "paged_attention_mla_maxerr":
                _scalar(err_mla, "lower", 3.0, abs_max=1e-4),
            "paged_prefill_maxerr":
                _scalar(err_prefill, "lower", 3.0, abs_max=1e-4),
            "paged_kv_block_read_ratio":
                _scalar(read_ratio, "lower", 0.0),
            # float case: reduction-order ulps only (fusion-dependent)
            "ternary_matmul_maxerr":
                _scalar(err_t, "lower", 3.0, abs_max=1e-4),
            # exact-arithmetic case: bit-exact by contract, gate at zero
            "ternary_matmul_exact_err":
                _scalar(exact_err_t, "lower", 0.0, abs_max=0.0),
            # deterministic layout ratio; < 1 is the format's raison
            # d'etre (one alpha row, no offset vs the 2-plane generic)
            "ternary_vs_bcq2_bytes_ratio":
                _scalar(t_bytes_ratio, "lower", 0.0, abs_max=0.999),
            # timing-derived: the structural abs_min=1.0 floor is the
            # real gate, the relative slack absorbs timer jitter
            "tuned_speedup": _scalar(speedup, "higher", 0.9, abs_min=1.0),
        }
        data = {"schema_version": 1, "bench": "kernels",
                "scalars": scalars,
                "meta": {"source": "benchmarks.bench_kernels",
                         "jax": jax.__version__}}
        with open(bench_json, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"kernels,bench_json={bench_json}")
    return err1, err2, speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-json", default="",
                    help="write tracked scalars for the perf-trajectory "
                         "gate (compare with benchmarks.compare_trajectory)")
    args = ap.parse_args()
    run(bench_json=args.bench_json)


if __name__ == "__main__":
    main()
