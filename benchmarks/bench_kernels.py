"""Kernel micro-benchmarks: Pallas kernels (interpret mode) vs oracles.

Wall-times here are CPU-interpret numbers — NOT TPU performance — but
they pin correctness at benchmark scale and record the op-count ratios
the TPU roofline uses.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import tune as tune_mod
from repro.core import bcq
from repro.kernels.lut_gemm import lut_gemm, ref as lref
from repro.kernels.bcq_matmul import bcq_matmul


def _tuned_vs_default(rng):
    """Autotune both kernels on a small shape and report the speedup of
    the measured winner over the heuristic default.  The heuristic is
    candidate 0 of the tuner's space, so the winner's median can never be
    slower — speedup >= 1.0 is a structural invariant, and > 1.0 means
    the space genuinely contains a better launch for this point."""
    M, N, B = 128, 256, 8
    W = jnp.array(rng.normal(size=(M, N)).astype(np.float32))
    x = jnp.array(rng.normal(size=(B, N)).astype(np.float32))
    wq = bcq.from_uniform(W, bits=4, group_size=128)
    best_speedup = 0.0
    for kernel in ("lut_gemm", "bcq_matmul"):
        res = tune_mod.tune(kernel, x, wq, mu=4, reps=3, warmup=1,
                            max_candidates=8, cache=None, interpret=True)
        print(f"kernels,{kernel}_default_ms={res.default_time*1e3:.3f},"
              f"tuned_ms={res.best_time*1e3:.3f},speedup={res.speedup:.2f},"
              f"config=\"{res.best.to_kwargs(kernel)}\"")
        best_speedup = max(best_speedup, res.speedup)
    assert best_speedup >= 1.0, f"tuned slower than default: {best_speedup}"
    return best_speedup


def run():
    common.header("Kernel benches (interpret mode, correctness + timing)")
    rng = np.random.default_rng(0)
    M, N, B = 256, 512, 8
    W = jnp.array(rng.normal(size=(M, N)).astype(np.float32))
    x = jnp.array(rng.normal(size=(B, N)).astype(np.float32))
    wq = bcq.from_uniform(W, bits=4, group_size=128)
    want = lref.dense_ref(x, wq)

    y1 = lut_gemm(x, wq, interpret=True)
    err1 = float(jnp.abs(y1 - want).max())
    y2 = bcq_matmul(x, wq, interpret=True)
    err2 = float(jnp.abs(y2 - want).max())
    print(f"kernels,lut_gemm_maxerr={err1:.2e},bcq_matmul_maxerr={err2:.2e}")
    assert err1 < 1e-3 and err2 < 1e-3

    common.bench("kernels,lut_gemm_interpret",
                 lambda: jax.block_until_ready(lut_gemm(x, wq, interpret=True)),
                 n=2)
    common.bench("kernels,bcq_matmul_interpret",
                 lambda: jax.block_until_ready(bcq_matmul(x, wq, interpret=True)),
                 n=2)
    common.bench("kernels,dense_oracle",
                 lambda: jax.block_until_ready(lref.dense_ref(x, wq)), n=2)
    speedup = _tuned_vs_default(rng)
    return err1, err2, speedup


if __name__ == "__main__":
    run()
