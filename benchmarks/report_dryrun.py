"""Render the dry-run result JSONs into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m benchmarks.report_dryrun [--dir benchmarks/results/dryrun]
"""
import argparse
import glob
import json
import os


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def load(dir_):
    rows = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def render(rows):
    single = [r for r in rows if not r.get("multi_pod") and not r.get("skipped")]
    multi = [r for r in rows if r.get("multi_pod") and not r.get("skipped")]
    skipped = [r for r in rows if r.get("skipped")]

    out = []
    out.append("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
               "bound | roofline frac | useful FLOPs | mem GiB/dev |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in single:
        rf = r.get("roofline")
        if not rf:
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | "
                       f"{r['device_mem_gb']:.1f} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rf['t_compute_s']*1e3:.2f} | {rf['t_memory_s']*1e3:.2f} "
            f"| {rf['t_collective_s']*1e3:.2f} | {rf['bottleneck']} "
            f"| {rf['roofline_fraction']:.3f} | {rf['useful_flops_ratio']:.3f} "
            f"| {r['device_mem_gb']:.1f} |")
    out.append("")
    out.append(f"Multi-pod (2x16x16) compile proofs: "
               f"{len(multi)} cells OK: " +
               ", ".join(f"{r['arch']}/{r['shape']}" for r in multi))
    if skipped:
        out.append(f"\nSkipped cells (documented): " +
                   ", ".join(f"{r['arch']}/{r['shape']}" for r in skipped))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    args = ap.parse_args()
    print(render(load(args.dir)))


if __name__ == "__main__":
    main()
