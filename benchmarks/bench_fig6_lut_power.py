"""Fig 6 analogue: relative power of RFLUT vs FFLUT across mu, vs an FP
adder baseline at equivalent throughput; + Table III (hFFLUT halves LUT
power for ~0.5% decode overhead).

Paper's qualitative claims checked:
  * RFLUT read costs MORE than the FP adder it replaces (any mu) —> the
    reason a flip-flop LUT is needed at all;
  * FFLUT at mu in {2, 4} costs LESS than the FP adder;
  * mu = 8 blows up exponentially (excluded from the design space);
  * hFFLUT ~halves FFLUT power; decode overhead is trivial (Table III).
"""
from repro.core import energy_model as em
from benchmarks import common

FP_ADD = em.TECH.fp16_add


def run():
    common.header("Fig 6 analogue — LUT read power vs FP adder (pJ)")
    rows = {}
    for mu in (2, 4, 8):
        # per-FP-add-equivalent: one read replaces (mu-1)/... normalize per
        # read as the paper does (equivalent throughput per RAC)
        rf = em.rflut_read_energy(mu, 16)
        ff = em.fflut_read_energy(mu, 16, k=32, half=False)
        hff = em.fflut_read_energy(mu, 16, k=32, half=True)
        rows[mu] = (rf, ff, hff)
        print(f"fig6,mu={mu},rflut={rf/FP_ADD:.2f}x,fflut={ff/FP_ADD:.2f}x,"
              f"hfflut={hff/FP_ADD:.2f}x (of FP16 add)")

    # paper orderings.  Note: after Table-V power calibration the FULL
    # mu=4 FFLUT sits ~at the FP-adder line (paper Fig 8 likewise shows
    # mu=4, k=1 above baseline); the deployed design point is the hFFLUT,
    # which must clearly beat the adder.
    assert all(rows[mu][0] > FP_ADD for mu in (4, 8)), "RFLUT must exceed FP add"
    assert rows[2][1] < FP_ADD, "FFLUT(2) must beat FP add"
    assert rows[4][1] < 1.2 * FP_ADD, "FFLUT(4) must sit near FP add"
    assert rows[4][2] < FP_ADD, "hFFLUT(4) (deployed) must beat FP add"
    assert rows[8][1] > 4 * rows[4][1], "mu=8 must blow up"
    # Table III: hFFLUT ~ half the full-table mux + small decoder
    hff4_storage = em.fflut_static_energy_per_cycle(4, 16, half=True)
    ff4_storage = em.fflut_static_energy_per_cycle(4, 16, half=False)
    ratio = hff4_storage / ff4_storage
    print(f"table3,hfflut_storage_ratio={ratio:.3f} (paper: 0.494)")
    assert abs(ratio - 0.5) < 0.02
    return rows


if __name__ == "__main__":
    run()
