"""Fig 11 / §III-E analogue: LUT-generator adder counts.

Paper: the two-step tree generator needs 14 additions for the complete
mu=4 hFFLUT (42% fewer than naive), and beats k independent RAC adder
chains for k > 4.
"""
from repro.core import lut
from benchmarks import common


def run():
    common.header("Fig 11 analogue — LUT generator adder counts")
    naive = lut.naive_adder_count(4, half=True)
    tree = lut.generator_adder_count(4, half=True)
    saving = 1 - tree / naive
    print(f"fig11,mu=4,tree_adds={tree},naive_adds={naive},saving={saving:.0%}")
    assert tree == 14 and naive == 24
    assert abs(saving - 0.42) < 0.01

    # break-even vs straightforward hardware: k RACs need k*(mu-1) adds
    for k in (2, 4, 5, 8, 32):
        straightforward = k * 3
        wins = tree < straightforward
        print(f"fig11,break_even,k={k},lut_gen={tree},direct={straightforward},"
              f"lut_wins={wins}")
    assert lut.generator_adder_count(4) < 5 * 3       # wins for k=5
    assert lut.generator_adder_count(4) > 4 * 3       # not yet at k=4
    for mu in (2, 4, 6, 8):
        print(f"fig11,scaling,mu={mu},tree={lut.generator_adder_count(mu)},"
              f"naive={lut.naive_adder_count(mu)}")
    return tree, naive


if __name__ == "__main__":
    run()
