"""Serving benchmark: paged-KV engine end-to-end, dense vs BCQ backends.

Reports TTFT / per-token latency / throughput / pool occupancy for the
paged engine on a reduced model — CPU wall-times, NOT TPU performance,
but they pin the serving subsystem's behavior (admission, chunked
prefill, preemption accounting) and the dense-vs-quantized comparison
the paper's deployment story rests on.  A second section compares the
fused Pallas paged-attention paths (decode AND chunked prefill) against
the gathered ``paged_view`` fallback — token-for-token equality,
per-token latency, and the analytic KV bytes moved per decode and per
prefill token (the CI smoke asserts the fused paths' bytes are strictly
below the gathered paths' and the decode logits are finite) — and
repeats the comparison on int8-KV pools, where the fused kernels fold
the per-slot dequant scales in-kernel.  A third section replays a
shared-prefix
stream with the prefix cache on vs off at equal pool memory and asserts
identical tokens, hit-rate > 0, blocks saved > 0, effective capacity
peaking above 1x and a single-chunk warm-probe prefill.  A fourth
section measures the event-trace overhead (trace on vs off on a warm
engine, must stay <= 5% of tokens/s) and validates the exported Chrome
trace.  A fifth section drives open-loop Poisson traffic (seeded,
tick-indexed — no wall-clock randomness) through the double-buffered
async tick at three offered loads, reports goodput vs offered load and
the device-busy fraction, and asserts the async engine's tokens are
identical to the sync engine's with >= 95% of its throughput on the
saturated workload.  ``--bench-json`` writes the schema-versioned tracked-scalar
file the perf-trajectory gate (``benchmarks.compare_trajectory``)
diffs against the committed baseline.

    PYTHONPATH=src python -m benchmarks.bench_serve [--json out.json]
        [--bench-json BENCH_serve.json] [--trace-out trace.json]

``run()`` is the ``benchmarks.run`` registry entry (smoke scale).
"""
import argparse
import gc
import json
import os
import time

import jax
import numpy as np

from benchmarks import common
from repro.configs import get_reduced
from repro.models import Model
from repro.quant import QuantSpec, quantize_model
from repro.serve import PagedServeEngine, Request


def _requests(cfg, n, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(4, 28)),)),
                    max_new_tokens=max_new)
            for i in range(n)]


def bench_backend(label, model, params, cfg, *, requests=6, max_new=8,
                  num_blocks=32, block_size=8, max_batch=4, max_ticks=400):
    eng = PagedServeEngine(model, params, num_blocks=num_blocks,
                           block_size=block_size, max_batch=max_batch,
                           max_seq_len=128, prefill_buckets=(16, 32))
    reqs = _requests(cfg, requests, max_new)
    t0 = time.time()
    done = eng.run(reqs, max_ticks=max_ticks)
    dt = time.time() - t0
    eng.pool.check()
    s = eng.metrics.summary()
    toks = s["counters"]["tokens_out"]
    row = {
        "backend": label,
        "requests_done": len(done),
        "tokens": toks,
        "tok_per_s": toks / dt if dt > 0 else 0.0,
        "ttft_ms_p50": s["ttft_s"]["p50"] * 1e3,
        "ttft_ms_p95": s["ttft_s"]["p95"] * 1e3,
        "per_token_ms_p50": s["per_token_s"]["p50"] * 1e3,
        "occupancy_mean": s["occupancy"]["mean"],
        "occupancy_peak": s["occupancy"]["peak"],
        "peak_active": s["peak_active"],
        "preempted": s["counters"]["preempted"],
        "ticks": s["counters"]["ticks"],
    }
    print(f"serve,{label},tok_s={row['tok_per_s']:.1f},"
          f"ttft_ms_p50={row['ttft_ms_p50']:.1f},"
          f"per_token_ms_p50={row['per_token_ms_p50']:.1f},"
          f"occ_peak={row['occupancy_peak']:.2f},"
          f"preempted={row['preempted']}")
    assert len(done) == requests, (len(done), requests)
    return row


def _kernel_compare(label, model, params, cfg, *, requests=4, max_new=6,
                    num_blocks=24, block_size=8, max_batch=3,
                    max_ticks=400):
    """Drive the SAME request stream through a gathered and a fused
    engine of ``model``; assert token-for-token equality, that the fused
    engine actually resolved both paged paths to the fused kernels, and
    that the analytic KV traffic strictly favors fusion on BOTH the
    decode and the chunked-prefill leg.  Returns the two metric rows."""
    rows, outs = [], {}
    for mode in ("gather", "fused"):
        eng = PagedServeEngine(model, params, num_blocks=num_blocks,
                               block_size=block_size, max_batch=max_batch,
                               max_seq_len=128, prefill_buckets=(16, 32),
                               paged_kernel=mode)
        reqs = _requests(cfg, requests, max_new, seed=1)
        t0 = time.time()
        done = eng.run(reqs, max_ticks=max_ticks)
        dt = time.time() - t0
        eng.pool.check()
        outs[mode] = {r.uid: r.out_tokens for r in done}
        s = eng.metrics.summary()
        pk = s["paged_kernel"]
        row = {
            "paged_kernel": mode,
            "decode_path": eng.decode_path,
            "prefill_path": eng.prefill_path,
            "requests_done": len(done),
            "tokens": s["counters"]["tokens_out"],
            "tok_per_s": s["counters"]["tokens_out"] / dt if dt > 0 else 0.0,
            "per_token_ms_p50": s["per_token_s"]["p50"] * 1e3,
            "kv_bytes_per_token_fused": pk["kv_bytes_per_token_fused"],
            "kv_bytes_per_token_gathered":
                pk["kv_bytes_per_token_gathered"],
            "kv_bytes_per_prefill_token_fused":
                pk["kv_bytes_per_prefill_token_fused"],
            "kv_bytes_per_prefill_token_gathered":
                pk["kv_bytes_per_prefill_token_gathered"],
        }
        print(f"serve,paged_kernel={mode},variant={label},"
              f"path={row['decode_path']},"
              f"prefill_path={row['prefill_path']},"
              f"tok_s={row['tok_per_s']:.1f},"
              f"per_token_ms_p50={row['per_token_ms_p50']:.1f},"
              f"kv_B_per_tok_fused={row['kv_bytes_per_token_fused']:.0f},"
              f"kv_B_per_tok_gathered={row['kv_bytes_per_token_gathered']:.0f},"
              f"kv_B_per_pf_tok_fused="
              f"{row['kv_bytes_per_prefill_token_fused']:.0f},"
              f"kv_B_per_pf_tok_gathered="
              f"{row['kv_bytes_per_prefill_token_gathered']:.0f}")
        rows.append(row)
    assert outs["gather"] == outs["fused"], \
        f"fused {label} serving diverged from the gathered oracle"
    fused_row = rows[1]
    assert fused_row["decode_path"] == "fused", fused_row
    assert fused_row["prefill_path"] == "fused", fused_row
    # the fusion's point: strictly fewer KV bytes per token on BOTH legs
    assert fused_row["kv_bytes_per_token_fused"] \
        < fused_row["kv_bytes_per_token_gathered"], fused_row
    assert fused_row["kv_bytes_per_prefill_token_fused"] \
        < fused_row["kv_bytes_per_prefill_token_gathered"], fused_row
    return rows


def bench_paged_kernel(model, params, cfg, *, requests=4, max_new=6,
                       **kw):
    """Fused Pallas paged-attention kernels (decode + chunked prefill)
    vs the gathered paged_view path: same request stream, token-for-
    token equal outputs, per-token latency and the analytic KV bytes
    moved per decode AND per prefill token.

    The CPU wall-times favor the *gathered* path (the fused kernels run
    under the Pallas interpreter off-TPU); the KV-bytes columns are the
    roofline quantities the fusion exists for and must always favor the
    fused path."""
    rows = _kernel_compare("float", model, params, cfg,
                           requests=requests, max_new=max_new, **kw)

    # finiteness probe on the fused path's raw decode logits (the engine
    # only exposes argmax'd tokens); prefill runs fused here too
    import jax.numpy as jnp
    from repro.serve import set_block_tables
    mf = Model(cfg.replace(paged_kernel="fused"))
    cache = mf.init_paged_cache(1, num_blocks=8, block_size=4,
                                max_blocks_per_seq=6)
    cache = set_block_tables(cache, np.array([[2, 5, 1, -1, -1, -1]],
                                             np.int32))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    _, cache = mf.prefill_chunk(params, {"tokens": toks}, cache,
                                jnp.int32(0), jnp.int32(7))
    logits, _ = mf.decode_step(params, toks[:, :1], cache, 8)
    assert np.isfinite(np.asarray(logits)).all(), \
        "fused decode produced non-finite logits"
    print("serve,paged_kernel_finite=1,paged_kernel_equal=1")
    return rows


def bench_paged_kernel_int8(model, params, cfg, *, requests=4, max_new=6,
                            **kw):
    """int8-KV pools, fused vs gathered: the fused kernels DMA the
    per-slot scale rows alongside each block and fold the dequant into
    the score/value epilogues, so the serve-level contract is the same
    as the float variant — identical greedy tokens and strictly fewer
    KV bytes per token on both the decode and the prefill leg (the byte
    estimates on BOTH paths include the scale rows; see
    ``attention.kv_entry_bytes``)."""
    cfg8 = cfg.replace(kv_cache_bits=8)
    return _kernel_compare("int8", Model(cfg8), params, cfg8,
                           requests=requests, max_new=max_new, **kw)


def _drive_prefix_stream(eng, prefix, tails, probe_tail, max_new,
                         max_ticks=600):
    """Drive one engine through the shared-prefix schedule: a cold
    warm-up request, then ``len(tails)`` concurrent requests sharing
    ``prefix``, then a warm probe.  Returns per-request tokens plus the
    probe's TTFT (wall seconds) and prefill-chunk count — the
    deterministic proxy for 'near-zero TTFT on a warm prefix'."""
    toks = {}
    warm = Request(uid=1000, prompt=np.concatenate([prefix, tails[0]]),
                   max_new_tokens=max_new)
    eng.submit(warm)
    while not warm.done and eng.ticks < max_ticks:
        eng.step()
    toks[warm.uid] = warm.out_tokens

    batch = [Request(uid=i, prompt=np.concatenate([prefix, t]),
                     max_new_tokens=max_new)
             for i, t in enumerate(tails[1:])]
    for r in batch:
        eng.submit(r)
    while not all(r.done for r in batch) and eng.ticks < max_ticks:
        eng.step()
    for r in batch:
        toks[r.uid] = r.out_tokens

    chunks0 = eng.metrics.counters["prefill_chunks"]
    probe = Request(uid=2000, prompt=np.concatenate([prefix, probe_tail]),
                    max_new_tokens=2)
    t0 = time.time()
    eng.submit(probe)
    ttft = None
    while not probe.done and eng.ticks < max_ticks:
        eng.step()
        if ttft is None and probe.out_tokens:
            ttft = time.time() - t0
    toks[probe.uid] = probe.out_tokens
    eng.pool.check()
    return {"tokens": toks, "probe_ttft_s": ttft,
            "probe_chunks": eng.metrics.counters["prefill_chunks"] - chunks0}


def bench_prefix_cache(model, params, cfg, *, max_new=6, block_size=8,
                       num_blocks=25, max_batch=5):
    """Shared-prefix traffic through the SAME pool with the prefix cache
    on vs off: a 6-block system prompt, one cold warm-up request, five
    concurrent requests with unique tails, one warm probe.

    Pins the tentpole's acceptance criteria: token-for-token equality,
    prefix hit-rate > 0, blocks saved > 0, effective capacity (logical
    block-table entries over distinct pool blocks) peaking above 1x at
    equal KV memory, zero preemptions where the cache-off run is forced
    into preempt-by-recompute, and a warm probe that prefills in a
    single chunk (near-zero TTFT — the shared 48 tokens are adopted,
    not recomputed)."""
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, (6 * block_size,))
    tails = [rng.integers(0, cfg.vocab_size, (4,)) for _ in range(6)]
    probe_tail = rng.integers(0, cfg.vocab_size, (4,))

    rows, results = [], {}
    for label, on in (("off", False), ("on", True)):
        eng = PagedServeEngine(model, params, num_blocks=num_blocks,
                               block_size=block_size, max_batch=max_batch,
                               max_seq_len=128, prefill_buckets=(16, 32),
                               prefix_cache=on)
        res = _drive_prefix_stream(eng, prefix, tails, probe_tail, max_new)
        results[label] = res
        s = eng.metrics.summary()
        row = {
            "prefix_cache": label,
            "tokens": s["counters"]["tokens_out"],
            "prefill_chunks": s["counters"]["prefill_chunks"],
            "peak_active": s["peak_active"],
            "preempted": s["counters"]["preempted"],
            "prefix_hit_rate": s["prefix_cache"]["hit_rate"],
            "blocks_saved": s["prefix_cache"]["blocks_saved"],
            "tokens_saved": s["prefix_cache"]["tokens_saved"],
            "effective_capacity_peak": s["effective_capacity"]["peak"],
            "probe_ttft_ms": (res["probe_ttft_s"] or 0.0) * 1e3,
            "probe_prefill_chunks": res["probe_chunks"],
        }
        print(f"serve,prefix_cache={label},"
              f"hit_rate={row['prefix_hit_rate']:.2f},"
              f"blocks_saved={row['blocks_saved']},"
              f"effcap_peak={row['effective_capacity_peak']:.2f},"
              f"peak_active={row['peak_active']},"
              f"preempted={row['preempted']},"
              f"probe_ttft_ms={row['probe_ttft_ms']:.1f},"
              f"probe_chunks={row['probe_prefill_chunks']}")
        rows.append(row)

    off, on = rows
    # the whole point, asserted: identical tokens...
    assert results["off"]["tokens"] == results["on"]["tokens"], \
        "prefix cache changed generated tokens"
    # ...from fewer prefills and fewer distinct blocks
    assert on["prefix_hit_rate"] > 0, on
    assert on["blocks_saved"] > 0, on
    assert on["effective_capacity_peak"] > 1.0, on
    assert off["effective_capacity_peak"] == 1.0, off
    # equal memory, same load: without sharing the pool cannot hold all
    # five 8-block footprints (40 > 24 usable blocks) and must resort to
    # preempt-by-recompute; with sharing everything coexists
    assert off["preempted"] > on["preempted"], (on, off)
    assert on["preempted"] == 0, on
    assert on["prefill_chunks"] < off["prefill_chunks"], (on, off)
    # warm probe: the adopted 48 tokens leave a single-chunk prefill
    assert on["probe_prefill_chunks"] < off["probe_prefill_chunks"], \
        (on, off)
    assert on["probe_prefill_chunks"] == 1, on
    print("serve,prefix_equal=1")
    return rows


def bench_trace_overhead(model, params, cfg, *, requests=4, max_new=24,
                         num_blocks=24, block_size=8, max_batch=3,
                         trials=3, streams=3, trace_out=""):
    """Tokens/s with the event-level trace ON vs OFF on the same warm
    engine (jit caches hot, identical greedy request stream), plus
    structural checks on the produced trace: it must validate as Chrome
    trace-event JSON with >= 1 span per engine phase
    (admission/prefill/decode/sample) and a track per request.

    The acceptance bar is overhead <= 5% of tokens/s.  At smoke scale a
    single run is tens of milliseconds, where box noise (frequency
    scaling, co-tenants) swings wall-time far more than 5%, so each
    timed sample covers ``streams`` back-to-back replays of the long
    (``max_new``) decode leg and the overhead estimate is the MEDIAN of
    per-pair off->on ratios: the two modes of a pair run adjacent in
    time, so a slow stretch inflates both and cancels in the ratio,
    and the median outvotes an episodic hiccup that a min-vs-min
    comparison lets masquerade as tracing cost."""
    from repro import obs

    eng = PagedServeEngine(model, params, num_blocks=num_blocks,
                           block_size=block_size, max_batch=max_batch,
                           max_seq_len=128, prefill_buckets=(16, 32))
    # untimed warm-up: compile both entry points so neither timed mode
    # pays jit time
    eng.run(_requests(cfg, requests, max_new, seed=3), max_ticks=600)

    tracer = obs.Tracer()
    times = {"off": [], "on": []}
    toks_by_mode = {}

    def _trial_pair():
        # pause the cyclic GC while timing: in a long-lived bench
        # process the heap is large, so the event dicts tracing
        # allocates can trigger full collections whose cost scales with
        # the WHOLE heap — that's GC amplification, not tracing cost,
        # and it doesn't exist in a fresh serving process
        gc.collect()
        gc.disable()
        try:
            # one untimed lap re-warms caches/CPU after the collect so
            # the pair's first timed leg isn't systematically cold, and
            # alternating which mode runs first cancels any residual
            # within-pair order bias in the median of pair ratios
            eng.attach_tracer(None)
            eng.ticks = 0
            eng.run(_requests(cfg, requests, max_new, seed=4),
                    max_ticks=600)
            order = ("off", "on") if len(times["off"]) % 2 == 0 \
                else ("on", "off")
            for mode in order:
                eng.attach_tracer(tracer if mode == "on" else None)
                dt = 0.0
                for _ in range(streams):
                    reqs = _requests(cfg, requests, max_new, seed=4)
                    eng.ticks = 0
                    t0 = time.perf_counter()
                    eng.run(reqs, max_ticks=600)
                    dt += time.perf_counter() - t0
                    assert all(r.done and r.error is None for r in reqs)
                    toks = {r.uid: tuple(r.out_tokens) for r in reqs}
                    assert toks_by_mode.setdefault(mode, toks) == toks
                times[mode].append(dt)
        finally:
            gc.enable()

    def _overhead():
        n = streams * sum(len(t) for t in toks_by_mode["off"].values())
        ts = {m: n / min(v) for m, v in times.items()}
        ratios = sorted((on - off) / off
                        for off, on in zip(times["off"], times["on"]))
        return ts, 100.0 * ratios[len(ratios) // 2]

    for _ in range(trials):
        _trial_pair()
    tok_s, overhead_pct = _overhead()
    # the median is robust to an episodic hiccup, but a genuinely noisy
    # stretch can still tip a near-budget median over: buy more evidence
    # before declaring the budget blown
    while overhead_pct > 5.0 and len(times["off"]) < trials + 4:
        _trial_pair()
        tok_s, overhead_pct = _overhead()
    eng.attach_tracer(None)
    # greedy decode: tracing must not change a single token
    assert toks_by_mode["on"] == toks_by_mode["off"], \
        "tracing changed generated tokens"

    # structural acceptance: the trace loads and covers every phase
    chrome = obs.to_chrome(tracer)
    errs = obs.validate_chrome(chrome)
    assert not errs, f"trace failed validation: {errs}"
    spans = {e["name"] for e in tracer.events if e["ph"] == "X"}
    for phase in ("admission", "prefill_chunk", "decode_dispatch",
                  "sample", "device_sync", "tick"):
        assert phase in spans, f"no {phase!r} span in trace: {sorted(spans)}"
    req_tracks = {t for t in tracer.tracks() if t.startswith("req/")}
    assert req_tracks == {f"req/{u}" for u in toks_by_mode["on"]}, req_tracks
    if trace_out:
        obs.save_chrome(tracer, trace_out)
        print(f"serve,trace_out={trace_out}")

    row = {
        "tok_per_s_trace_off": tok_s["off"],
        "tok_per_s_trace_on": tok_s["on"],
        "trace_overhead_pct": overhead_pct,
        "trace_events": len(tracer.events),
        "trace_dropped": tracer.dropped,
    }
    print(f"serve,trace_overhead_pct={overhead_pct:.2f},"
          f"tok_s_off={tok_s['off']:.1f},tok_s_on={tok_s['on']:.1f},"
          f"events={row['trace_events']}")
    assert overhead_pct <= 5.0, \
        f"trace overhead {overhead_pct:.2f}% exceeds the 5% budget"
    return row


def _arrival_ticks(rate, n, seed):
    """Tick indices of ``n`` Poisson arrivals at ``rate`` requests/tick:
    floored cumulative exponential inter-arrival gaps from a seeded
    generator.  Tick-indexed, so the schedule is identical run-to-run
    and engine-to-engine — no wall-clock randomness anywhere."""
    rng = np.random.default_rng(seed)
    return np.floor(np.cumsum(rng.exponential(1.0 / rate, n))).astype(int)


def _saturation_requests(cfg, n, max_new, seed=11):
    """Mixed-sampling open-loop stream: even uids greedy, odd uids
    seeded temperature/top-k — the async engine must reproduce both
    on-device, token for token."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        kw = {} if i % 2 == 0 else \
            {"temperature": 0.8, "top_k": 20, "seed": 100 + i}
        reqs.append(Request(uid=i,
                            prompt=rng.integers(0, cfg.vocab_size,
                                                (int(rng.integers(4, 24)),)),
                            max_new_tokens=max_new, **kw))
    return reqs


def _drive_open_loop(eng, step, reqs, arrive, max_ticks=4000):
    """Submit each request at its scheduled tick, step until the engine
    drains (including the async engine's in-flight tail).  Idle ticks
    (arrival gaps with nothing running) advance the schedule without
    stepping.  Returns (wall seconds, ticks driven)."""
    i, t = 0, 0
    t0 = time.perf_counter()
    while i < len(reqs) or eng.sched.has_work() or eng.has_inflight:
        assert t < max_ticks, "open-loop drive did not drain"
        while i < len(reqs) and arrive[i] <= t:
            eng.submit(reqs[i])
            i += 1
        if eng.sched.has_work() or eng.has_inflight:
            step()
        t += 1
    eng.flush()
    return time.perf_counter() - t0, t


def bench_async_saturation(model, params, cfg, *, requests=8, max_new=8,
                           num_blocks=32, block_size=8, max_batch=4,
                           trials=3, streams=2):
    """Open-loop saturation: seeded Poisson arrivals at three offered
    loads through the double-buffered async tick, then sync vs async on
    the saturated workload.

    The goodput table sweeps under/near/over capacity (service rate is
    roughly ``max_batch / max_new`` requests per tick) and reports
    completed tokens/s plus the device-busy fraction at each load.  The
    comparison leg pins the tentpole's acceptance bar: identical tokens
    (greedy AND seeded-sampling requests) and async tokens/s >= 95% of
    the sync engine on the same workload — each timed sample covers
    ``streams`` back-to-back drives and the modes are timed interleaved
    best-of-N like the trace-overhead section, because single
    smoke-scale runs are at the mercy of box noise."""
    from repro.serve.metrics import ServeMetrics

    eng = PagedServeEngine(model, params, num_blocks=num_blocks,
                           block_size=block_size, max_batch=max_batch,
                           max_seq_len=128, prefill_buckets=(16, 32))
    rates = (0.15, 0.5, 2.0)
    # untimed warm-up: compile both tick paths (sync decode + host
    # sampling, fused decode_and_sample) before anything is timed
    for step in (eng.step, eng.step_async):
        _drive_open_loop(eng, step, _saturation_requests(cfg, 4, max_new),
                         _arrival_ticks(2.0, 4, seed=23))

    load_rows = []
    for rate in rates:
        eng.metrics = ServeMetrics(eng.clock)
        reqs = _saturation_requests(cfg, requests, max_new)
        dt, ticks = _drive_open_loop(eng, eng.step_async, reqs,
                                     _arrival_ticks(rate, requests, seed=23))
        assert all(r.done and r.error is None for r in reqs)
        eng.pool.check()
        s = eng.metrics.summary()
        row = {
            "offered_req_per_tick": rate,
            "requests_done": len(reqs),
            "tokens": s["counters"]["tokens_out"],
            "goodput_tok_per_s": s["counters"]["tokens_out"] / dt
                                 if dt > 0 else 0.0,
            "queue_delay_ms_p50": s["queue_delay_s"]["p50"] * 1e3,
            "device_busy_fraction": s["device_busy_fraction"],
            "preempted": s["counters"]["preempted"],
            "ticks": ticks,
        }
        print(f"serve,async_load={rate},"
              f"goodput_tok_s={row['goodput_tok_per_s']:.1f},"
              f"queue_delay_ms_p50={row['queue_delay_ms_p50']:.1f},"
              f"busy={row['device_busy_fraction']:.2f},"
              f"preempted={row['preempted']}")
        load_rows.append(row)
    # saturation keeps the device busier than a trickle
    assert load_rows[-1]["device_busy_fraction"] \
        > load_rows[0]["device_busy_fraction"], load_rows

    # -- sync vs async on the saturated workload -----------------------
    sat = _arrival_ticks(rates[-1], requests, seed=23)
    times = {"sync": [], "async": []}
    toks_by_mode, busy = {}, {}

    def _trial_pair():
        for mode, step in (("sync", eng.step), ("async", eng.step_async)):
            eng.metrics = ServeMetrics(eng.clock)
            dt = 0.0
            for _ in range(streams):
                reqs = _saturation_requests(cfg, requests, max_new)
                dt += _drive_open_loop(eng, step, reqs, sat)[0]
                assert all(r.done and r.error is None for r in reqs)
                toks = {r.uid: tuple(r.out_tokens) for r in reqs}
                assert toks_by_mode.setdefault(mode, toks) == toks
            times[mode].append(dt)
            busy[mode] = eng.metrics.device_busy_fraction()

    def _tok_s():
        n = streams * sum(len(t) for t in toks_by_mode["sync"].values())
        ts = {m: n / min(v) for m, v in times.items()}
        # median of per-pair speedups (paired design, like the trace
        # overhead section): the modes of a pair run adjacent in time,
        # so box-noise drift cancels in the ratio
        ratios = sorted(s / a for s, a in zip(times["sync"],
                                              times["async"]))
        return ts, ratios[len(ratios) // 2]

    for _ in range(trials):
        _trial_pair()
    tok_s, ratio = _tok_s()
    while ratio < 0.95 and len(times["sync"]) < trials + 4:
        _trial_pair()
        tok_s, ratio = _tok_s()
    # the acceptance bar: identical tokens, and the double-buffered loop
    # keeps >= 95% of the sync engine's throughput on the same workload
    assert toks_by_mode["async"] == toks_by_mode["sync"], \
        "async engine diverged from the sync engine"
    assert ratio >= 0.95, \
        (f"async/sync throughput ratio {ratio:.3f} < 0.95 "
         f"(async {tok_s['async']:.1f} vs sync {tok_s['sync']:.1f} tok/s)")
    row = {
        "load_rows": load_rows,
        "tok_per_s_sync": tok_s["sync"],
        "tok_per_s_async": tok_s["async"],
        "async_vs_sync": ratio,
        "device_busy_fraction_sync": busy["sync"],
        "device_busy_fraction_async": busy["async"],
    }
    print(f"serve,async_tok_s={tok_s['async']:.1f},"
          f"sync_tok_s={tok_s['sync']:.1f},"
          f"ratio={ratio:.2f},"
          f"busy_async={busy['async']:.2f},busy_sync={busy['sync']:.2f}")
    print("serve,async_equal=1")
    return row


_SHARDED_PROG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_reduced
from repro.models import Model
from repro.serve import PagedServeEngine, Request
from repro.launch.mesh import make_mesh

cfg = get_reduced("opt_6_7b").replace(remat=False, dtype="float32",
                                      n_heads=8, n_kv_heads=4, head_dim=16)
model = Model(cfg)
params = jax.tree_util.tree_map(
    lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
    model.init(jax.random.PRNGKey(0)))

def requests(n, max_new):
    rng = np.random.default_rng(2)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               (int(rng.integers(4, 20)),)),
                    max_new_tokens=max_new) for i in range(n)]

N, MAX_NEW = %(requests)d, %(max_new)d
rows, toks = [], {}
for label, mesh in (("single", None),
                    ("sharded", make_mesh((2, 4), ("data", "model")))):
    eng = PagedServeEngine(model, params, num_blocks=32, block_size=8,
                           max_batch=4, max_seq_len=128,
                           prefill_buckets=(16, 32), paged_kernel="fused",
                           mesh=mesh)
    t0 = time.time()
    done = eng.run(requests(N, MAX_NEW), max_ticks=400)
    dt = time.time() - t0
    eng.pool.check()
    toks[label] = {r.uid: r.out_tokens for r in done}
    s = eng.metrics.summary()
    stack = eng.cache.get("layers") or eng.cache.get("prefix") \
        or eng.cache["scan"]
    rows.append({
        "engine": label, "decode_path": eng.decode_path,
        "requests_done": len(done),
        "tokens": s["counters"]["tokens_out"],
        "tok_per_s": s["counters"]["tokens_out"] / dt if dt > 0 else 0.0,
        "per_token_ms_p50": s["per_token_s"]["p50"] * 1e3,
        "occupancy_peak": s["occupancy"]["peak"],
        "kv_pool_spec": str(getattr(stack[0]["self"]["k"].sharding,
                                    "spec", "single-device")),
    })
print(json.dumps({"rows": rows,
                  "equal": toks["single"] == toks["sharded"]}))
"""


def bench_sharded(*, requests=4, max_new=6):
    """Sharded (2x4 TP/DP mesh, 8 fake CPU devices) vs single-device
    paged serving: token-for-token equality plus throughput/latency of
    both, in a subprocess (the fake device count must be pinned before
    jax initializes, so this cannot run in-process).

    CPU wall-times favor the single-device engine (8-way fake-device
    SPMD on one host is pure overhead); the section pins the mesh
    engine's CORRECTNESS and reports the KV-pool placement the TP win
    comes from on real hardware."""
    import subprocess
    import sys
    prog = _SHARDED_PROG % {"requests": requests, "max_new": max_new}
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in (env.get("PYTHONPATH"), "src") if p])
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=570, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for row in out["rows"]:
        print(f"serve,sharded={row['engine']},path={row['decode_path']},"
              f"tok_s={row['tok_per_s']:.1f},"
              f"per_token_ms_p50={row['per_token_ms_p50']:.1f},"
              f"kv_pool_spec={row['kv_pool_spec']}")
    assert out["equal"], "sharded decode diverged from single-device"
    sharded = next(r for r in out["rows"] if r["engine"] == "sharded")
    assert "model" in sharded["kv_pool_spec"], sharded
    print("serve,sharded_equal=1")
    return out["rows"]


def _scalar(value, direction, rel_tol, **bounds):
    s = {"value": float(value), "direction": direction, "rel_tol": rel_tol}
    s.update(bounds)
    return s


def write_bench_json(path, rows, kernel_rows, int8_rows, prefix_rows,
                     trace_row, async_row, bits):
    """Schema-versioned tracked-scalar file for the perf-trajectory gate
    (``benchmarks.compare_trajectory``).  Wall-clock scalars get loose
    tolerances (CI-runner variance is large on shared boxes); scalars
    that are deterministic functions of the workload (KV bytes/token,
    prefix hit rate, probe chunk count) are pinned tight."""
    dense = next(r for r in rows if r["backend"] == "dense")
    bcq = next(r for r in rows if r["backend"].startswith("bcq"))
    fused = next(r for r in kernel_rows if r["paged_kernel"] == "fused")
    fused8 = next(r for r in int8_rows if r["paged_kernel"] == "fused")
    pfx_on = next(r for r in prefix_rows if r["prefix_cache"] == "on")
    scalars = {
        # wall-clock: gate only order-of-magnitude collapses
        "tokens_per_s_dense": _scalar(dense["tok_per_s"], "higher", 0.8),
        f"tokens_per_s_bcq{bits}": _scalar(bcq["tok_per_s"], "higher", 0.8),
        "ttft_ms_p50_dense": _scalar(dense["ttft_ms_p50"], "lower", 1.5),
        "ttft_ms_p95_dense": _scalar(dense["ttft_ms_p95"], "lower", 1.5),
        # deterministic analytic/counting scalars: pinned (near-)exactly
        "kv_bytes_per_token_fused":
            _scalar(fused["kv_bytes_per_token_fused"], "lower", 0.05),
        "kv_bytes_per_token_gathered":
            _scalar(fused["kv_bytes_per_token_gathered"], "lower", 0.05),
        # int8-KV pools: fused decode/prefill must keep beating the
        # gathered view even with the scale rows riding the DMA
        "kv_bytes_per_token_fused_int8":
            _scalar(fused8["kv_bytes_per_token_fused"], "lower", 0.05),
        "kv_bytes_per_token_gathered_int8":
            _scalar(fused8["kv_bytes_per_token_gathered"], "lower", 0.05),
        # chunked prefill: the fused flash kernel reads the pool through
        # the block table instead of materializing the gathered view
        "prefill_kv_bytes_per_token_fused":
            _scalar(fused["kv_bytes_per_prefill_token_fused"],
                    "lower", 0.05),
        "prefill_kv_bytes_per_token_gathered":
            _scalar(fused["kv_bytes_per_prefill_token_gathered"],
                    "lower", 0.05),
        "prefix_hit_rate":
            _scalar(pfx_on["prefix_hit_rate"], "higher", 0.0),
        "prefix_blocks_saved":
            _scalar(pfx_on["blocks_saved"], "higher", 0.0),
        "effective_capacity_peak":
            _scalar(pfx_on["effective_capacity_peak"], "higher", 0.05),
        "probe_prefill_chunks":
            _scalar(pfx_on["probe_prefill_chunks"], "lower", 0.0),
        # trace overhead: relative drift is noise, the absolute 5%
        # budget is the contract
        "trace_overhead_pct":
            _scalar(trace_row["trace_overhead_pct"], "lower", 10.0,
                    abs_max=5.0),
        # async engine: wall-clock throughput gated loosely, the >= 95%
        # -of-sync ratio gated absolutely (the bench itself also asserts
        # it, so a regression fails twice)
        "async_tokens_per_s":
            _scalar(async_row["tok_per_s_async"], "higher", 0.8),
        "async_vs_sync_ratio":
            _scalar(async_row["async_vs_sync"], "higher", 0.5,
                    abs_min=0.95),
        "device_busy_fraction":
            _scalar(async_row["device_busy_fraction_async"], "higher", 0.5),
    }
    data = {"schema_version": 1, "bench": "serve", "scalars": scalars,
            "meta": {"source": "benchmarks.bench_serve",
                     "jax": jax.__version__,
                     "trace_events": trace_row["trace_events"]}}
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"serve,bench_json={path}")
    return data


def run(json_path: str = "", requests: int = 6, max_new: int = 8,
        bits: int = 3, sharded: bool = False, bench_json: str = "",
        trace_out: str = ""):
    common.header("Paged serving bench (CPU smoke): dense vs BCQ backends")
    cfg = get_reduced("opt_6_7b").replace(max_seq_len=256, remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = [bench_backend("dense", model, params, cfg,
                          requests=requests, max_new=max_new)]
    spec = QuantSpec(bits=bits, group_size=32, iters=2, backend="bcq_xla")
    qparams, man_bcq = quantize_model(params, spec, model.axes())
    model_q = Model(cfg.replace(quant=spec))
    rows.append(bench_backend(f"bcq{bits}", model_q, qparams, cfg,
                              requests=requests, max_new=max_new))
    # ternary: the 1.58-bit plane bundle on the same engine.  The byte
    # comparison is against a generic 2-bit BCQ manifest at the same
    # group size — the ternary layout must be strictly smaller
    spec_t = QuantSpec(format="ternary", group_size=32, backend="bcq_xla")
    qparams_t, man_t = quantize_model(params, spec_t, model.axes())
    rows.append(bench_backend("ternary", Model(cfg.replace(quant=spec_t)),
                              qparams_t, cfg, requests=requests,
                              max_new=max_new))
    man_bcq2 = quantize_model(params,
                              QuantSpec(bits=2, group_size=32, iters=2),
                              model.axes())[1]
    print(f"serve,ternary_quant_bytes={man_t.quant_bytes},"
          f"bcq2_quant_bytes={man_bcq2.quant_bytes},"
          f"ternary_avg_effective_bits={man_t.avg_effective_bits:.3f}")
    assert man_t.quant_bytes < man_bcq2.quant_bytes, \
        (man_t.quant_bytes, man_bcq2.quant_bytes)
    assert man_t.avg_effective_bits < man_bcq2.avg_effective_bits
    # all backends must serve the full stream through the paged engine
    assert all(r["requests_done"] == requests for r in rows)
    common.header("Paged kernels: fused (interpret) vs gathered view — "
                  "decode + chunked prefill")
    kernel_rows = bench_paged_kernel(model, params, cfg,
                                     requests=min(requests, 4),
                                     max_new=max_new)
    common.header("Paged kernels, int8-KV pools: fused vs gathered")
    int8_rows = bench_paged_kernel_int8(model, params, cfg,
                                        requests=min(requests, 4),
                                        max_new=max_new)
    common.header("Prefix cache: shared-prefix stream, cache on vs off")
    prefix_rows = bench_prefix_cache(model, params, cfg, max_new=max_new)
    common.header("Trace overhead: event trace on vs off, warm engine")
    # floor the decode length: timed runs must be long enough that box
    # noise doesn't swamp the <= 5% overhead budget
    trace_row = bench_trace_overhead(model, params, cfg,
                                     requests=min(requests, 4),
                                     max_new=max(max_new, 24),
                                     trace_out=trace_out)
    common.header("Async saturation: open-loop Poisson load, sync vs async")
    async_row = bench_async_saturation(model, params, cfg,
                                       requests=max(requests, 8),
                                       max_new=max_new)
    sharded_rows = []
    if sharded:
        common.header("Sharded (2x4 mesh, 8 fake devices) vs single device")
        sharded_rows = bench_sharded(requests=min(requests, 4),
                                     max_new=max_new)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"rows": rows, "paged_kernel_rows": kernel_rows,
                       "paged_kernel_int8_rows": int8_rows,
                       "prefix_rows": prefix_rows,
                       "trace_row": trace_row,
                       "async_row": async_row,
                       "sharded_rows": sharded_rows},
                      f, indent=2, sort_keys=True)
        print(f"serve,metrics_json={json_path}")
    if bench_json:
        write_bench_json(bench_json, rows, kernel_rows, int8_rows,
                         prefix_rows, trace_row, async_row, bits)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", help="write per-backend metrics")
    ap.add_argument("--bench-json", default="",
                    help="write tracked scalars for the perf-trajectory "
                         "gate (compare with benchmarks.compare_trajectory)")
    ap.add_argument("--trace-out", default="",
                    help="save the overhead section's Chrome trace here")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--bits", type=int, default=3)
    ap.add_argument("--sharded", action="store_true",
                    help="add the sharded-vs-single section (spawns an "
                         "8-fake-device subprocess; ~1 min extra)")
    args = ap.parse_args()
    run(json_path=args.json, requests=args.requests, max_new=args.max_new,
        bits=args.bits, sharded=args.sharded, bench_json=args.bench_json,
        trace_out=args.trace_out)


if __name__ == "__main__":
    main()
