from repro.quantize.ptq import (quantize_model, abstract_quantized_params,
                                collect_linears, QUANT_KEYS)

__all__ = ["quantize_model", "abstract_quantized_params", "collect_linears",
           "QUANT_KEYS"]
