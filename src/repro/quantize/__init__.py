from repro.quantize.ptq import (quantize_model, abstract_quantized_params,
                                collect_linears, QUANT_KEYS)

__all__ = ["quantize_model", "abstract_quantized_params", "collect_linears",
           "QUANT_KEYS"]

# NOTE: ``repro.quantize.quantize_model`` is the legacy kwargs surface
# (deprecated, kept one release).  New code should use the declarative
# API: ``from repro.quant import QuantSpec, quantize_model``.
