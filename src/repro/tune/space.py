"""Config space for the Pallas kernels (FIGLUT §III-C/D execution shapes).

A :class:`KernelConfig` fixes everything the launcher may vary per call
site: the (block_b, block_m, block_n) tile geometry plus, for the LUT
kernel, the RAC read mode (``select`` mux sweep vs MXU ``onehot``
contraction vs ``gather``) and whether the half table (hFFLUT) is built.

``candidate_configs`` enumerates the space *already clamped to a concrete
(B, M, N) problem* and de-duplicated — on a small layer most of the grid
collapses onto a handful of distinct launches, so the tuner never times
the same launch twice.  ``heuristic_config`` is the deterministic
fallback used when no tuned entry exists (tuning disabled, cold cache,
or interpret mode off-device): it reproduces the seed defaults clamped
to the shape, so untuned behavior is exactly the pre-tuner behavior.

TPU tiling constraints (pallas_guide: f32 min tile 8x128, lane dim 128)
shape the grid: block_n candidates are multiples of 128, block_m/block_b
multiples of 8.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

KERNELS = ("lut_gemm", "bcq_matmul", "ternary_matmul", "paged_attention",
           "paged_prefill")

# kernels whose RAC read mode is a live config axis (ternary_matmul is
# always half-table — the sign decode IS the datapath — so only the
# read mode varies for it)
LUT_KERNELS = ("lut_gemm", "ternary_matmul")

# the paged-attention kernel family shares one config axis (the kv-head
# tile); "paged_prefill" is a distinct kernel NAME so its cache entries
# can never collide with decode's (and stale pre-prefill caches miss)
PAGED_KERNELS = ("paged_attention", "paged_prefill")

READ_MODES = ("onehot", "select", "gather")

# enumeration grids (pre-clamp); heuristic defaults are the seed constants
_BLOCK_B = (8, 16, 32)
_BLOCK_M = (64, 128, 256)
_BLOCK_N = (256, 512, 1024)
_BLOCK_H = (0, 1, 2, 4, 8)        # paged_attention kv-head tile (0 = all)


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One launch configuration.  ``read_mode``/``half_lut`` only affect
    the ``lut_gemm`` kernel and ``block_h`` (kv heads per grid step)
    only the ``paged_attention`` kernel; fields irrelevant to a kernel
    are normalized to their defaults so configs compare/dedupe cleanly."""

    block_b: int = 8
    block_m: int = 128
    block_n: int = 512
    read_mode: str = "onehot"
    half_lut: bool = True
    block_h: int = 0                 # paged_attention: kv-head tile (0 = all)

    def to_kwargs(self, kernel: str) -> dict:
        """kwargs for the kernel's public op wrapper."""
        if kernel in PAGED_KERNELS:
            return dict(block_h=self.block_h)
        kw = dict(block_b=self.block_b, block_m=self.block_m,
                  block_n=self.block_n)
        if kernel == "lut_gemm":
            kw.update(read_mode=self.read_mode, half_lut=self.half_lut)
        elif kernel == "ternary_matmul":
            kw.update(read_mode=self.read_mode)
        return kw

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


def divisor_clamp(block_h: int, hkv: int) -> int:
    """Largest divisor of ``hkv`` that is <= block_h (0 -> all heads).

    The single clamp rule for the paged_attention kv-head tile — used by
    both ``clamp_config`` (dispatch side) and the op wrapper, so a tuned
    cache entry always describes the launch actually run."""
    if block_h <= 0 or block_h >= hkv:
        return hkv
    while hkv % block_h:
        block_h -= 1
    return max(block_h, 1)


def clamp_config(cfg: KernelConfig, kernel: str, *, b: int, m: int, n: int,
                 group_size: int) -> KernelConfig:
    """Snap a config onto a concrete problem so the tiled kernel's
    divisibility asserts hold (mirrors the padding math in ops.py).

    For ``paged_attention`` the problem dims are remapped: ``m`` is the
    kv-head count, ``n`` the per-sequence KV capacity and ``group_size``
    the pool block size; the only live axis is ``block_h`` (clamped to a
    divisor of the head count) and the GEMM tile fields are normalized
    so configs dedupe.  ``paged_prefill`` shares the same remapping."""
    if kernel in PAGED_KERNELS:
        return KernelConfig(block_h=divisor_clamp(cfg.block_h, max(m, 1)))
    n_pad = _round_up(max(n, 1), group_size)
    block_n = _round_up(min(cfg.block_n, n_pad), group_size)
    block_m = _round_up(min(cfg.block_m, _round_up(max(m, 1), 8)), 8)
    block_b = _round_up(min(cfg.block_b, _round_up(max(b, 1), 8)), 8)
    read_mode = cfg.read_mode if kernel in LUT_KERNELS else "onehot"
    half_lut = cfg.half_lut if kernel == "lut_gemm" else True
    return KernelConfig(block_b=block_b, block_m=block_m, block_n=block_n,
                        read_mode=read_mode, half_lut=half_lut)


def heuristic_config(kernel: str, *, b: int, m: int, n: int,
                     mu: int = 4, group_size: int = 128) -> KernelConfig:
    """Deterministic no-measurement fallback.

    Reproduces the seed defaults (8, 128, 512, onehot, hFFLUT) with a
    mild batch scaling — decode (b <= 8) keeps the minimum f32 sublane
    tile, larger batches grow block_b so the LUT build amortizes over
    more rows per launch.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; known: {KERNELS}")
    if kernel in PAGED_KERNELS:
        # decode head counts are small: all kv heads per grid step keeps
        # the grid minimal and the q tile resident (prefill inherits the
        # same default — the chunk dim rides inside the block)
        return clamp_config(KernelConfig(block_h=0), kernel, b=b, m=m, n=n,
                            group_size=group_size)
    block_b = 8 if b <= 8 else (16 if b <= 16 else 32)
    base = KernelConfig(block_b=block_b, block_m=128, block_n=512,
                        read_mode="onehot", half_lut=True)
    return clamp_config(base, kernel, b=b, m=m, n=n, group_size=group_size)


def candidate_configs(kernel: str, *, b: int, m: int, n: int, mu: int = 4,
                      group_size: int = 128,
                      max_candidates: int = 0) -> list:
    """Enumerate the clamped, de-duplicated config space for one problem.

    The heuristic config is always candidate 0, so a tuner that selects
    the argmin over this list can never do worse than the untuned path.
    ``read_mode``/``half_lut`` vary fastest so a truncated prefix
    (``max_candidates``) still spans the execution-mode axis of the space.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; known: {KERNELS}")
    out = [heuristic_config(kernel, b=b, m=m, n=n, mu=mu,
                            group_size=group_size)]
    seen = {out[0]}
    if kernel in PAGED_KERNELS:
        for bh in _BLOCK_H:
            cfg = clamp_config(KernelConfig(block_h=bh), kernel,
                               b=b, m=m, n=n, group_size=group_size)
            if cfg not in seen:
                seen.add(cfg)
                out.append(cfg)
        if max_candidates and len(out) > max_candidates:
            out = out[:max_candidates]
        return out
    if kernel in LUT_KERNELS and group_size % mu:
        raise ValueError(f"group_size {group_size} not divisible by mu {mu}")
    modes = READ_MODES if kernel in LUT_KERNELS else ("onehot",)
    halves = (True, False) if kernel == "lut_gemm" else (True,)

    for bb, bm, bn, rm, hl in itertools.product(
            _BLOCK_B, _BLOCK_M, _BLOCK_N, modes, halves):
        cfg = clamp_config(
            KernelConfig(bb, bm, bn, rm, hl), kernel,
            b=b, m=m, n=n, group_size=group_size)
        if cfg not in seen:
            seen.add(cfg)
            out.append(cfg)
    if max_candidates and len(out) > max_candidates:
        out = out[:max_candidates]
    return out
