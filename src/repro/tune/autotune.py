"""Measurement-driven tuner for the Pallas kernels.

For one (kernel, activation, BCQWeight) problem the tuner enumerates the
clamped candidate space, runs every candidate once against the kernel's
reference oracle (``lut_gemm`` candidates must match ``ref.lut_ref``,
``bcq_matmul`` candidates ``ref.bcq_matmul_ref``) and only then times the
survivors with the median-of-k harness.  A config that crashes or
mis-computes is recorded but can never win.  Candidate 0 is always the
deterministic heuristic, so ``best_time <= default_time`` by
construction — tuning can only help.

Winners persist in the JSON :class:`~repro.tune.cache.TuneCache`;
``pretune_params`` walks a quantized params tree, collects the distinct
GEMM problems actually served, and tunes each once per batch bucket —
the warm-up path the serve engine and ``python -m repro.tune`` share.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bcq import BCQWeight, from_uniform

from . import cache as cache_mod
from .measure import measure
from .space import KernelConfig, candidate_configs


@dataclasses.dataclass
class Timing:
    config: KernelConfig
    seconds: float          # inf when invalid
    ok: bool
    error: str = ""


@dataclasses.dataclass
class TuneResult:
    kernel: str
    key: str
    best: KernelConfig
    best_time: float
    default_time: float
    timings: list

    @property
    def speedup(self) -> float:
        """Tuned-vs-heuristic speedup (>= 1.0 by construction)."""
        return self.default_time / max(self.best_time, 1e-12)


def _kernel_fns(kernel: str):
    """(op, oracle) for a kernel — lazy so importing repro.tune stays
    cheap and cycle-free (the op wrappers import repro.tune.dispatch)."""
    if kernel == "lut_gemm":
        from repro.kernels.lut_gemm import lut_gemm, ref
        return lut_gemm, ref.lut_ref
    if kernel == "bcq_matmul":
        from repro.kernels.bcq_matmul import bcq_matmul, ref
        return bcq_matmul, ref.bcq_matmul_ref
    if kernel == "ternary_matmul":
        from repro.kernels.ternary_matmul import ternary_matmul, ref
        return ternary_matmul, ref.ternary_ref
    raise ValueError(f"unknown kernel {kernel!r}")


def _default_interpret() -> bool:
    from repro.core import lut_gemm as core_lg
    return core_lg.INTERPRET


def tune(kernel: str, x: jax.Array, w: BCQWeight, *, mu: int = 4,
         reps: int = 5, warmup: int = 2, max_candidates: int = 0,
         atol: float = 1e-3, interpret: Optional[bool] = None,
         cache: Optional[cache_mod.TuneCache] = None,
         verbose: bool = False) -> TuneResult:
    """Tune one problem; returns the winner (cached if ``cache`` given)."""
    interpret = _default_interpret() if interpret is None else interpret
    op, oracle = _kernel_fns(kernel)

    x2 = x.reshape(-1, x.shape[-1])
    b, m, nn = x2.shape[0], w.out_features, w.in_features
    # mu only affects the LUT-reading kernels; key it as 0 for bcq_matmul
    # so the cache key matches what the op wrapper's dispatch looks up.
    lut_like = kernel in ("lut_gemm", "ternary_matmul")
    key_mu = mu if lut_like else 0
    key = cache_mod.cache_key(kernel, b=b, m=m, n=nn, dtype=x2.dtype,
                              mu=key_mu, group_size=w.group_size,
                              interpret=interpret)
    cands = candidate_configs(kernel, b=b, m=m, n=nn, mu=mu,
                              group_size=w.group_size,
                              max_candidates=max_candidates)
    if lut_like:
        want = np.asarray(oracle(x2, w, mu=mu, out_dtype=jnp.float32))
    else:
        want = np.asarray(oracle(x2, w, out_dtype=jnp.float32))
    scale = float(np.abs(want).max()) + 1e-6

    timings = []
    for cfg in cands:
        kw = cfg.to_kwargs(kernel)
        if lut_like:
            kw["mu"] = mu
        run = lambda kw=kw: op(x2, w, interpret=interpret,
                               out_dtype=jnp.float32, **kw)
        try:
            got = np.asarray(jax.block_until_ready(run()))
            err = float(np.abs(got - want).max()) / scale
            if not np.isfinite(err) or err > atol:
                raise AssertionError(f"max rel err {err:.2e} > {atol:.0e}")
            secs = measure(run, n=reps, warmup=warmup)
            timings.append(Timing(cfg, secs, True))
        except Exception as e:                    # invalid launch: record, skip
            timings.append(Timing(cfg, float("inf"), False,
                                  f"{type(e).__name__}: {e}"))
        if verbose:
            t = timings[-1]
            state = f"{t.seconds * 1e3:9.3f} ms" if t.ok else f"INVALID ({t.error[:60]})"
            print(f"[tune] {kernel} {cfg.to_kwargs(kernel)} -> {state}")

    valid = [t for t in timings if t.ok]
    if not valid:
        raise RuntimeError(
            f"no valid config for {kernel} on b={b} m={m} n={nn} "
            f"(first error: {timings[0].error})")
    best = min(valid, key=lambda t: t.seconds)
    default_time = timings[0].seconds if timings[0].ok else best.seconds
    result = TuneResult(kernel=kernel, key=key, best=best.config,
                        best_time=best.seconds, default_time=default_time,
                        timings=timings)
    if cache is not None:
        cache.store(key, best.config, time_s=best.seconds,
                    default_time_s=default_time,
                    speedup=round(result.speedup, 4),
                    shape=[b, m, nn], n_candidates=len(cands))
    return result


# ---------------------------------------------------------------------------
# shape-level helpers (synthesize operands; used by CLI / serve pretune)
# ---------------------------------------------------------------------------


def tune_shape(kernel: str, *, b: int, m: int, n: int, bits: int = 4,
               group_size: int = 128, mu: int = 4, dtype=jnp.float32,
               seed: int = 0, **kw) -> TuneResult:
    """Tune a synthetic (b, m, n) problem — tuning depends on shapes and
    dtypes, not weight values, so RTN-quantized gaussian weights stand in
    for the real layer (ternary-quantized for the ternary kernel)."""
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(b, n)).astype(np.float32), dtype=dtype)
    if kernel == "ternary_matmul":
        from repro.quant.formats import quantize_ternary    # lazy: registry
        wq = quantize_ternary(W, group_size=group_size)
    else:
        wq = from_uniform(W, bits=bits, group_size=group_size)
    return tune(kernel, x, wq, mu=mu, **kw)


def collect_bcq_specs(params) -> list:
    """Distinct (out_features, in_features, bits, group_size, kind)
    across every plane-bundle leaf (scan-stacked leaves count once — the
    per-layer GEMM problem is identical)."""
    from repro.quant.ptq import _walk          # shared pytree walker
    specs = []
    for _, leaf in _walk(params):
        if isinstance(leaf, BCQWeight):
            spec = (leaf.out_features, leaf.in_features,
                    int(leaf.packed.shape[-3]), leaf.group_size, leaf.kind)
            if spec not in specs:
                specs.append(spec)
    return specs


def pretune_params(params, *, kernels: Sequence[str] = ("lut_gemm",),
                   batch_sizes: Sequence[int] = (1, 8), mu: int = 4,
                   dtype=jnp.float32, cache: Optional[cache_mod.TuneCache] = None,
                   save: bool = True, verbose: bool = False,
                   **kw) -> list:
    """Tune every distinct GEMM problem a quantized params tree serves.

    Returns the list of :class:`TuneResult`; persists winners into
    ``cache`` (the process default when None) and saves the JSON file.
    """
    cache = cache_mod.default_cache() if cache is None else cache
    specs = collect_bcq_specs(params)
    results = []
    done = set()
    for m, n, bits, group_size, kind in specs:
        # ternary layers serve through the dedicated kernel only; bcq
        # layers tune whatever the caller asked for
        use_kernels = ("ternary_matmul",) if kind == "ternary" else kernels
        for b in batch_sizes:
            for kernel in use_kernels:
                # batch sizes sharing a pow2 bucket share a cache key
                tag = (kernel, m, n, bits, group_size,
                       cache_mod.bucket_batch(b))
                if tag in done:
                    continue
                done.add(tag)
                res = tune_shape(kernel, b=b, m=m, n=n, bits=bits,
                                 group_size=group_size, mu=mu, dtype=dtype,
                                 cache=cache, verbose=verbose, **kw)
                results.append(res)
                if verbose:
                    print(f"[pretune] {res.key}: best {res.best_time*1e3:.3f} ms "
                          f"(x{res.speedup:.2f} vs default) {res.best.to_kwargs(kernel)}")
    if save and results:
        cache.save()
    return results
