"""Pre-tune the Pallas kernel configs for a model's layer shapes.

    PYTHONPATH=src python -m repro.tune --arch opt_6_7b --bits 4 \
        --batch 1 8 --kernels lut_gemm bcq_matmul

Collects every distinct (out, in) GEMM problem of the arch (abstractly —
no weights are allocated, so ``--full`` works for the 236B configs too),
tunes each per batch bucket, prints a CSV summary and persists winners to
the JSON cache (``--cache`` / ``REPRO_TUNE_CACHE``).  ``--shapes BxMxN``
tunes explicit problems instead; ``--show`` dumps the current cache.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _model_shapes(arch: str, full: bool):
    """(distinct (rows, cols) of every quantizable linear, activation
    dtype name) for an arch, via eval_shape — no weights allocated."""
    import jax
    from repro.configs import get_config, get_reduced
    from repro.models import Model
    from repro.quant.ptq import _axes_of, _is_quant_leaf, _lead_batch, _walk

    cfg = get_config(arch) if full else get_reduced(arch)
    model = Model(cfg)
    abstract = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    axes_tree = model.axes()
    shapes = []
    for path, leaf in _walk(abstract):
        axes = _axes_of(axes_tree, path)
        if not _is_quant_leaf(path, leaf, axes):
            continue
        nb = _lead_batch(axes, len(leaf.shape))
        rows = int(np.prod(leaf.shape[nb:-1]))
        cols = int(leaf.shape[-1])
        if (rows, cols) not in shapes:
            shapes.append((rows, cols))
    return shapes, cfg.dtype


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tune",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="opt_6_7b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) config's shapes")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=128)
    ap.add_argument("--mu", type=int, default=4)
    ap.add_argument("--batch", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--dtype", default=None,
                    choices=["float32", "bfloat16", "float16"],
                    help="activation dtype to tune for (cache keys embed "
                         "it; defaults to the arch's dtype, else float32)")
    ap.add_argument("--kernels", nargs="+", default=["lut_gemm", "bcq_matmul"],
                    choices=["lut_gemm", "bcq_matmul", "ternary_matmul"])
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--max-candidates", type=int, default=0,
                    help="cap the candidate set (0 = full space)")
    ap.add_argument("--cache", default=None, help="cache JSON path override")
    ap.add_argument("--shapes", nargs="+", default=[], metavar="BxMxN",
                    help="tune explicit problems instead of a model's")
    ap.add_argument("--show", action="store_true", help="dump the cache")
    ap.add_argument("--interpret", action="store_true",
                    help="force Pallas interpret mode (auto on non-TPU)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    from repro import tune as T

    cache = T.TuneCache(args.cache) if args.cache else T.default_cache()
    if args.show:
        print(json.dumps({"path": cache.path, "entries": cache.entries},
                         indent=1, sort_keys=True))
        return 0

    dtype_name = args.dtype
    if args.shapes:
        problems = []
        for s in args.shapes:
            try:
                b, m, n = (int(v) for v in s.lower().split("x"))
            except ValueError:
                ap.error(f"--shapes entry {s!r} must look like BxMxN, "
                         f"e.g. 8x256x512")
            problems.append((b, m, n))
    else:
        from repro.configs.base import ARCH_IDS
        arch = args.arch.replace("-", "_").replace(".", "_")
        if arch not in ARCH_IDS:
            ap.error(f"unknown --arch {args.arch!r}; known: {ARCH_IDS}")
        shapes, cfg_dtype = _model_shapes(arch, args.full)
        dtype_name = dtype_name or cfg_dtype      # serve-time activations
        print(f"# {args.arch}{' (full)' if args.full else ' (reduced)'}: "
              f"{len(shapes)} distinct linear shapes, dtype {dtype_name}")
        problems = [(b, m, n) for (m, n) in shapes for b in args.batch]

    import jax.numpy as jnp
    dtype = jnp.dtype(dtype_name or "float32")
    interpret = True if args.interpret else None
    print("kernel,b,m,n,candidates,default_ms,best_ms,speedup,config")
    for b, m, n in problems:
        for kernel in args.kernels:
            res = T.tune_shape(
                kernel, b=b, m=m, n=n, bits=args.bits,
                group_size=args.group_size, mu=args.mu, dtype=dtype,
                cache=cache, reps=args.reps, warmup=args.warmup,
                max_candidates=args.max_candidates, interpret=interpret,
                verbose=args.verbose)
            cfgkw = res.best.to_kwargs(kernel)
            print(f"{kernel},{b},{m},{n},{len(res.timings)},"
                  f"{res.default_time*1e3:.3f},{res.best_time*1e3:.3f},"
                  f"{res.speedup:.2f},\"{cfgkw}\"")
    path = cache.save()
    print(f"# saved {len(cache)} entries -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
