"""JSON persistence for tuned kernel configs.

One flat JSON file maps a deterministic string key

    <kernel>|b<batch-bucket>|m<M>|n<N>|<dtype>|mu<mu>|g<group>|<device>

to the winning :class:`~repro.tune.space.KernelConfig` plus measurement
metadata.  The batch dim is bucketed to the next power of two (floor 8 —
the f32 sublane tile) because serving batch sizes vary tick-to-tick as
slots drain; M/N are the weight's logical dims and stay exact.  The
device tag is JAX's ``device_kind`` with ``+interpret`` appended when the
kernel runs under the Pallas interpreter, so CPU-interpret tuning (CI)
never shadows real-TPU entries.

Writes are atomic (tmp file + rename) with sorted keys, so saving the
same cache twice yields byte-identical files — the round-trip
determinism the tuner tests pin.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Optional

import jax

from .space import KernelConfig

SCHEMA_VERSION = 1

_ENV_PATH = "REPRO_TUNE_CACHE"
_DEFAULT_PATH = os.path.join("~", ".cache", "repro", "tune_cache.json")


def bucket_batch(b: int) -> int:
    """Next power of two, floor 8 (the f32 sublane tile)."""
    return max(8, 1 << max(0, int(b) - 1).bit_length())


def device_tag(interpret: bool = False) -> str:
    kind = jax.devices()[0].device_kind.replace(" ", "_").replace("|", "_")
    return f"{kind}+interpret" if interpret else kind


def cache_key(kernel: str, *, b: int, m: int, n: int, dtype,
              mu: int, group_size: int, device: Optional[str] = None,
              interpret: bool = False) -> str:
    dev = device or device_tag(interpret)
    return (f"{kernel}|b{bucket_batch(b)}|m{int(m)}|n{int(n)}|{dtype}"
            f"|mu{int(mu)}|g{int(group_size)}|{dev}")


class TuneCache:
    """In-memory view over one JSON cache file."""

    def __init__(self, path: Optional[str] = None):
        self.path = os.path.expanduser(
            path or os.environ.get(_ENV_PATH) or _DEFAULT_PATH)
        self.entries: dict = {}
        self.load()

    # ------------------------------------------------------------------
    def load(self) -> "TuneCache":
        self.entries = {}
        try:
            with open(self.path) as f:
                blob = json.load(f)
            if isinstance(blob, dict) and blob.get("version") == SCHEMA_VERSION:
                self.entries = dict(blob.get("entries", {}))
        except (OSError, ValueError):
            pass                                  # cold or corrupt -> empty
        return self

    def save(self) -> str:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        blob = {"version": SCHEMA_VERSION,
                "entries": dict(sorted(self.entries.items()))}
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(self.path) or ".",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(blob, f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return self.path

    # ------------------------------------------------------------------
    def lookup(self, key: str) -> Optional[KernelConfig]:
        ent = self.entries.get(key)
        if not ent:
            return None
        try:
            return KernelConfig.from_dict(ent["config"])
        except (KeyError, TypeError):
            return None

    def store(self, key: str, cfg: KernelConfig, **meta) -> None:
        self.entries[key] = {"config": cfg.to_dict(), **meta}

    def __len__(self) -> int:
        return len(self.entries)


# ---------------------------------------------------------------------------
# process-wide default cache (what dispatch consults)
# ---------------------------------------------------------------------------

_DEFAULT: Optional[TuneCache] = None


def default_cache() -> TuneCache:
    global _DEFAULT
    if _DEFAULT is None or _DEFAULT.path != os.path.expanduser(
            os.environ.get(_ENV_PATH) or _DEFAULT_PATH):
        _DEFAULT = TuneCache()
    return _DEFAULT


def reset_default_cache() -> None:
    """Drop the process-wide cache (tests / after env changes)."""
    global _DEFAULT
    _DEFAULT = None
