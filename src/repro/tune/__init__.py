"""Kernel autotuning + dispatch for the FIGLUT Pallas kernels.

The execution shape of a LUT GEMM — tile geometry, hFFLUT vs full table,
mux-``select`` vs MXU-``onehot`` reads (paper §III-C/D) — swings
throughput several-fold per (B, M, N, mu, device) point.  This package
makes that choice a measured, cached decision instead of a hard-coded
constant:

  * :mod:`space`    — the config space + deterministic heuristic fallback
  * :mod:`measure`  — warmup + block_until_ready + median-of-k timing
  * :mod:`autotune` — validate-then-time tuner, shape/params pretuning
  * :mod:`cache`    — JSON persistence keyed by
                      (kernel, batch-bucket, M, N, dtype, mu, group, device)
  * :mod:`dispatch` — the single resolution point the op wrappers call

CLI: ``python -m repro.tune --arch opt_6_7b --bits 4`` pre-tunes every
distinct linear-layer problem of a model config and persists the winners
(``REPRO_TUNE_CACHE`` overrides the cache path; ``REPRO_TUNE=off``
forces the heuristic path, ``auto`` tunes on cache miss on-device).
"""
from .space import (KERNELS, PAGED_KERNELS, READ_MODES, KernelConfig,
                    candidate_configs, clamp_config, divisor_clamp,
                    heuristic_config)
from .cache import (TuneCache, bucket_batch, cache_key, default_cache,
                    device_tag, reset_default_cache)
from .measure import measure
from .dispatch import (kernel_config, kernel_supports,
                       kernel_unsupported_reason, tune_mode)
from .autotune import (TuneResult, Timing, collect_bcq_specs, pretune_params,
                       tune, tune_shape)

__all__ = [
    "KERNELS", "READ_MODES", "KernelConfig", "candidate_configs",
    "clamp_config", "divisor_clamp", "heuristic_config",
    "TuneCache", "bucket_batch", "cache_key", "default_cache", "device_tag",
    "reset_default_cache",
    "PAGED_KERNELS", "measure",
    "kernel_config", "kernel_supports", "kernel_unsupported_reason",
    "tune_mode",
    "TuneResult", "Timing", "collect_bcq_specs", "pretune_params", "tune",
    "tune_shape",
]
