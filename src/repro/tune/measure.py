"""Wall-clock measurement harness for kernel candidates.

``measure`` follows the standard JAX micro-bench discipline: warmup
iterations first (absorbing jit compilation and autotuner-invisible
first-touch costs), then k timed iterations each fenced with
``jax.block_until_ready`` so dispatch-async never under-reports, and the
*median* is returned — medians are robust to the occasional scheduler
hiccup that poisons means on shared CPU runners.
"""
from __future__ import annotations

import statistics
import time
from typing import Callable

import jax


def measure(fn: Callable, *, n: int = 5, warmup: int = 2) -> float:
    """Median seconds per call of ``fn`` over ``n`` fenced iterations."""
    if n < 1:
        raise ValueError("n must be >= 1")
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(statistics.median(times))
