"""The single dispatch point that picks a launch config for a kernel call.

Every ``lut_gemm`` / ``bcq_matmul`` call whose caller did not pin the
geometry lands here.  Resolution order:

  1. tuned entry in the JSON cache (keyed per cache.cache_key), unless
     tuning is disabled;
  2. with ``REPRO_TUNE=auto`` and a real device (not interpret mode):
     tune on miss with the live operands, persist, return the winner;
  3. deterministic heuristic (seed defaults clamped to the shape).

``REPRO_TUNE`` modes: ``on`` (default — cache then heuristic), ``off`` /
``0`` (heuristic only; fully deterministic, no file IO), ``auto``
(tune-on-miss).  Config resolution is shape-driven and happens eagerly in
the op wrappers — shapes are static even under jit tracing, so dispatch
adds no traced ops.
"""
from __future__ import annotations

import os
from typing import Optional

from . import cache as cache_mod
from .space import KernelConfig, clamp_config, heuristic_config

_ENV_MODE = "REPRO_TUNE"


def tune_mode() -> str:
    mode = os.environ.get(_ENV_MODE, "on").strip().lower()
    if mode in ("off", "0", "heuristic", "disable", "disabled"):
        return "off"
    if mode == "auto":
        return "auto"
    return "on"


def kernel_supports(kernel: str, *, m: int, n: int, group_size: int,
                    bits: Optional[int] = None, **caps) -> bool:
    """Capability probe: can this Pallas kernel launch the problem at all?

    For the GEMM kernels (callers: the quant backend registry,
    :mod:`repro.quant.backends`) ``(m, n)`` are the weight dims and the
    constraints mirror the op wrappers' padding math: plane packing is
    byte-granular along the input dim (group_size % 8 == 0, which also
    covers the LUT kernel's mu=4 sub-group split), and the bit-serial
    loop streams at most 8 planes.

    For ``paged_attention`` (caller: ``models.attention``'s paged decode
    router) the dims are remapped — ``m`` is the total q-head count,
    ``n`` the per-sequence KV capacity, ``group_size`` the pool block
    size — and ``caps`` carries the variant axes the kernel does not
    cover yet, which fall back to the gathered-XLA path:

      * ``n_kv_heads``  — q heads must group evenly over kv heads;
      * ``tp``          — model-axis shard count when the serve engine
        runs the kernel per-shard under ``shard_map``: both head counts
        must divide the mesh so every shard sees whole GQA groups (the
        probe then applies to the per-shard head counts — narrow-GQA
        models whose kv heads don't divide the mesh gather instead);
      * ``kv_dtype``    — float pools only (int8-KV needs the per-slot
        scale fold the gathered ``decode_attend`` already does);
      * ``window``      — sliding-window masking (ring caches are not
        paged, so this is only reachable through direct op calls);
      * ``latent``      — MLA absorbed decode stays on the gathered view.
    """
    from .space import KERNELS
    if kernel not in KERNELS:
        return False
    if kernel == "paged_attention":
        hkv = int(caps.get("n_kv_heads", m) or m)
        tp = int(caps.get("tp", 1) or 1)
        if tp < 1 or m % tp or hkv % tp:
            return False
        m, hkv = m // tp, hkv // tp            # per-shard head counts
        if m < 1 or hkv < 1 or m % hkv or n < 1 or group_size < 1:
            return False
        if caps.get("window", 0) or caps.get("latent", False):
            return False
        dt = caps.get("kv_dtype")
        if dt is not None:
            import jax.numpy as jnp
            if not jnp.issubdtype(jnp.dtype(dt), jnp.floating):
                return False
        return True
    if m < 1 or n < 1 or group_size < 8 or group_size % 8:
        return False
    if bits is not None and not 1 <= bits <= 8:
        return False
    return True


def kernel_config(kernel: str, *, b: int, m: int, n: int, dtype,
                  mu: int = 0, group_size: int = 128,
                  interpret: bool = False,
                  operands=None) -> KernelConfig:
    """Resolve the launch config for one (kernel, problem) point.

    b/m/n are the *logical* batch rows, out_features, in_features;
    ``operands=(x2, w)`` (2-D activations + BCQWeight) enables
    tune-on-miss under ``REPRO_TUNE=auto``.
    """
    from repro.obs.trace import record_kernel_config
    mode = tune_mode()
    if mode != "off":
        key = cache_mod.cache_key(kernel, b=b, m=m, n=n, dtype=dtype,
                                  mu=mu, group_size=group_size,
                                  interpret=interpret)
        hit = cache_mod.default_cache().lookup(key)
        if hit is not None:
            cfg = clamp_config(hit, kernel, b=b, m=m, n=n,
                               group_size=group_size)
            record_kernel_config(kernel, "cache", cfg, b=b, m=m, n=n)
            return cfg
        if mode == "auto" and not interpret and operands is not None:
            import jax
            if not any(isinstance(o, jax.core.Tracer) for o in operands):
                # concrete operands only — under jit tracing we fall through
                # to the heuristic (tune offline with `python -m repro.tune`)
                from . import autotune                # lazy: avoids cycle
                res = autotune.tune(kernel, *operands, mu=mu or 4,
                                    cache=cache_mod.default_cache(),
                                    interpret=interpret)
                cache_mod.default_cache().save()
                record_kernel_config(kernel, "tuned", res.best,
                                     b=b, m=m, n=n)
                return res.best
    cfg = heuristic_config(kernel, b=b, m=m, n=n, mu=mu or 4,
                           group_size=group_size)
    # traces show tuned-vs-fallback launch choices: "cache"/"tuned"
    # resolutions above vs this deterministic heuristic default
    record_kernel_config(kernel, "heuristic", cfg, b=b, m=m, n=n)
    return cfg
