"""The single dispatch point that picks a launch config for a kernel call.

Every ``lut_gemm`` / ``bcq_matmul`` call whose caller did not pin the
geometry lands here.  Resolution order:

  1. tuned entry in the JSON cache (keyed per cache.cache_key), unless
     tuning is disabled;
  2. with ``REPRO_TUNE=auto`` and a real device (not interpret mode):
     tune on miss with the live operands, persist, return the winner;
  3. deterministic heuristic (seed defaults clamped to the shape).

``REPRO_TUNE`` modes: ``on`` (default — cache then heuristic), ``off`` /
``0`` (heuristic only; fully deterministic, no file IO), ``auto``
(tune-on-miss).  Config resolution is shape-driven and happens eagerly in
the op wrappers — shapes are static even under jit tracing, so dispatch
adds no traced ops.
"""
from __future__ import annotations

import os
from typing import Optional

from . import cache as cache_mod
from .space import KernelConfig, clamp_config, heuristic_config

_ENV_MODE = "REPRO_TUNE"


def tune_mode() -> str:
    mode = os.environ.get(_ENV_MODE, "on").strip().lower()
    if mode in ("off", "0", "heuristic", "disable", "disabled"):
        return "off"
    if mode == "auto":
        return "auto"
    return "on"


def kernel_unsupported_reason(kernel: str, *, m: int, n: int,
                              group_size: int, bits: Optional[int] = None,
                              **caps) -> Optional[str]:
    """Capability probe: ``None`` when the Pallas kernel can launch the
    problem, else the SPECIFIC cap that failed (so callers, traces and
    tests can assert *why* a launch negotiated down to the gathered
    path instead of collapsing every reason into one boolean).

    For the GEMM kernels (callers: the quant backend registry,
    :mod:`repro.quant.backends`) ``(m, n)`` are the weight dims and the
    constraints mirror the op wrappers' padding math: plane packing is
    byte-granular along the input dim (group_size % 8 == 0, which also
    covers the LUT kernel's mu=4 sub-group split), and the bit-serial
    loop streams at most 8 planes.

    For the paged-attention family (``paged_attention`` decode,
    ``paged_prefill`` chunked prefill; caller: ``models.attention``'s
    routers) the dims are remapped — ``m`` is the total q-head count,
    ``n`` the per-sequence KV capacity, ``group_size`` the pool block
    size — and ``caps`` carries the variant axes:

      * ``n_kv_heads``  — q heads must group evenly over kv heads
        (reason ``"heads"``);
      * ``tp``          — model-axis shard count when the serve engine
        runs the kernel per-shard under ``shard_map``: both head counts
        must divide the mesh so every shard sees whole GQA groups
        (reason ``"tp"``);
      * ``kv_dtype``    — float AND int8 pools are covered (the int8
        kernels fold the per-slot scales in-kernel); anything else is
        reason ``"kv_dtype"``;
      * ``window``      — sliding-window masking still gathers (ring
        caches are not paged, so this is only reachable through direct
        op calls; reason ``"window"``);
      * ``latent``      — MLA absorbed decode is fused
        (``paged_attention``), but MLA *prefill* needs the
        decompressing ``kv_map_fn`` and stays gathered
        (``paged_prefill`` reason ``"latent"``).

    The GEMM kernels additionally accept a ``kind`` cap (the
    PlaneBundle layout kind): ``ternary_matmul`` only consumes
    ``kind="ternary"`` bundles, while ``lut_gemm``/``bcq_matmul`` read
    generic ``kind="bcq"`` planes (reason ``"kind"`` either way).

    Reasons: ``"unknown_kernel"``, ``"tp"``, ``"heads"``, ``"shape"``,
    ``"window"``, ``"kv_dtype"``, ``"latent"``, ``"group_size"``,
    ``"bits"``, ``"kind"``.  Every non-None return is also recorded on
    the active trace (``record_kernel_unsupported``).
    """
    reason = _unsupported_reason(kernel, m=m, n=n, group_size=group_size,
                                 bits=bits, **caps)
    if reason is not None:
        from repro.obs.trace import record_kernel_unsupported
        record_kernel_unsupported(kernel, reason, m=m, n=n)
    return reason


def _unsupported_reason(kernel: str, *, m: int, n: int, group_size: int,
                        bits: Optional[int] = None,
                        **caps) -> Optional[str]:
    from .space import KERNELS, PAGED_KERNELS
    if kernel not in KERNELS:
        return "unknown_kernel"
    if kernel in PAGED_KERNELS:
        hkv = int(caps.get("n_kv_heads", m) or m)
        tp = int(caps.get("tp", 1) or 1)
        if tp < 1 or m % tp or hkv % tp:
            return "tp"
        m, hkv = m // tp, hkv // tp            # per-shard head counts
        if m < 1 or hkv < 1 or m % hkv:
            return "heads"
        if n < 1 or group_size < 1:
            return "shape"
        if caps.get("window", 0):
            return "window"
        latent = bool(caps.get("latent", False))
        if latent and kernel == "paged_prefill":
            return "latent"                    # kv_map_fn decompression
        dt = caps.get("kv_dtype")
        if dt is not None and not latent:
            import jax.numpy as jnp
            dt = jnp.dtype(dt)
            if not (jnp.issubdtype(dt, jnp.floating) or dt == jnp.int8):
                return "kv_dtype"
        return None
    if m < 1 or n < 1:
        return "shape"
    if group_size < 8 or group_size % 8:
        return "group_size"
    if bits is not None and not 1 <= bits <= 8:
        return "bits"
    kind = caps.get("kind")
    if kind is not None:
        if kernel == "ternary_matmul" and kind != "ternary":
            return "kind"
        if kernel != "ternary_matmul" and kind == "ternary":
            return "kind"
    return None


def kernel_supports(kernel: str, *, m: int, n: int, group_size: int,
                    bits: Optional[int] = None, **caps) -> bool:
    """Boolean view of :func:`kernel_unsupported_reason` (True == the
    kernel can launch this problem)."""
    return kernel_unsupported_reason(kernel, m=m, n=n,
                                     group_size=group_size, bits=bits,
                                     **caps) is None


def kernel_config(kernel: str, *, b: int, m: int, n: int, dtype,
                  mu: int = 0, group_size: int = 128,
                  interpret: bool = False,
                  operands=None) -> KernelConfig:
    """Resolve the launch config for one (kernel, problem) point.

    b/m/n are the *logical* batch rows, out_features, in_features;
    ``operands=(x2, w)`` (2-D activations + BCQWeight) enables
    tune-on-miss under ``REPRO_TUNE=auto``.
    """
    from repro.obs.trace import record_kernel_config
    mode = tune_mode()
    if mode != "off":
        key = cache_mod.cache_key(kernel, b=b, m=m, n=n, dtype=dtype,
                                  mu=mu, group_size=group_size,
                                  interpret=interpret)
        hit = cache_mod.default_cache().lookup(key)
        if hit is not None:
            cfg = clamp_config(hit, kernel, b=b, m=m, n=n,
                               group_size=group_size)
            record_kernel_config(kernel, "cache", cfg, b=b, m=m, n=n)
            return cfg
        if mode == "auto" and not interpret and operands is not None:
            import jax
            if not any(isinstance(o, jax.core.Tracer) for o in operands):
                # concrete operands only — under jit tracing we fall through
                # to the heuristic (tune offline with `python -m repro.tune`)
                from . import autotune                # lazy: avoids cycle
                res = autotune.tune(kernel, *operands, mu=mu or 4,
                                    cache=cache_mod.default_cache(),
                                    interpret=interpret)
                cache_mod.default_cache().save()
                record_kernel_config(kernel, "tuned", res.best,
                                     b=b, m=m, n=n)
                return res.best
    cfg = heuristic_config(kernel, b=b, m=m, n=n, mu=mu or 4,
                           group_size=group_size)
    # traces show tuned-vs-fallback launch choices: "cache"/"tuned"
    # resolutions above vs this deterministic heuristic default
    record_kernel_config(kernel, "heuristic", cfg, b=b, m=m, n=n)
    return cfg
