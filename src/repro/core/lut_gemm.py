"""Backend dispatch for executing BCQ-quantized linears.

Backends (all numerically equivalent up to FP reassociation; tested):

  * ``dense``      — dequantize to dense f32 and matmul (FPE baseline; the
                     "GPU engine" of Table IV).
  * ``bcq_xla``    — pure-XLA packed execution: unpack uint8 planes on the
                     fly, per-plane +-1 contraction scaled by alpha + offset
                     term.  This is the backend used by the *distributed*
                     model (pjit-traceable on any backend, incl. the CPU
                     dry-run): HLO sees q/16 of the dense weight bytes.
  * ``lut_pallas`` — the paper-faithful Pallas kernel (kernels/lut_gemm).
  * ``mxu_pallas`` — the beyond-paper dequant-in-VMEM kernel
                     (kernels/bcq_matmul).
  * ``ternary_pallas`` — the dedicated 1.58-bit kernel
                     (kernels/ternary_matmul); only consumes
                     ``kind="ternary"`` bundles.

The ``dense``/``bcq_xla`` paths are *kind-aware* through
``plane.dequantize``, so a ternary bundle executes correctly on every
XLA fallback; only the per-plane ``bcq_xla_planes`` contraction is
BCQ-specific.

The Pallas backends target TPU; on this CPU container they run
under ``interpret=True`` (set ``repro.core.lut_gemm.INTERPRET = True`` —
done automatically when no TPU is present).

Launch geometry for the Pallas backends (block sizes, LUT read mode,
hFFLUT) is resolved per call through :mod:`repro.tune` — tuned JSON-cache
entries when present (``python -m repro.tune`` pre-tunes a model's layer
shapes), deterministic heuristics otherwise.  Nothing in this module or
its callers hard-codes a block constant.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.bcq import BCQWeight, dequantize, unpack_planes

Backend = Literal["dense", "bcq_xla", "lut_pallas", "mxu_pallas",
                  "ternary_pallas"]

# interpret=True when running on CPU (kernel tests / examples); the dry-run
# and production configs use bcq_xla for traced code anyway.
INTERPRET = jax.default_backend() != "tpu"


def bcq_xla_matmul(x: jax.Array, w: BCQWeight, out_dtype=None) -> jax.Array:
    """Pure-XLA packed BCQ GEMM.

    Per plane i:  y_i[b, m] = sum_G alpha[i,m,G] * sum_{n in G} pm1[m,n] x[b,n]
    computed as a grouped contraction so alpha stays per-(row, group); offset
    folds into per-group activation sums.  XLA fuses unpack+scale into the
    matmul prologue; HBM-side weight bytes remain the packed uint8 planes.
    """
    out_dtype = out_dtype or x.dtype
    if w.kind != "bcq":
        raise ValueError(
            f"bcq_xla_matmul reads independent ±1 planes (kind='bcq'); "
            f"got kind={w.kind!r} — use the fused path or ternary_pallas")
    q, m, nb = w.packed.shape
    n_pad = nb * 8
    g = w.group_size
    n_groups = w.alpha.shape[-1]

    xf = x.astype(jnp.float32)
    if xf.shape[-1] != n_pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, n_pad - xf.shape[-1])])
    lead = xf.shape[:-1]
    xg = xf.reshape(-1, n_groups, g)                       # [B, G, g]

    pm1 = unpack_planes(w.packed, dtype=jnp.float32)       # [q, M, n_pad]
    pm1 = pm1.reshape(q, m, n_groups, g)
    # per-plane grouped partial sums: [q, B, M, G]
    part = jnp.einsum("bGn,qmGn->qbmG", xg, pm1,
                      preferred_element_type=jnp.float32)
    y = jnp.einsum("qbmG,qmG->bm", part, w.alpha,
                   preferred_element_type=jnp.float32)
    if w.z is not None:
        y = y + jnp.einsum("bG,mG->bm", xg.sum(-1), w.z,
                           preferred_element_type=jnp.float32)
    return y.reshape(*lead, m).astype(out_dtype)


def bcq_xla_matmul_fused(x: jax.Array, w: BCQWeight, out_dtype=None,
                         compute_dtype=jnp.bfloat16) -> jax.Array:
    """XLA packed BCQ GEMM, dequant-then-single-matmul formulation.

    The dense weight is reconstructed inside the jit scope in
    ``compute_dtype`` (bf16: 2 B/weight of traffic on a fusing backend —
    the per-plane form materializes 16 B/weight) and contracted with FP32
    accumulation.  The 0.56 B/weight packed traffic of the paper's engine
    needs the Pallas kernel (kernels/bcq_matmul), which streams packed
    planes HBM->VMEM and never writes the dense form to HBM.
    """
    out_dtype = out_dtype or x.dtype
    dense = dequantize(w, dtype=compute_dtype)             # fused by XLA
    y = jnp.einsum("...n,mn->...m", x.astype(compute_dtype), dense,
                   preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def bcq_apply(x: jax.Array, w: BCQWeight, backend: Backend = "bcq_xla",
              out_dtype=None) -> jax.Array:
    """Execute y = x @ dequant(w).T on the selected backend."""
    if backend == "dense":
        return bcq_xla_matmul_fused(x, w, out_dtype,
                                    compute_dtype=jnp.float32)
    if backend == "bcq_xla":
        return bcq_xla_matmul_fused(x, w, out_dtype)
    if backend == "bcq_xla_planes":
        return bcq_xla_matmul(x, w, out_dtype)
    if backend == "lut_pallas":
        from repro.kernels.lut_gemm import lut_gemm
        # block sizes / read mode resolved via repro.tune dispatch
        return lut_gemm(x, w, interpret=INTERPRET, out_dtype=out_dtype)
    if backend == "mxu_pallas":
        from repro.kernels.bcq_matmul import bcq_matmul
        return bcq_matmul(x, w, interpret=INTERPRET, out_dtype=out_dtype)
    if backend == "ternary_pallas":
        from repro.kernels.ternary_matmul import ternary_matmul
        return ternary_matmul(x, w, interpret=INTERPRET, out_dtype=out_dtype)
    raise ValueError(f"unknown backend {backend!r}")
