"""Sensitivity-based mixed-precision bit allocation (ShiftAddLLM-style).

The paper's Fig. 17 evaluates FIGLUT under *mixed-precision* BCQ (e.g.
average 2.4 bits): each layer gets its own bit-width chosen by sensitivity,
and the bit-serial engine executes whatever q each layer carries.

We implement the standard greedy marginal-gain allocator:

  1. for every weight matrix, measure BCQ reconstruction error at each
     candidate bit-width (output-MSE proxy: ||(W - W_q) . x_cal||^2 when a
     calibration batch is supplied, else Frobenius weight MSE);
  2. start every layer at min(bits); repeatedly upgrade the layer with the
     best error-reduction per additional stored bit until the parameter-
     weighted average bit budget is exhausted.

Returns {name: bits}; ``quantize_mixed`` applies it.  The launch path
reaches this through ``repro.quant``: a fractional ``QuantSpec.bits``
(``--bits 2.4``) makes :func:`repro.quant.api.plan_bits` call
``allocate_bits`` over every quantizable linear and the manifest reports
the achieved average.
"""
from __future__ import annotations

from typing import Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcq as bcq_mod


def _as_2d(w: jax.Array, max_rows: int = 0) -> jax.Array:
    """Flatten stacked leaves ([L, out, in] / [E, f, d]) to [rows, in] and
    optionally subsample rows with a deterministic stride — the
    sensitivity probe is a *ranking* signal, so a few hundred rows per
    layer suffice and keep fractional-bits allocation launch-fast."""
    w2 = w.reshape(-1, w.shape[-1]) if w.ndim != 2 else w
    if max_rows and w2.shape[0] > max_rows:
        stride = -(-w2.shape[0] // max_rows)
        w2 = w2[::stride][:max_rows]
    return w2


def layer_sensitivity(w: jax.Array, bits: float, group_size: int = 128,
                      x_cal: Optional[jax.Array] = None, iters: int = 3,
                      max_rows: int = 0,
                      quantizer: Optional[Callable] = None) -> float:
    """Quantization error of one layer at one bit-width.

    ``quantizer(w2d, bits=, group_size=, iters=) -> BCQWeight`` lets the
    probe measure the error of the format that will actually be applied
    (repro.quant passes the registered format's quantize); default BCQ.
    """
    qfn = quantizer or (lambda w2, **kw: bcq_mod.quantize(w2, **kw))
    w = _as_2d(jnp.asarray(w, jnp.float32), max_rows)
    wq = qfn(w, bits=bits, group_size=group_size, iters=iters)
    err = bcq_mod.dequantize(wq) - w
    if x_cal is not None:
        out = jnp.einsum("...n,mn->...m", x_cal.astype(jnp.float32), err)
        return float(jnp.mean(out * out))
    return float(jnp.mean(err * err))


def allocate_bits(weights: Mapping[str, jax.Array], target_avg_bits: float,
                  candidates: Sequence[float] = (2, 3, 4),
                  group_size: int = 128,
                  x_cal: Optional[Mapping[str, jax.Array]] = None,
                  sensitivity_fn: Callable = layer_sensitivity) -> dict:
    """Greedy marginal-gain mixed-precision allocation.

    target_avg_bits is parameter-weighted; returns {name: bits}.
    ``candidates`` may be fractional: ``1.585`` (log2 3) is the ternary
    sentinel, so e.g. ``--bits 1.58`` mixes ternary/2/3-bit layers and
    the budget is charged at each format's information rate.
    """
    candidates = sorted(candidates)
    names = list(weights)
    sizes = {k: int(np.prod(weights[k].shape)) for k in names}
    total = sum(sizes.values())

    err = {
        k: {b: sensitivity_fn(weights[k], b, group_size,
                              None if x_cal is None else x_cal.get(k))
            for b in candidates}
        for k in names
    }

    bits = {k: candidates[0] for k in names}
    budget = target_avg_bits * total

    def used() -> float:
        return sum(bits[k] * sizes[k] for k in names)

    while True:
        best, best_gain = None, 0.0
        for k in names:
            cur = bits[k]
            nxt = next((b for b in candidates if b > cur), None)
            if nxt is None:
                continue
            extra = (nxt - cur) * sizes[k]
            if used() + extra > budget + 1e-9:
                continue
            gain = (err[k][cur] - err[k][nxt]) / extra
            if gain > best_gain:
                best, best_gain = (k, nxt), gain
        if best is None:
            break
        bits[best[0]] = best[1]
    return bits


def quantize_mixed(weights: Mapping[str, jax.Array], bit_map: Mapping[str, int],
                   group_size: int = 128, iters: int = 5) -> dict:
    """Apply a mixed-precision plan; returns {name: BCQWeight}."""
    return {
        k: bcq_mod.quantize(w, bits=bit_map[k], group_size=group_size,
                            iters=iters)
        for k, w in weights.items()
    }


def average_bits(bit_map: Mapping[str, float],
                 weights: Mapping[str, jax.Array]) -> float:
    sizes = {k: int(np.prod(weights[k].shape)) for k in weights}
    total = sum(sizes.values())
    return sum(bit_map[k] * sizes[k] for k in weights) / total
