"""FIGLUT core: plane-native weight bundles, LUT-based FP-INT GEMM, energy model."""
from repro.core.bcq import (BCQWeight, quantize, from_uniform, dequantize,
                            pack_planes, unpack_planes, packed_nbytes)
from repro.core.lut_gemm import bcq_apply, bcq_xla_matmul, Backend
from repro.core.plane import KINDS, PlaneBundle, TERNARY_BITS
from repro.core.quantized_linear import linear_apply, quantize_linear

__all__ = [
    "BCQWeight", "PlaneBundle", "KINDS", "TERNARY_BITS", "quantize",
    "from_uniform", "dequantize", "pack_planes", "unpack_planes",
    "packed_nbytes", "bcq_apply", "bcq_xla_matmul", "Backend",
    "linear_apply", "quantize_linear",
]
