"""Plane-native weight layout — the single quantize→kernel handoff.

Every quantized format in the repo lowers into one container, the
:class:`PlaneBundle`: packed sign planes + per-group scale rows + layout
metadata, repacked into kernel-tile order once at quantize/admission
time (the FLUTE offline-restructure-then-fuse pattern).  Consumers —
the XLA reference paths, both generic Pallas kernels and the dedicated
ternary kernel, sharding, checkpoints, the manifest — all read this
layout instead of hand-rolling their own plane math.

Two *kinds* of bundle exist today:

``kind="bcq"``       generic binary-coding quantization (paper Eq. (3)):
                     ``packed`` holds q independent ±1 planes,
                     ``alpha`` one scale row per plane, ``z`` an offset
                     row.  RTN/OPTQ/greedy-BCQ all land here.

``kind="ternary"``   the 1.58-bit fast path: plane 0 is the *sign* bit,
                     plane 1 the *nonzero mask*; a single ``alpha`` row
                     carries the shared magnitude and there is no
                     offset (``z is None``).  w = alpha * sign * mask.
                     The identity  w = (alpha/2)(b1 + b2)  with
                     b1 = mask ? sign : +1, b2 = mask ? sign : -1 maps
                     it onto BCQ planes *bitwise in-kernel* (see
                     ``kernels/ternary_matmul``), so the stored bundle
                     keeps only 1 scale row and no z — strictly fewer
                     bytes than the generic 2-plane encoding.

Plane packing is uint8, LSB-first along the input dim (8 weights per
byte; bit value 1 encodes +1 / "nonzero").  Scale rows are per
(out_row, input_group) with ``group_size`` columns per group.

``tile_operands`` is the one place that pads a bundle + activation
batch out to kernel-launch geometry; the per-kernel ``ops.py`` wrappers
delegate here instead of re-deriving the layout at every call site.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "PlaneBundle",
    "KINDS",
    "TERNARY_BITS",
    "pack_planes",
    "unpack_planes",
    "dequantize",
    "tile_operands",
]

KINDS = ("bcq", "ternary")

# Planner sentinel for the ternary format's information rate (log2 3).
# ``core.mixed_precision`` treats any candidate below 2 as "ternary"
# and ``quant.api`` resolves it to the ternary format per layer.
TERNARY_BITS = 1.585


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PlaneBundle:
    """Plane-packed quantized weight tensor (pytree).

    Attributes:
      packed:   uint8[q, out, in//8]  bit-planes, 8 weights per byte
                (LSB-first within the byte along the input dim).  For
                ``kind="bcq"`` bit 1 encodes b=+1; for ``kind="ternary"``
                plane 0 is the sign bit (1 = +) and plane 1 the nonzero
                mask (1 = keep).
      alpha:    f32[n_alpha, out, n_groups] scale rows — one per plane
                for BCQ, a single shared-magnitude row for ternary.
      z:        f32[out, n_groups] offset row, or ``None`` (ternary).
      kind:     static layout kind, one of :data:`KINDS`.
      group_size: static — input-dim group size for alpha/z.
      in_features / out_features: static logical shape (pre-padding).
    """

    packed: jax.Array
    alpha: jax.Array
    z: Optional[jax.Array]
    group_size: int = dataclasses.field(metadata=dict(static=True))
    in_features: int = dataclasses.field(metadata=dict(static=True))
    out_features: int = dataclasses.field(metadata=dict(static=True))
    kind: str = dataclasses.field(default="bcq", metadata=dict(static=True))

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown bundle kind {self.kind!r}; "
                             f"expected one of {KINDS}")

    @property
    def bits(self) -> int:
        """Stored plane count (2 for ternary: sign + mask)."""
        return self.packed.shape[-3]

    @property
    def effective_bits(self) -> float:
        """Information rate in bits/weight (log2 of the level count)."""
        return TERNARY_BITS if self.kind == "ternary" else float(self.bits)

    @property
    def n_groups(self) -> int:
        return self.alpha.shape[-1]

    def nbytes(self) -> int:
        """Storage footprint in bytes (what HBM actually holds)."""
        n = (self.packed.size * self.packed.dtype.itemsize
             + self.alpha.size * self.alpha.dtype.itemsize)
        if self.z is not None:
            n += self.z.size * self.z.dtype.itemsize
        return n

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize(self, dtype=dtype)


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


def pack_planes(planes: jax.Array) -> jax.Array:
    """Pack {-1,+1} (or {0,1}) bit-planes into uint8, LSB-first.

    planes: [q, out, in] with in % 8 == 0; values in {-1,+1} or {0,1}.
    returns uint8[q, out, in//8].
    """
    q, out, n = planes.shape
    if n % 8 != 0:
        raise ValueError(f"input dim {n} not divisible by 8; pad first")
    bits = (planes > 0).astype(jnp.uint8).reshape(q, out, n // 8, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return (bits << shifts).sum(axis=-1).astype(jnp.uint8)


def unpack_planes(packed: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`pack_planes`; returns ±1 planes [q, out, in]."""
    q, out, nb = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)  # [q, out, nb, 8]
    pm1 = bits.astype(dtype) * 2 - 1
    return pm1.reshape(q, out, nb * 8)


# ---------------------------------------------------------------------------
# dequantize (kind-aware reference reconstruction)
# ---------------------------------------------------------------------------


def dequantize(w: PlaneBundle, dtype=jnp.float32) -> jax.Array:
    """Reconstruct the dense weight matrix W[out, in] from a bundle.

    Written as one elementwise chain (unpack -> scale -> reduce) so XLA
    can fuse it into a single kernel whose HBM traffic is the packed
    bytes in + the dense matrix out.  Pass dtype=bf16 on the serve path:
    an f32 dense intermediate doubles the dominant weight-byte term.
    """
    q, out, nb = w.packed.shape[-3:]
    in_pad = nb * 8
    g = w.group_size
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (w.packed[..., None] >> shifts) & jnp.uint8(1)   # [q,out,nb,8]
    if w.kind == "ternary":
        sign = (bits[0].astype(jnp.float32) * 2 - 1).reshape(out, in_pad)
        mask = bits[1].astype(jnp.float32).reshape(out, in_pad)
        a_cols = jnp.repeat(w.alpha[0], g, axis=-1)         # [out, in_pad]
        dense = a_cols * sign * mask
    else:
        pm1 = bits.astype(jnp.float32) * 2 - 1
        alpha_cols = jnp.repeat(w.alpha, g, axis=-1)        # [q,out,in_pad]
        dense = (pm1.reshape(q, out, in_pad) * alpha_cols).sum(0)
        if w.z is not None:
            dense = dense + jnp.repeat(w.z, g, axis=-1)
    return dense[:, : w.in_features].astype(dtype)


# ---------------------------------------------------------------------------
# kernel-tile admission: the one place launch padding happens
# ---------------------------------------------------------------------------


def tile_operands(x2: jax.Array, w: PlaneBundle, *, block_b: int,
                  block_m: int, block_n: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array,
                             Optional[jax.Array], int, int, int, int]:
    """Pad (activations, bundle) out to kernel-launch geometry.

    x2: [b, in_features] flattened activation batch.  Returns
    ``(xp, packed, alpha, z, b, m, block_m, block_n)`` where every array
    is zero-padded to block multiples: xp [bp, npad], packed
    [q, mp, npad//8], alpha [n_alpha, mp, agp], z [mp, agp] or None.
    ``block_m``/``block_n`` come back clamped to the (row-aligned,
    group-aligned) weight extents so callers pass the effective values
    to the tiled launcher.

    Zero padding is correct for every kind: padded x columns contribute
    0 to LUT entries and activation-sums alike, and padded weight rows
    produce garbage only in output rows that are sliced off ([:b, :m]).
    """
    b = x2.shape[0]
    q, m, _ = w.packed.shape
    n_pad_w = w.packed.shape[-1] * 8          # weight-side padded N (x8)
    ag = w.alpha.shape[-1]
    na = w.alpha.shape[0]

    bp = _round_up(b, block_b)
    block_n = min(block_n, _round_up(n_pad_w, w.group_size))
    npad = _round_up(n_pad_w, block_n)
    block_m = min(block_m, _round_up(m, 8))
    mp = _round_up(m, block_m)
    agp = npad // w.group_size

    xp = jnp.zeros((bp, npad), x2.dtype).at[:b, : x2.shape[1]].set(x2)
    packed, alpha, z = w.packed, w.alpha, w.z
    if npad != n_pad_w or mp != m or agp != ag:
        packed = jnp.zeros((q, mp, npad // 8), jnp.uint8) \
            .at[:, :m, : n_pad_w // 8].set(packed)
        alpha = jnp.zeros((na, mp, agp), alpha.dtype) \
            .at[:, :m, :ag].set(alpha)
        if z is not None:
            z = jnp.zeros((mp, agp), z.dtype).at[:m, :ag].set(z)
    return xp, packed, alpha, z, b, m, block_m, block_n
