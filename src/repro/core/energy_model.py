"""Analytical energy / area / throughput model of FP-INT GEMM engines.

The paper's headline results (Figs 6, 8, 9, 13, 15, 16, 17; Tables III, V)
are circuit measurements from a 28 nm P&R flow — unavailable in software.
We reproduce them with a component-level analytical model:

  * per-op energies (pJ) for FP/INT adders & multipliers, flip-flops,
    muxes, register files, SRAM and DRAM accesses — 28 nm-class constants
    (Horowitz ISSCC'14 scaled, CACTI-class memory numbers), with a small
    set of calibration factors chosen once so that the *paper's own
    anchors* (Table V watts, Fig 6 RFLUT>FP-adder ordering, Fig 8/9 optima
    at mu=4/k=32) are met; every benchmark then reports model numbers next
    to the paper's and the deltas.
  * engine descriptions mirroring §IV-B's configurations: FPE & FIGNA
    64x64 PEs, iFPU 64x64x4 bit-serial, FIGLUT 2x16x4 PEs with one
    (h)FFLUT + k RACs per PE — all sized for identical Q4 throughput.

Workloads are (M, N, B) GEMMs; LLM evaluation walks the OPT family's layer
shapes.  Cycle counts follow each engine's dataflow; bit-serial engines
(iFPU, FIGLUT) scale cycles with q, fixed-width engines pad sub-4-bit to
Q4 (§IV-C).  Time = max(compute, DRAM) — the memory-bound regime of LLM
decode is what rewards sub-4-bit storage.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

from repro.core.lut import generator_adder_count

Engine = Literal["FPE", "iFPU", "FIGNA", "FIGLUT-F", "FIGLUT-I"]

# ---------------------------------------------------------------------------
# component constants (28nm-class; pJ, um^2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Tech:
    # arithmetic energy, pJ
    fp16_add: float = 0.40
    fp16_mul: float = 1.10
    fp32_add: float = 0.90
    fp32_mul: float = 3.70
    int_add_per_bit: float = 0.006      # ripple-class adder, pJ/bit
    int_mul_per_bit2: float = 0.0095     # array multiplier, pJ/(bit*bit)
    i2f_dequant: float = 0.55            # INT->FP convert + scale (FPE)
    # storage / wires
    ff_clk_per_bit: float = 0.0035       # FF clock+data toggle, pJ/bit/cycle
    mux_per_bit_per_way: float = 0.0015  # read-mux select tree, pJ/(bit*way)
    fanout_per_reader: float = 0.004     # relative extra mux/wire energy per
                                         # additional RAC sharing one LUT
    rf_read_per_bit: float = 0.055       # register-file (RFLUT) read, pJ/bit
    sram_per_byte: float = 2.5
    dram_per_byte: float = 20.0
    # area, um^2
    a_fp16_add: float = 600.0
    a_fp16_mul: float = 1700.0
    a_fp32_add: float = 1300.0
    a_fp32_mul: float = 4500.0
    a_int_add_per_bit: float = 18.0
    a_int_mul_per_bit2: float = 8.0
    a_ff_per_bit: float = 4.5
    a_mux_per_bit_per_way: float = 0.55
    a_i2f: float = 900.0
    # system
    freq_hz: float = 100e6               # paper synthesizes @100 MHz
    dram_bw: float = 25.6e9              # single-channel DDR4-class
    # single global derate calibrated to Table V's 0.14 TOPS anchor
    utilization: float = 0.17
    # on-chip power overhead (clock tree, control, buffer static power —
    # not modelled per-component); calibrated once against Table V watts
    overhead_factor: float = 7.5


TECH = Tech()

ACT_BITS = {"fp16": 16, "bf16": 16, "fp32": 32}
ACT_MANT = {"fp16": 11, "bf16": 8, "fp32": 24}  # incl. implicit bit


# ---------------------------------------------------------------------------
# engine configurations  (paper §IV-B "Configuration Setup")
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineCfg:
    name: str
    macs_per_cycle: int          # Q4-equivalent MACs per cycle
    bit_serial: bool
    mu: int = 4
    k: int = 32

    @property
    def binary_ops_per_cycle(self) -> int:
        return self.macs_per_cycle * 4  # Q4 reference


def engine_cfg(engine: Engine, mu: int = 4, k: int = 32) -> EngineCfg:
    if engine == "FPE":
        return EngineCfg("FPE", 64 * 64, False)
    if engine == "FIGNA":
        return EngineCfg("FIGNA", 64 * 64, False)
    if engine == "iFPU":
        return EngineCfg("iFPU", 64 * 64, True)          # 64x64x4 binary units
    if engine in ("FIGLUT-F", "FIGLUT-I"):
        # 2x16x4 PEs x k RACs; with mu=4,k=32 -> 4096 RACs = iFPU unit count
        return EngineCfg(engine, 64 * 64, True, mu=mu, k=k)
    raise ValueError(engine)


# ---------------------------------------------------------------------------
# LUT power primitives (Fig 6 / Fig 8 / Fig 9 / Table III)
# ---------------------------------------------------------------------------


def fflut_read_energy(mu: int, act_bits: int, k: int, tech: Tech = TECH,
                      half: bool = True) -> float:
    """Energy of one RAC read from a (h)FFLUT shared by k readers, pJ.

    mux tree over the table entries x value width, plus fan-out wiring
    penalty growing with k (paper Fig 9's rising tail).
    """
    entries = (1 << (mu - 1)) if half else (1 << mu)
    base = tech.mux_per_bit_per_way * entries * act_bits
    if half:
        base += 0.10 * base  # hFFLUT decoder (sign flip + MSB mux, Table III)
    return base * (1.0 + tech.fanout_per_reader * max(k - 1, 0))


def fflut_static_energy_per_cycle(mu: int, act_bits: int, tech: Tech = TECH,
                                  half: bool = True) -> float:
    """FF clock/toggle energy of one LUT per cycle, pJ."""
    entries = (1 << (mu - 1)) if half else (1 << mu)
    return tech.ff_clk_per_bit * entries * act_bits


def rflut_read_energy(mu: int, act_bits: int, tech: Tech = TECH) -> float:
    """Register-file LUT read (the rejected baseline of Fig 6), pJ."""
    return tech.rf_read_per_bit * act_bits * (1.0 + 0.08 * mu)


def lut_generation_energy(mu: int, act_bits: int, is_int: bool,
                          tech: Tech = TECH, half: bool = True) -> float:
    """Energy to (re)generate one LUT's entries (§III-E tree), pJ."""
    adds = generator_adder_count(mu, half=half)
    if is_int:
        e_add = tech.int_add_per_bit * (ACT_MANT["fp16"] + int(np.log2(mu)))
    else:
        e_add = tech.fp16_add if act_bits == 16 else tech.fp32_add
    write = tech.ff_clk_per_bit * ((1 << (mu - 1)) if half else (1 << mu)) * act_bits
    return adds * e_add + write


# ---------------------------------------------------------------------------
# per-engine MAC-level energy (compute only)
# ---------------------------------------------------------------------------


def _acc_bits(act: str) -> int:
    return 24 if act != "fp32" else 32     # prealigned integer accumulators


def pe_energy_per_mac(engine: Engine, q: int, act: str = "fp16",
                      mu: int = 4, k: int = 32, tech: Tech = TECH) -> float:
    """Average compute energy per (FP-act x INTq-weight) MAC, pJ.

    Bit-serial engines process ceil stays with q planes; fixed-width engines
    execute sub-4-bit as padded Q4 (energy of the Q4 datapath).
    """
    ab = ACT_BITS[act]
    mant = ACT_MANT[act]
    if engine == "FPE":
        # dequant INT->FP + FP mul + FP32 acc
        mul = tech.fp16_mul if ab == 16 else tech.fp32_mul
        return tech.i2f_dequant + mul + tech.fp32_add
    if engine == "FIGNA":
        # INT(mant) x INT(max(q,4)) mul + INT acc  (+ prealign amortized)
        qq = max(q, 4)
        mul = tech.int_mul_per_bit2 * mant * qq
        acc = tech.int_add_per_bit * _acc_bits(act)
        return mul + acc + 0.02  # prealign/postscale amortized over N
    if engine == "iFPU":
        # q binary-plane INT add/subs per MAC + pipeline FF overhead
        add = tech.int_add_per_bit * _acc_bits(act)
        ff = tech.ff_clk_per_bit * 2 * _acc_bits(act)   # deep bit-serial pipe
        return q * (add + ff) + 0.02
    if engine in ("FIGLUT-F", "FIGLUT-I"):
        # q/mu LUT reads per MAC + accumulate; generation amortized over k
        # readers x (M/k reuse via row forwarding) -> per-read share below.
        if engine == "FIGLUT-I":
            acc = tech.int_add_per_bit * _acc_bits(act)
            is_int = True
        else:
            acc = tech.fp32_add
            is_int = False
        read = fflut_read_energy(mu, ab, k, tech)
        static_share = fflut_static_energy_per_cycle(mu, ab, tech) / k
        gen_share = lut_generation_energy(mu, ab, is_int, tech) / (64 * mu)
        # one LUT serves k RACs each cycle; a generated LUT is reused by all
        # 64 output rows of a tile column (row forwarding, §III-B).
        per_read = read + acc + static_share + gen_share
        return (q / mu) * per_read
    raise ValueError(engine)


# ---------------------------------------------------------------------------
# GEMM-level model (cycles, DRAM, power, TOPS/W)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GemmReport:
    engine: str
    q: float
    act: str
    macs: float
    cycles: float
    time_s: float
    compute_J: float
    sram_J: float
    dram_J: float
    total_J: float
    power_W: float
    tops: float
    tops_per_w: float

    def row(self) -> str:
        return (f"{self.engine:10s} q={self.q:<4} {self.act:5s} "
                f"P={self.power_W:6.3f}W  TOPS={self.tops:6.3f}  "
                f"TOPS/W={self.tops_per_w:6.3f}")


def gemm_report(engine: Engine, M: int, N: int, B: int, q: float,
                act: str = "fp16", mu: int = 4, k: int = 32,
                tech: Tech = TECH, weight_resident: bool = False) -> GemmReport:
    """Model one FP-INT GEMM  y[B,M] = x[B,N] @ W[M,N]^T  on an engine.

    ``q`` may be fractional (mixed precision — average plane count for
    bit-serial engines; fixed engines pad up to ceil->4/8).
    """
    cfg = engine_cfg(engine, mu, k)
    macs = float(M) * N * B
    ab = ACT_BITS[act]

    if cfg.bit_serial:
        cycles = macs * q / cfg.binary_ops_per_cycle
    else:
        q_hw = 4 if q <= 4 else 8
        cycles = macs / cfg.macs_per_cycle
        if q_hw == 8:   # widened datapath runs at same rate, higher energy
            pass
    t_compute = cycles / tech.freq_hz

    # DRAM: packed weights (q/8 B each) + FP acts in + FP outs
    w_bytes = M * N * q / 8 + M * (N / 128) * (q + 1) * 2   # planes + alpha/z fp16
    if engine in ("FPE", "FIGNA") and q < 4:
        w_bytes = M * N * 4 / 8 + M * (N / 128) * 5 * 2     # stored padded Q4
    io_bytes = (B * N + B * M) * (ab // 8)
    dram_bytes = (0 if weight_resident else w_bytes) + io_bytes
    t_dram = dram_bytes / tech.dram_bw
    time_s = max(t_compute, t_dram) / tech.utilization

    e_mac = pe_energy_per_mac(engine, min(int(np.ceil(q)), 8), act, mu, k, tech)
    if cfg.bit_serial:
        # energy scales with actual plane count (possibly fractional avg)
        e_mac = e_mac * (q / min(int(np.ceil(q)), 8))
    compute_J = macs * e_mac * 1e-12
    # SRAM: every operand staged through on-chip buffers once per tile-use
    sram_J = (w_bytes + 2 * io_bytes) * tech.sram_per_byte * 1e-12
    dram_J = dram_bytes * tech.dram_per_byte * 1e-12
    # clock/control/static overhead applies on-chip only (not DRAM)
    compute_J *= tech.overhead_factor
    sram_J *= tech.overhead_factor
    total_J = compute_J + sram_J + dram_J

    power = total_J / time_s
    ops = 2 * macs
    tops = ops / time_s / 1e12
    return GemmReport(engine, q, act, macs, cycles, time_s, compute_J,
                      sram_J, dram_J, total_J, power, tops,
                      tops / max(power, 1e-12))


# ---------------------------------------------------------------------------
# area model (Fig 13 / Fig 14)
# ---------------------------------------------------------------------------


def engine_area_mm2(engine: Engine, q: int = 4, act: str = "fp16",
                    mu: int = 4, k: int = 32, tech: Tech = TECH) -> dict:
    """MPU area split into arithmetic vs flip-flop (Fig 14's categories)."""
    ab = ACT_BITS[act]
    mant = ACT_MANT[act]
    n_pe = 64 * 64
    if engine == "FPE":
        a_mul = tech.a_fp16_mul if ab == 16 else tech.a_fp32_mul
        a_add = tech.a_fp32_add
        arith = n_pe * (a_mul + a_add + tech.a_i2f)
        ff = n_pe * tech.a_ff_per_bit * (2 * ab + 32) * 2.0   # 63-stage systolic pipe
    elif engine == "FIGNA":
        qq = max(q, 4)
        arith = n_pe * (tech.a_int_mul_per_bit2 * mant * qq
                        + tech.a_int_add_per_bit * 24)
        ff = n_pe * tech.a_ff_per_bit * (mant + qq + 24) * 2.0
    elif engine == "iFPU":
        n_units = 64 * 64 * 4
        arith = n_units * tech.a_int_add_per_bit * 24
        ff = n_units * tech.a_ff_per_bit * 24 * 2.5          # deep serial pipes
    elif engine in ("FIGLUT-F", "FIGLUT-I"):
        n_rac = 2 * 16 * 4 * k
        n_lut = 2 * 16 * 4
        entries = 1 << (mu - 1)
        a_acc = tech.a_fp32_add if engine == "FIGLUT-F" else tech.a_int_add_per_bit * 24
        arith = (n_rac * (a_acc + tech.a_mux_per_bit_per_way * entries * ab)
                 + n_lut * 2 * 16 * (tech.a_fp16_add if engine == "FIGLUT-F"
                                     else tech.a_int_add_per_bit * (mant + 2)) )
        # generators: 14 adders per LUT row block
        ff = n_lut * tech.a_ff_per_bit * entries * ab \
            + n_rac * tech.a_ff_per_bit * (mu + 32)          # key reg + acc reg
        # 15-stage (vs 63) input staging credit already reflected in counts
    else:
        raise ValueError(engine)
    return {"arith_mm2": arith * 1e-6, "ff_mm2": ff * 1e-6,
            "total_mm2": (arith + ff) * 1e-6}


# ---------------------------------------------------------------------------
# OPT-family workload shapes (paper evaluates OPT-125M .. 30B)
# ---------------------------------------------------------------------------

OPT_DIMS = {            # d_model, n_layers, ffn_mult 4
    "opt-125m": (768, 12),
    "opt-350m": (1024, 24),
    "opt-1.3b": (2048, 24),
    "opt-2.7b": (2560, 32),
    "opt-6.7b": (4096, 32),
    "opt-13b": (5120, 40),
    "opt-30b": (7168, 48),
}


def opt_layer_gemms(model: str) -> list[tuple[int, int]]:
    """(M, N) for every GEMM in one decoder layer (QKVO + 2 FFN)."""
    d, _ = OPT_DIMS[model]
    return [(d, d)] * 4 + [(4 * d, d), (d, 4 * d)]


def model_report(engine: Engine, model: str, B: int, q: float,
                 act: str = "fp16", mu: int = 4, k: int = 32,
                 tech: Tech = TECH) -> GemmReport:
    """Aggregate a whole OPT model's GEMMs into one report."""
    d, L = OPT_DIMS[model]
    reports = [gemm_report(engine, M, N, B, q, act, mu, k, tech)
               for (M, N) in opt_layer_gemms(model)]
    agg = GemmReport(engine, q, act, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0)
    for r in reports:
        agg.macs += r.macs * L
        agg.cycles += r.cycles * L
        agg.time_s += r.time_s * L
        agg.compute_J += r.compute_J * L
        agg.sram_J += r.sram_J * L
        agg.dram_J += r.dram_J * L
        agg.total_J += r.total_J * L
    agg.power_W = agg.total_J / agg.time_s
    agg.tops = 2 * agg.macs / agg.time_s / 1e12
    agg.tops_per_w = agg.tops / agg.power_W
    return agg
