"""Binary-coding quantization (BCQ) — the weight format FIGLUT executes.

A real-valued weight w is approximated as

    w  ≈  sum_{i=1}^{q} alpha_i * b_i  +  z ,     b_i in {-1, +1}

(paper Eq. (1)/(3)).  The binary planes ``B_i`` are what the accelerator
streams bit-serially; ``alpha`` and the offset ``z`` are per-output-row
(optionally per input-group) FP scaling terms.

This module provides:

  * ``quantize``            — greedy + alternating-refinement BCQ solver
  * ``from_uniform``        — exact RTN-uniform -> BCQ(+offset) conversion
                              (paper Fig. 1 / Eq. (3), after [28])
  * ``dequantize``          — reference reconstruction
  * ``pack_planes`` / ``unpack_planes`` — uint8 bit-plane packing (8 binary
                              weights per byte per plane) — the storage format
                              whose HBM footprint the roofline credits
  * ``BCQWeight``           — pytree container used by QuantizedLinear

Shapes follow the GEMM convention of the paper: a weight matrix
``W in R^{out, in}`` multiplies activations ``x in R^{in}``.  Scaling factors
are per (out, group) where groups tile the *input* dimension (group size g,
default 128 — the LUT-GEMM convention), so

    W[m, n]  ≈  sum_i alpha[i, m, G(n)] * B[i, m, n]  +  z[m, G(n)]

All solvers are pure JAX and jittable; they vectorize over rows and groups.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BCQWeight",
    "quantize",
    "from_uniform",
    "dequantize",
    "pack_planes",
    "unpack_planes",
    "packed_nbytes",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BCQWeight:
    """BCQ-quantized weight tensor (pytree).

    Attributes:
      packed:   uint8[q, out, in//8]  bit-planes, 8 binary weights per byte
                (LSB-first within the byte along the input dim).  Bit value 1
                encodes b=+1, 0 encodes b=-1.
      alpha:    f32[q, out, n_groups] per-plane scaling factors.
      z:        f32[out, n_groups]    offset term (0 for pure BCQ).
      group_size: static — input-dim group size for alpha/z.
      in_features / out_features: static logical shape (pre-padding).
    """

    packed: jax.Array
    alpha: jax.Array
    z: jax.Array
    group_size: int = dataclasses.field(metadata=dict(static=True))
    in_features: int = dataclasses.field(metadata=dict(static=True))
    out_features: int = dataclasses.field(metadata=dict(static=True))

    @property
    def bits(self) -> int:
        return self.packed.shape[0]

    @property
    def n_groups(self) -> int:
        return self.alpha.shape[-1]

    def nbytes(self) -> int:
        """Storage footprint in bytes (what HBM actually holds)."""
        return (
            self.packed.size * self.packed.dtype.itemsize
            + self.alpha.size * self.alpha.dtype.itemsize
            + self.z.size * self.z.dtype.itemsize
        )


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


def pack_planes(planes: jax.Array) -> jax.Array:
    """Pack {-1,+1} (or {0,1}) bit-planes into uint8, LSB-first.

    planes: [q, out, in] with in % 8 == 0; values in {-1,+1} or {0,1}.
    returns uint8[q, out, in//8].
    """
    q, out, n = planes.shape
    if n % 8 != 0:
        raise ValueError(f"input dim {n} not divisible by 8; pad first")
    bits = (planes > 0).astype(jnp.uint8).reshape(q, out, n // 8, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return (bits << shifts).sum(axis=-1).astype(jnp.uint8)


def unpack_planes(packed: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`pack_planes`; returns ±1 planes [q, out, in]."""
    q, out, nb = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)  # [q, out, nb, 8]
    pm1 = bits.astype(dtype) * 2 - 1
    return pm1.reshape(q, out, nb * 8)


def packed_nbytes(out_features: int, in_features: int, bits: int,
                  group_size: int = 128, alpha_bytes: int = 4) -> int:
    """Analytic storage of a BCQ weight (used by the energy/roofline models)."""
    n_groups = -(-in_features // group_size)
    return (bits * out_features * in_features) // 8 + \
        alpha_bytes * out_features * n_groups * (bits + 1)


# ---------------------------------------------------------------------------
# dequantize (reference reconstruction)
# ---------------------------------------------------------------------------


def dequantize(w: BCQWeight, dtype=jnp.float32) -> jax.Array:
    """Reconstruct the dense weight matrix W[out, in] from BCQ form.

    Written as one elementwise chain (unpack -> scale -> reduce over q)
    so XLA can fuse it into a single kernel whose HBM traffic is the
    packed bytes in + the dense matrix out — the plane tensors stay in
    registers on a fusing backend.  Pass dtype=bf16 on the serve path:
    an f32 dense intermediate doubles the dominant weight-byte term.
    """
    q, out, nb = w.packed.shape
    in_pad = nb * 8
    g = w.group_size
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (w.packed[..., None] >> shifts) & jnp.uint8(1)       # [q,out,nb,8]
    pm1 = bits.astype(jnp.float32) * 2 - 1
    alpha_cols = jnp.repeat(w.alpha, g, axis=-1)                # [q,out,in_pad]
    z_cols = jnp.repeat(w.z, g, axis=-1)                        # [out,in_pad]
    dense = (pm1.reshape(q, out, in_pad) * alpha_cols).sum(0) + z_cols
    return dense[:, : w.in_features].astype(dtype)


# ---------------------------------------------------------------------------
# uniform (RTN) -> BCQ with offset       (paper Fig. 1, after LUT-GEMM [28])
# ---------------------------------------------------------------------------


def from_uniform(w_dense: jax.Array, bits: int, group_size: int = 128) -> BCQWeight:
    """Exact mapping of round-to-nearest uniform quantization into BCQ form.

    RTN:   w ≈ s * (n - z0),  n ∈ {0..2^q-1},  s/z0 per (row, group)
    BCQ:   alpha_i = s * 2^{i-1},   z = s * ((2^q - 1)/2 - z0)

    so that sum_i alpha_i b_i + z reproduces every uniform level exactly
    (b_i = 2*bit_i(n) - 1).  This is what lets the fixed BCQ engine execute
    ordinary uniformly-quantized checkpoints (OPTQ/AWQ/RTN).
    """
    w = jnp.asarray(w_dense, jnp.float32)
    out, n = w.shape
    g = int(group_size)
    n_pad = -(-n // g) * g
    if n_pad != n:
        w = jnp.pad(w, ((0, 0), (0, n_pad - n)), mode="edge")
    n_groups = n_pad // g
    wg = w.reshape(out, n_groups, g)

    levels = (1 << bits) - 1
    wmin = wg.min(axis=-1)
    wmax = wg.max(axis=-1)
    scale = jnp.maximum((wmax - wmin) / levels, 1e-12)   # s
    z0 = -wmin / scale                                   # real-valued zero-point
    code = jnp.clip(jnp.round((wg - wmin[..., None]) / scale[..., None]), 0, levels)

    # bit-planes of the code, LSB = plane 0
    planes = []
    for i in range(bits):
        bit = (code.astype(jnp.int32) >> i) & 1
        planes.append((bit * 2 - 1).astype(jnp.float32))
    planes = jnp.stack(planes)                     # [q, out, n_groups, g] in {-1,1}
    planes = planes.reshape(bits, out, n_pad)

    pow2 = (2.0 ** jnp.arange(bits, dtype=jnp.float32)) / 2.0   # 2^{i-1}
    alpha = scale[None, :, :] * pow2[:, None, None]              # [q, out, G]
    z = scale * ((levels / 2.0) - z0)                            # [out, G]
    # reconstruct offset: w = s*(n - z0); n = sum 2^i bit_i = sum 2^{i-1}(b_i+1)
    #   => w = sum s 2^{i-1} b_i + s(sum 2^{i-1} - z0) = sum alpha_i b_i + s((2^q-1)/2 - z0)
    return BCQWeight(
        packed=pack_planes(planes),
        alpha=alpha.astype(jnp.float32),
        z=z.astype(jnp.float32),
        group_size=g,
        in_features=n,
        out_features=out,
    )


# ---------------------------------------------------------------------------
# BCQ solver: greedy init + alternating refinement   (Eq. (1), after [33])
# ---------------------------------------------------------------------------


def _greedy_init(wg: jax.Array, bits: int):
    """Greedy BCQ (Xu et al.): repeatedly fit sign/mean-abs to the residual.

    wg: [out, G, g] grouped weights. Returns planes [q,out,G,g] in {-1,1},
    alpha [q,out,G].
    """
    r = wg
    planes, alphas = [], []
    for _ in range(bits):
        b = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.mean(jnp.abs(r), axis=-1)          # [out, G]
        planes.append(b)
        alphas.append(a)
        r = r - a[..., None] * b
    return jnp.stack(planes), jnp.stack(alphas)


def _ls_alpha(wg: jax.Array, planes: jax.Array, with_offset: bool):
    """Least-squares refit of (alpha_1..alpha_q[, z]) given binary planes.

    Solves  min || w - A c ||  where A = [b_1 .. b_q (, 1)] per (out, G) row.
    Uses the qxq normal equations (q <= 8 so this is tiny).
    planes: [q, out, G, g];  wg: [out, G, g].
    Returns alpha [q, out, G], z [out, G].
    """
    q = planes.shape[0]
    cols = planes
    if with_offset:
        ones = jnp.ones_like(planes[:1])
        cols = jnp.concatenate([planes, ones], axis=0)    # [q+1, out, G, g]
    k = cols.shape[0]
    # normal matrix  M[i,j] = <col_i, col_j>  per (out, G)
    M = jnp.einsum("iogn,jogn->ogij", cols, cols)          # [out, G, k, k]
    v = jnp.einsum("iogn,ogn->ogi", cols, wg)              # [out, G, k]
    # Tikhonov-regularize: binary columns CAN be exactly collinear (a greedy
    # plane that comes out constant duplicates the offset column), which makes
    # M singular.  Diagonal entries are exactly g, so scale the ridge with g.
    g = wg.shape[-1]
    M = M + (1e-3 * g) * jnp.eye(k, dtype=M.dtype)
    c = jnp.linalg.solve(M, v[..., None])[..., 0]          # [out, G, k]
    alpha = jnp.moveaxis(c[..., :q], -1, 0)                # [q, out, G]
    z = c[..., q] if with_offset else jnp.zeros_like(v[..., 0])
    return alpha, z


def _reassign_planes(wg: jax.Array, alpha: jax.Array, z: jax.Array, bits: int):
    """Optimal binary plane re-assignment for fixed alpha/z.

    Each scalar weight independently picks the codeword
    c(p) = sum_i alpha_i * (+-1 per bit of p) + z  closest to it — a 2^q-entry
    nearest-codebook search (q <= 8 -> at most 256 candidates, vectorized).
    """
    q = bits
    n_codes = 1 << q
    codes = jnp.arange(n_codes)
    # signs[p, i] = +1 if bit i of p else -1
    signs = ((codes[:, None] >> jnp.arange(q)[None, :]) & 1) * 2.0 - 1.0  # [P, q]
    # codeword values per (out, G): [out, G, P]
    vals = jnp.einsum("pi,iog->ogp", signs, alpha) + z[..., None]
    # nearest code per element: wg [out, G, g] vs vals [out, G, P]
    idx = jnp.argmin(
        jnp.abs(wg[..., None] - vals[..., None, :]), axis=-1
    )  # [out, G, g]
    bit = (idx[None, ...] >> jnp.arange(q)[:, None, None, None]) & 1
    return bit.astype(jnp.float32) * 2 - 1                 # [q, out, G, g]


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "iters", "with_offset"))
def _quantize_impl(w: jax.Array, bits: int, group_size: int, iters: int,
                   with_offset: bool):
    out, n = w.shape
    g = group_size
    n_pad = -(-n // g) * g
    pad = n_pad - n
    if pad:
        # pad with edge replication so padded cols don't skew alpha; they are
        # masked out of the LS fits below via weighting = simply repeat values.
        w = jnp.pad(w, ((0, 0), (0, pad)), mode="edge")
    n_groups = n_pad // g
    wg = w.reshape(out, n_groups, g)

    planes, alpha = _greedy_init(wg, bits)
    z = jnp.zeros((out, n_groups), w.dtype)
    for _ in range(iters):
        alpha, z_new = _ls_alpha(wg, planes, with_offset)
        z = z_new if with_offset else z
        # keep alpha positive & planes canonical (sign absorbed into planes)
        sign = jnp.where(alpha < 0, -1.0, 1.0)
        alpha = alpha * sign
        planes = planes * sign[..., None]
        planes = _reassign_planes(wg, alpha, z, bits)
    alpha, z_new = _ls_alpha(wg, planes, with_offset)
    z = z_new if with_offset else z
    sign = jnp.where(alpha < 0, -1.0, 1.0)
    alpha, planes = alpha * sign, planes * sign[..., None]

    planes = planes.reshape(bits, out, n_pad)
    return pack_planes(planes), alpha.astype(jnp.float32), z.astype(jnp.float32)


def quantize(w_dense: jax.Array, bits: int, group_size: int = 128,
             iters: int = 5, with_offset: bool = True) -> BCQWeight:
    """BCQ-quantize a dense weight matrix.

    Greedy init + ``iters`` rounds of (alpha,z) least squares <-> binary
    nearest-codebook reassignment (alternating minimization of Eq. (1)).

    with_offset=True yields the extended BCQ of Eq. (3) that subsumes
    uniform quantization; False gives classic zero-offset BCQ.
    """
    w = jnp.asarray(w_dense, jnp.float32)
    if w.ndim != 2:
        raise ValueError(f"expected 2-D weight, got {w.shape}")
    packed, alpha, z = _quantize_impl(w, int(bits), int(group_size), int(iters),
                                      bool(with_offset))
    return BCQWeight(
        packed=packed, alpha=alpha, z=z, group_size=int(group_size),
        in_features=w.shape[1], out_features=w.shape[0],
    )
