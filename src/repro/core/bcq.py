"""Binary-coding quantization (BCQ) — the weight format FIGLUT executes.

A real-valued weight w is approximated as

    w  ≈  sum_{i=1}^{q} alpha_i * b_i  +  z ,     b_i in {-1, +1}

(paper Eq. (1)/(3)).  The binary planes ``B_i`` are what the accelerator
streams bit-serially; ``alpha`` and the offset ``z`` are per-output-row
(optionally per input-group) FP scaling terms.

This module provides:

  * ``quantize``            — greedy + alternating-refinement BCQ solver
  * ``from_uniform``        — exact RTN-uniform -> BCQ(+offset) conversion
                              (paper Fig. 1 / Eq. (3), after [28])
  * ``dequantize``          — reference reconstruction
  * ``pack_planes`` / ``unpack_planes`` — uint8 bit-plane packing (8 binary
                              weights per byte per plane) — the storage format
                              whose HBM footprint the roofline credits
  * ``BCQWeight``           — pytree container used by QuantizedLinear

Shapes follow the GEMM convention of the paper: a weight matrix
``W in R^{out, in}`` multiplies activations ``x in R^{in}``.  Scaling factors
are per (out, group) where groups tile the *input* dimension (group size g,
default 128 — the LUT-GEMM convention), so

    W[m, n]  ≈  sum_i alpha[i, m, G(n)] * B[i, m, n]  +  z[m, G(n)]

All solvers are pure JAX and jittable; they vectorize over rows and groups.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.plane import (PlaneBundle, dequantize, pack_planes,
                              unpack_planes)

__all__ = [
    "BCQWeight",
    "PlaneBundle",
    "quantize",
    "from_uniform",
    "dequantize",
    "pack_planes",
    "unpack_planes",
    "packed_nbytes",
]


# ``BCQWeight`` is the historical name for the generic-BCQ view of the
# plane-native layout; since PR 10 it IS the :class:`PlaneBundle`
# (kind="bcq" by default) — every constructor keyword, pytree
# registration, checkpoint encoding and isinstance check carries over.
BCQWeight = PlaneBundle


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


def packed_nbytes(out_features: int, in_features: int, bits: int,
                  group_size: int = 128, alpha_bytes: int = 4) -> int:
    """Analytic storage of a BCQ weight (used by the energy/roofline models)."""
    n_groups = -(-in_features // group_size)
    return (bits * out_features * in_features) // 8 + \
        alpha_bytes * out_features * n_groups * (bits + 1)


# ---------------------------------------------------------------------------
# uniform (RTN) -> BCQ with offset       (paper Fig. 1, after LUT-GEMM [28])
# ---------------------------------------------------------------------------


def from_uniform(w_dense: jax.Array, bits: int, group_size: int = 128) -> BCQWeight:
    """Exact mapping of round-to-nearest uniform quantization into BCQ form.

    RTN:   w ≈ s * (n - z0),  n ∈ {0..2^q-1},  s/z0 per (row, group)
    BCQ:   alpha_i = s * 2^{i-1},   z = s * ((2^q - 1)/2 - z0)

    so that sum_i alpha_i b_i + z reproduces every uniform level exactly
    (b_i = 2*bit_i(n) - 1).  This is what lets the fixed BCQ engine execute
    ordinary uniformly-quantized checkpoints (OPTQ/AWQ/RTN).
    """
    w = jnp.asarray(w_dense, jnp.float32)
    out, n = w.shape
    g = int(group_size)
    n_pad = -(-n // g) * g
    if n_pad != n:
        w = jnp.pad(w, ((0, 0), (0, n_pad - n)), mode="edge")
    n_groups = n_pad // g
    wg = w.reshape(out, n_groups, g)

    levels = (1 << bits) - 1
    wmin = wg.min(axis=-1)
    wmax = wg.max(axis=-1)
    scale = jnp.maximum((wmax - wmin) / levels, 1e-12)   # s
    z0 = -wmin / scale                                   # real-valued zero-point
    code = jnp.clip(jnp.round((wg - wmin[..., None]) / scale[..., None]), 0, levels)

    # bit-planes of the code, LSB = plane 0
    planes = []
    for i in range(bits):
        bit = (code.astype(jnp.int32) >> i) & 1
        planes.append((bit * 2 - 1).astype(jnp.float32))
    planes = jnp.stack(planes)                     # [q, out, n_groups, g] in {-1,1}
    planes = planes.reshape(bits, out, n_pad)

    pow2 = (2.0 ** jnp.arange(bits, dtype=jnp.float32)) / 2.0   # 2^{i-1}
    alpha = scale[None, :, :] * pow2[:, None, None]              # [q, out, G]
    z = scale * ((levels / 2.0) - z0)                            # [out, G]
    # reconstruct offset: w = s*(n - z0); n = sum 2^i bit_i = sum 2^{i-1}(b_i+1)
    #   => w = sum s 2^{i-1} b_i + s(sum 2^{i-1} - z0) = sum alpha_i b_i + s((2^q-1)/2 - z0)
    return BCQWeight(
        packed=pack_planes(planes),
        alpha=alpha.astype(jnp.float32),
        z=z.astype(jnp.float32),
        group_size=g,
        in_features=n,
        out_features=out,
    )


# ---------------------------------------------------------------------------
# BCQ solver: greedy init + alternating refinement   (Eq. (1), after [33])
# ---------------------------------------------------------------------------


def _greedy_init(wg: jax.Array, bits: int):
    """Greedy BCQ (Xu et al.): repeatedly fit sign/mean-abs to the residual.

    wg: [out, G, g] grouped weights. Returns planes [q,out,G,g] in {-1,1},
    alpha [q,out,G].
    """
    r = wg
    planes, alphas = [], []
    for _ in range(bits):
        b = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.mean(jnp.abs(r), axis=-1)          # [out, G]
        planes.append(b)
        alphas.append(a)
        r = r - a[..., None] * b
    return jnp.stack(planes), jnp.stack(alphas)


def _ls_alpha(wg: jax.Array, planes: jax.Array, with_offset: bool):
    """Least-squares refit of (alpha_1..alpha_q[, z]) given binary planes.

    Solves  min || w - A c ||  where A = [b_1 .. b_q (, 1)] per (out, G) row.
    Uses the qxq normal equations (q <= 8 so this is tiny).
    planes: [q, out, G, g];  wg: [out, G, g].
    Returns alpha [q, out, G], z [out, G].
    """
    q = planes.shape[0]
    cols = planes
    if with_offset:
        ones = jnp.ones_like(planes[:1])
        cols = jnp.concatenate([planes, ones], axis=0)    # [q+1, out, G, g]
    k = cols.shape[0]
    # normal matrix  M[i,j] = <col_i, col_j>  per (out, G)
    M = jnp.einsum("iogn,jogn->ogij", cols, cols)          # [out, G, k, k]
    v = jnp.einsum("iogn,ogn->ogi", cols, wg)              # [out, G, k]
    # Tikhonov-regularize: binary columns CAN be exactly collinear (a greedy
    # plane that comes out constant duplicates the offset column), which makes
    # M singular.  Diagonal entries are exactly g, so scale the ridge with g.
    g = wg.shape[-1]
    M = M + (1e-3 * g) * jnp.eye(k, dtype=M.dtype)
    c = jnp.linalg.solve(M, v[..., None])[..., 0]          # [out, G, k]
    alpha = jnp.moveaxis(c[..., :q], -1, 0)                # [q, out, G]
    z = c[..., q] if with_offset else jnp.zeros_like(v[..., 0])
    return alpha, z


def _reassign_planes(wg: jax.Array, alpha: jax.Array, z: jax.Array, bits: int):
    """Optimal binary plane re-assignment for fixed alpha/z.

    Each scalar weight independently picks the codeword
    c(p) = sum_i alpha_i * (+-1 per bit of p) + z  closest to it — a 2^q-entry
    nearest-codebook search (q <= 8 -> at most 256 candidates, vectorized).
    """
    q = bits
    n_codes = 1 << q
    codes = jnp.arange(n_codes)
    # signs[p, i] = +1 if bit i of p else -1
    signs = ((codes[:, None] >> jnp.arange(q)[None, :]) & 1) * 2.0 - 1.0  # [P, q]
    # codeword values per (out, G): [out, G, P]
    vals = jnp.einsum("pi,iog->ogp", signs, alpha) + z[..., None]
    # nearest code per element: wg [out, G, g] vs vals [out, G, P]
    idx = jnp.argmin(
        jnp.abs(wg[..., None] - vals[..., None, :]), axis=-1
    )  # [out, G, g]
    bit = (idx[None, ...] >> jnp.arange(q)[:, None, None, None]) & 1
    return bit.astype(jnp.float32) * 2 - 1                 # [q, out, G, g]


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "iters", "with_offset"))
def _quantize_impl(w: jax.Array, bits: int, group_size: int, iters: int,
                   with_offset: bool):
    out, n = w.shape
    g = group_size
    n_pad = -(-n // g) * g
    pad = n_pad - n
    if pad:
        # pad with edge replication so padded cols don't skew alpha; they are
        # masked out of the LS fits below via weighting = simply repeat values.
        w = jnp.pad(w, ((0, 0), (0, pad)), mode="edge")
    n_groups = n_pad // g
    wg = w.reshape(out, n_groups, g)

    planes, alpha = _greedy_init(wg, bits)
    z = jnp.zeros((out, n_groups), w.dtype)
    for _ in range(iters):
        alpha, z_new = _ls_alpha(wg, planes, with_offset)
        z = z_new if with_offset else z
        # keep alpha positive & planes canonical (sign absorbed into planes)
        sign = jnp.where(alpha < 0, -1.0, 1.0)
        alpha = alpha * sign
        planes = planes * sign[..., None]
        planes = _reassign_planes(wg, alpha, z, bits)
    alpha, z_new = _ls_alpha(wg, planes, with_offset)
    z = z_new if with_offset else z
    sign = jnp.where(alpha < 0, -1.0, 1.0)
    alpha, planes = alpha * sign, planes * sign[..., None]

    planes = planes.reshape(bits, out, n_pad)
    return pack_planes(planes), alpha.astype(jnp.float32), z.astype(jnp.float32)


def quantize(w_dense: jax.Array, bits: int, group_size: int = 128,
             iters: int = 5, with_offset: bool = True) -> BCQWeight:
    """BCQ-quantize a dense weight matrix.

    Greedy init + ``iters`` rounds of (alpha,z) least squares <-> binary
    nearest-codebook reassignment (alternating minimization of Eq. (1)).

    with_offset=True yields the extended BCQ of Eq. (3) that subsumes
    uniform quantization; False gives classic zero-offset BCQ.
    """
    w = jnp.asarray(w_dense, jnp.float32)
    if w.ndim != 2:
        raise ValueError(f"expected 2-D weight, got {w.shape}")
    packed, alpha, z = _quantize_impl(w, int(bits), int(group_size), int(iters),
                                      bool(with_offset))
    return BCQWeight(
        packed=packed, alpha=alpha, z=z, group_size=int(group_size),
        in_features=w.shape[1], out_features=w.shape[0],
    )
