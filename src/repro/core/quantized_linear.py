"""QuantizedLinear — the drop-in linear layer executing BCQ weights.

A linear's weight leaf is either a dense ``jax.Array`` (training /
unquantized) or a :class:`~repro.core.bcq.BCQWeight` (post-PTQ serving).
``linear_apply`` dispatches transparently, so model code never branches on
quantization state; the execution backend (dense / bcq_xla / lut_pallas /
mxu_pallas) is a config knob threaded through apply.  For the Pallas
backends the launch geometry is resolved per layer shape through
:mod:`repro.tune` (tuned cache or heuristic) — no call site pins block
sizes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bcq import BCQWeight, quantize, from_uniform
from repro.core.lut_gemm import Backend, bcq_apply


_CAPTURE = None


def set_capture(fn):
    """Install a capture hook fn(w, x) called on every linear_apply —
    used to collect per-layer calibration activations for OPTQ (eager
    forward passes only; hooks see tracers under jit)."""
    global _CAPTURE
    _CAPTURE = fn


def linear_apply(w, x: jax.Array, bias: Optional[jax.Array] = None,
                 backend: Backend = "bcq_xla", out_dtype=None) -> jax.Array:
    """y = x @ W^T (+ bias).  W is dense [out, in] or BCQWeight."""
    if _CAPTURE is not None:
        _CAPTURE(w, x)
    out_dtype = out_dtype or x.dtype
    if isinstance(w, BCQWeight):
        y = bcq_apply(x, w, backend=backend, out_dtype=out_dtype)
    else:
        y = jnp.einsum("...n,mn->...m", x, w.astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(out_dtype)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def quantize_linear(w: jax.Array, bits: int, method: str = "bcq",
                    group_size: int = 128, iters: int = 5) -> BCQWeight:
    """Quantize one dense [out, in] weight.

    method: "bcq" (alternating non-uniform, ShiftAddLLM-class) or
            "rtn"/"uniform" (round-to-nearest mapped exactly into BCQ form —
            what lets FIGLUT run uniformly-quantized checkpoints).
    """
    if method == "bcq":
        return quantize(w, bits=bits, group_size=group_size, iters=iters)
    if method in ("rtn", "uniform"):
        return from_uniform(w, bits=bits, group_size=group_size)
    raise ValueError(f"unknown method {method!r}")
