"""QuantizedLinear — the drop-in linear layer executing BCQ weights.

A linear's weight leaf is either a dense ``jax.Array`` (training /
unquantized) or a :class:`~repro.core.bcq.BCQWeight` (post-PTQ serving).
``linear_apply`` hands every call to the backend *registry*
(:mod:`repro.quant.backends`): the ``backend`` argument is a preference
(``None``/"auto" lets the registry pick the best native path), and
capability negotiation walks the preference's fallback chain
(``mxu_pallas``/``lut_pallas`` -> ``bcq_xla`` -> ``dense``) per weight —
model code never branches on quantization state or pins an executor.
For the Pallas backends the launch geometry is resolved per layer shape
through :mod:`repro.tune` (tuned cache or heuristic).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.bcq import BCQWeight


_CAPTURE = None


def set_capture(fn):
    """Install a capture hook fn(w, x) called on every linear_apply —
    used to collect per-layer calibration activations for OPTQ (eager
    forward passes only; hooks see tracers under jit)."""
    global _CAPTURE
    _CAPTURE = fn


def linear_apply(w, x: jax.Array, bias: Optional[jax.Array] = None,
                 backend: Optional[str] = None, out_dtype=None) -> jax.Array:
    """y = x @ W^T (+ bias).  W is dense [out, in] or BCQWeight.

    ``backend``: preference name from the registry ("auto"/None, "dense",
    "bcq_xla", "lut_pallas", "mxu_pallas", ...) — resolution and fallback
    happen in :func:`repro.quant.backends.execute_linear`.
    """
    if _CAPTURE is not None:
        _CAPTURE(w, x)
    # function-level import: quant.backends imports core submodules, so a
    # module-level import would be order-sensitive during package init
    from repro.quant.backends import execute_linear
    y = execute_linear(x, w, backend=backend, out_dtype=out_dtype or x.dtype)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def quantize_linear(w: jax.Array, bits: int, method: str = "bcq",
                    group_size: int = 128, iters: int = 5) -> BCQWeight:
    """Quantize one dense [out, in] weight through the format registry.

    ``method`` is a format name ("bcq", "rtn"/"uniform", "ternary", or any
    :func:`repro.quant.register_format` addition).  Kept as a thin shim
    over :mod:`repro.quant.formats` for callers quantizing single
    matrices; whole trees should use ``repro.quant.quantize_model``.
    """
    from repro.quant.formats import get_format
    fmt = get_format(method)
    return fmt.quantize(w, bits=fmt.plane_bits(bits), group_size=group_size,
                        iters=iters)
