"""LUT construction & keying for LUT-based FP-INT GEMM (paper §III-A/D/E).

Given activations ``x`` split into groups of ``mu`` consecutive elements, the
LUT for group ``G`` holds every signed combination

    LUT[G, p] = sum_{j<mu} sign_j(p) * x[G*mu + j],   sign_j(p) = +1 if bit j
                of p is set else -1,   p in [0, 2^mu)

so a weight row's contribution over the group is ONE read keyed by its mu-bit
pattern (the RAC operation).  Key layout matches `bcq.pack_planes`: bit j of
the key corresponds to input ``G*mu + j`` (LSB-first).

hFFLUT (§III-D): LUT is odd-symmetric, ``LUT[p] = -LUT[2^mu-1-p]`` (flipping
every sign bit negates the sum).  We store only the MSB=1 half and decode

    value(p) = msb(p) ? half[p - 2^(mu-1)] : -half[(2^mu-1-p) - 2^(mu-1)]

The LUT *generator* (§III-E) builds all entries with a 2-step tree that
shares low-half partial sums; `generator_adder_count` reports its adder cost
(14 adds for mu=4 vs 24 naive -> the paper's "42% fewer" claim) and feeds the
energy model / bench_fig11.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "sign_matrix",
    "build_lut",
    "build_half_lut",
    "decode_half_lut",
    "extract_keys",
    "keys_from_packed",
    "generator_adder_count",
    "naive_adder_count",
]


@functools.lru_cache(maxsize=None)
def _sign_matrix_np(mu: int) -> np.ndarray:
    p = np.arange(1 << mu)
    bits = (p[:, None] >> np.arange(mu)[None, :]) & 1
    return (bits * 2 - 1).astype(np.float32)  # [2^mu, mu]


def sign_matrix(mu: int, dtype=jnp.float32) -> jax.Array:
    """S[p, j] = +-1 per bit j of p — LUT build is ``x_groups @ S.T``."""
    return jnp.asarray(_sign_matrix_np(mu), dtype)


def build_lut(x: jax.Array, mu: int) -> jax.Array:
    """Build full LUTs for activations x.

    x: [..., N] with N % mu == 0 (pad upstream). Returns [..., N//mu, 2^mu]
    where out[..., g, p] = sum_j sign_j(p) * x[..., g*mu + j].

    The contraction is a (G, mu) @ (mu, 2^mu) matmul — on TPU this runs on
    the MXU and is the systolic analogue of the paper's adder-tree generator.
    """
    n = x.shape[-1]
    if n % mu:
        raise ValueError(f"N={n} not divisible by mu={mu}")
    groups = x.reshape(*x.shape[:-1], n // mu, mu)
    s = sign_matrix(mu, x.dtype)
    return groups @ s.T                                  # [..., G, 2^mu]


def build_half_lut(x: jax.Array, mu: int) -> jax.Array:
    """hFFLUT: only the MSB=1 half of the table, [..., G, 2^(mu-1)].

    half[..., g, h] = LUT[..., g, h + 2^(mu-1)]  = x_hi + combo(x_lo..)
    Built directly from the half sign matrix (the generator tree computes
    exactly these rows, reusing low-bit partials — §III-E).
    """
    n = x.shape[-1]
    groups = x.reshape(*x.shape[:-1], n // mu, mu)
    s = sign_matrix(mu, x.dtype)[(1 << (mu - 1)):]       # MSB=1 rows
    return groups @ s.T                                  # [..., G, 2^(mu-1)]


def decode_half_lut(half: jax.Array, keys: jax.Array, mu: int) -> jax.Array:
    """Read values from an hFFLUT (paper Fig. 10 decoder).

    half: [..., G, 2^(mu-1)]; keys: int[..., G] in [0, 2^mu).
    value = msb ? half[key - H] : -half[(2^mu-1-key) - H],  H = 2^(mu-1).
    """
    hsz = 1 << (mu - 1)
    msb = keys >= hsz
    idx = jnp.where(msb, keys - hsz, (2 * hsz - 1 - keys) - hsz + hsz)
    # note: 2^mu-1-key for key<H lands in [H, 2^mu) -> subtract H:
    idx = jnp.where(msb, keys - hsz, hsz - 1 - keys)
    vals = jnp.take_along_axis(half, idx[..., None], axis=-1)[..., 0]
    return jnp.where(msb, vals, -vals)


def extract_keys(planes_pm1: jax.Array, mu: int) -> jax.Array:
    """Keys from +-1 planes: [q, out, N] -> int32 [q, out, N//mu]."""
    q, out, n = planes_pm1.shape
    bits = (planes_pm1 > 0).astype(jnp.int32).reshape(q, out, n // mu, mu)
    return (bits << jnp.arange(mu, dtype=jnp.int32)).sum(-1)


def keys_from_packed(packed: jax.Array, mu: int) -> jax.Array:
    """Extract mu-bit LUT keys directly from uint8-packed planes.

    packed: uint8[q, out, N//8]; requires 8 % mu == 0 (mu in {1,2,4,8}).
    Returns int32[q, out, N//mu]; key bit j <-> input g*mu+j (LSB-first),
    consistent with `bcq.pack_planes` and `build_lut`.
    """
    if 8 % mu:
        raise ValueError(f"mu={mu} must divide 8 for byte-packed keys")
    per_byte = 8 // mu
    q, out, nb = packed.shape
    shifts = (jnp.arange(per_byte, dtype=jnp.uint8) * mu)
    mask = jnp.uint8((1 << mu) - 1)
    keys = (packed[..., None] >> shifts) & mask          # [q, out, nb, per_byte]
    return keys.reshape(q, out, nb * per_byte).astype(jnp.int32)


# ---------------------------------------------------------------------------
# generator cost model (paper §III-E / Fig. 11)
# ---------------------------------------------------------------------------


def naive_adder_count(mu: int, half: bool = True) -> int:
    """Adds to build each LUT entry independently: (mu-1) per entry."""
    entries = 1 << (mu - 1) if half else 1 << mu
    return entries * (mu - 1)


def generator_adder_count(mu: int, half: bool = True) -> int:
    """Adds for the two-step tree generator of §III-E.

    Split the mu inputs into hi = ceil(mu/2), lo = floor(mu/2) bits.  All
    signed combos of the lo part (2^lo entries, built with a 1-add tree each
    beyond the first bit) are shared across hi patterns; hi combos likewise
    computed once; each final entry is then hi_combo + lo_combo (1 add).

    For mu=4, half=True: lo combos = 4 entries x 1 add = 4; hi combos with
    MSB fixed (+) = 2 entries x 1 add = 2; 8 final entries x 1 add = 8;
    total = 14 — matches the paper ("14 additions", 42% less than 24).
    """
    lo = mu // 2
    hi = mu - lo
    lo_adds = (1 << lo) * (lo - 1) if lo > 1 else 0
    if half:
        hi_patterns = 1 << (hi - 1)          # MSB fixed to +
    else:
        hi_patterns = 1 << hi
    hi_adds = hi_patterns * (hi - 1) if hi > 1 else 0
    final = (1 << (mu - 1) if half else 1 << mu) * 1
    return lo_adds + hi_adds + final
