"""FIGLUT-I numerics: exponent pre-alignment + integer-mantissa accumulate.

The paper's -I variant (after iFPU [22] / FIGNA [16]) aligns every FP
activation in a reduction group to the group's maximum exponent, truncating
mantissa bits that fall off, then performs the LUT/RAC arithmetic on pure
integers.  TPUs expose no separate integer-mantissa datapath worth
targeting, so this module exists for *numerical modelling*: it lets the
Table-IV-analogue benchmark quantify the tiny accuracy delta of -I vs -F
(paper: 20.89 vs 20.93 ppl on OPT-13B — i.e. negligible).

All arithmetic is emulated exactly in f32/int32 (mantissa sums of <= 2^23
stay exact in f32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bcq import BCQWeight, unpack_planes


def prealign(x: jax.Array, mantissa_bits: int = 11, axis: int = -1):
    """Align activations to the max exponent along ``axis``.

    Returns (mantissa_int f32-stored, scale) with
    x ~= mantissa * scale, |mantissa| < 2^mantissa_bits, mantissa integer.
    mantissa_bits=11 models FP16 inputs (1 implicit + 10 stored bits);
    use 8 for bf16.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    # exponent of the max: floor(log2(amax)); guard zeros
    e = jnp.floor(jnp.log2(jnp.maximum(amax, 1e-30)))
    scale = jnp.exp2(e - (mantissa_bits - 1))
    mant = jnp.round(xf / scale)                 # integer-valued, |.| < 2^mb
    return mant, scale


def prealigned_bcq_matmul(x: jax.Array, w: BCQWeight,
                          mantissa_bits: int = 11, out_dtype=None) -> jax.Array:
    """FIGLUT-I reference: integer-mantissa BCQ GEMM.

    The +-1-weighted sums over mantissas are exact integer arithmetic (the
    hardware's INT adder tree / LUT reads); only the final alpha/z scaling
    returns to FP.
    """
    out_dtype = out_dtype or x.dtype
    q, m, nb = w.packed.shape
    n_pad = nb * 8
    g = w.group_size
    n_groups = w.alpha.shape[-1]

    xf = x.astype(jnp.float32)
    if xf.shape[-1] != n_pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, n_pad - xf.shape[-1])])
    lead = xf.shape[:-1]
    x2 = xf.reshape(-1, n_pad)

    mant, scale = prealign(x2, mantissa_bits)    # [B, N], [B, 1]
    mg = mant.reshape(-1, n_groups, g)

    pm1 = unpack_planes(w.packed, dtype=jnp.float32).reshape(q, m, n_groups, g)
    # integer partial sums (exact in f32 for g*2^mb <= 2^24)
    part = jnp.einsum("bGn,qmGn->qbmG", mg, pm1,
                      preferred_element_type=jnp.float32)
    y = jnp.einsum("qbmG,qmG->bm", part, w.alpha,
                   preferred_element_type=jnp.float32)
    y = y + jnp.einsum("bG,mG->bm", mg.sum(-1), w.z,
                       preferred_element_type=jnp.float32)
    y = y * scale                                 # de-align
    return y.reshape(*lead, m).astype(out_dtype)
