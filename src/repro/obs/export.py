"""Trace exporters: Chrome trace-event JSON, per-request timelines.

``to_chrome`` renders a :class:`~repro.obs.trace.Tracer` as the Chrome
trace-event format (the JSON object form), loadable in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``:

  * process 0 — engine phases, one thread lane per ``engine/<phase>``
    track (tick, admission, prefix, prefill, decode, sync, sample,
    preempt, evict, kernel);
  * process 1 — requests, one thread lane per ``req/<uid>`` track, so
    a request's whole life (submit -> admit -> prefill chunks ->
    tokens -> retire) reads as one horizontal line.

Timestamps are microseconds (the format's native unit) since tracer
construction.  ``validate_chrome`` structurally checks an export —
tests and CI run it on real serve traces so a malformed artifact fails
loudly instead of silently refusing to load in Perfetto.

``timeline``/``format_timeline`` are the host-side view: a flat,
time-ordered table of one request's (or every request's) events for
terminals and logs — no browser required.
"""
from __future__ import annotations

import json
from typing import List, Optional

from repro.obs.trace import SCHEMA_VERSION, Tracer

_ENGINE_PID = 0
_REQ_PID = 1


def _track_lanes(tracks: List[str]):
    """Map track names onto (pid, tid) lanes; engine phases keep their
    catalogue order, request lanes sort by uid when numeric."""
    lanes = {}
    eng = [t for t in tracks if t.startswith("engine/")]
    req = [t for t in tracks if not t.startswith("engine/")]

    def _uid_key(t):
        tail = t.split("/", 1)[-1]
        return (0, int(tail)) if tail.lstrip("-").isdigit() else (1, tail)

    for tid, t in enumerate(eng):
        lanes[t] = (_ENGINE_PID, tid)
    for tid, t in enumerate(sorted(req, key=_uid_key)):
        lanes[t] = (_REQ_PID, tid)
    return lanes


def to_chrome(tracer: Tracer) -> dict:
    """Chrome trace-event JSON object for ``tracer``'s current ring."""
    lanes = _track_lanes(tracer.tracks())
    events = []
    for pid, pname in ((_ENGINE_PID, "engine phases"),
                       (_REQ_PID, "requests")):
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": pname}})
    for track, (pid, tid) in lanes.items():
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": track}})
    for ev in sorted(tracer.events, key=lambda e: e["ts"]):
        pid, tid = lanes[ev["track"]]
        out = {"name": ev["name"], "cat": ev.get("cat", "engine"),
               "ph": ev["ph"], "ts": ev["ts"], "pid": pid, "tid": tid,
               "args": ev.get("args", {})}
        if ev["ph"] == "X":
            out["dur"] = ev.get("dur", 0.0)
        if ev["ph"] == "i":
            out["s"] = "t"                      # instant scope: thread
        events.append(out)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "schema_version": SCHEMA_VERSION,
            "events": len(tracer.events),
            "dropped": tracer.dropped,
        },
    }


def save_chrome(tracer: Tracer, path: str) -> str:
    """Write the Chrome trace JSON; returns ``path``."""
    with open(path, "w") as f:
        json.dump(to_chrome(tracer), f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def validate_chrome(obj: dict) -> List[str]:
    """Structural checks on a Chrome trace export; returns a list of
    problems (empty == valid).  Checks the invariants Perfetto's loader
    and the trajectory gate rely on: every event carries the required
    fields, complete spans have non-negative durations, and every lane
    referenced by a real event has a ``thread_name`` metadata record."""
    errs = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["missing traceEvents"]
    meta = obj.get("otherData", {})
    if meta.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version {meta.get('schema_version')!r} != "
                    f"{SCHEMA_VERSION}")
    named = set()
    used = set()
    for i, ev in enumerate(obj["traceEvents"]):
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                errs.append(f"event {i}: missing {field!r}")
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "thread_name":
                named.add((ev["pid"], ev["tid"], ev["args"]["name"]))
            continue
        if ph not in ("X", "i"):
            errs.append(f"event {i}: unexpected ph {ph!r}")
            continue
        if not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
            errs.append(f"event {i}: bad ts {ev.get('ts')!r}")
        if ph == "X" and (not isinstance(ev.get("dur"), (int, float))
                          or ev["dur"] < 0):
            errs.append(f"event {i}: X span with bad dur {ev.get('dur')!r}")
        used.add((ev["pid"], ev["tid"]))
    lanes_named = {(p, t) for p, t, _ in named}
    for lane in used - lanes_named:
        errs.append(f"lane {lane} has events but no thread_name metadata")
    return errs


# ---------------------------------------------------------------------------
# host-side timeline table
# ---------------------------------------------------------------------------

def timeline(tracer: Tracer, uid=None) -> List[dict]:
    """Flat time-ordered rows; ``uid`` filters to one request's track
    plus the engine events that name it in their args."""
    rows = []
    want = None if uid is None else f"req/{uid}"
    for ev in sorted(tracer.events, key=lambda e: e["ts"]):
        args = ev.get("args", {})
        if want is not None and ev["track"] != want \
                and args.get("uid") != uid:
            continue
        rows.append({
            "ts_ms": ev["ts"] / 1e3,
            "dur_ms": ev.get("dur", 0.0) / 1e3,
            "track": ev["track"],
            "name": ev["name"],
            "tick": args.get("tick", ""),
            "args": {k: v for k, v in args.items() if k != "tick"},
        })
    return rows


def format_timeline(tracer: Tracer, uid=None,
                    max_rows: Optional[int] = None) -> str:
    """Fixed-width text rendering of :func:`timeline`."""
    rows = timeline(tracer, uid)
    clipped = 0
    if max_rows is not None and len(rows) > max_rows:
        clipped = len(rows) - max_rows
        rows = rows[:max_rows]
    head = f"{'ts_ms':>10} {'dur_ms':>9} {'tick':>5}  " \
           f"{'track':<18} {'event':<24} args"
    lines = [head, "-" * len(head)]
    for r in rows:
        args = " ".join(f"{k}={v}" for k, v in r["args"].items()
                        if not isinstance(v, dict))
        lines.append(f"{r['ts_ms']:>10.3f} {r['dur_ms']:>9.3f} "
                     f"{str(r['tick']):>5}  {r['track']:<18} "
                     f"{r['name']:<24} {args}")
    if clipped:
        lines.append(f"... ({clipped} more rows)")
    return "\n".join(lines)
