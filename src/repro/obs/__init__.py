"""``repro.obs`` — serving observability: event-level traces + exporters.

The tracing counterpart to ``serve/metrics.py``'s aggregates: where
``ServeMetrics`` says *what* regressed (TTFT p95, tokens/s), a
:class:`Tracer` threaded through the engine says *which tick, which
request, which phase* — and ``export`` renders it as Chrome trace-event
JSON (Perfetto / ``chrome://tracing``) or a per-request timeline table.
See ``docs/observability.md``.
"""
from repro.obs.export import (format_timeline, save_chrome, timeline,
                              to_chrome, validate_chrome)
from repro.obs.trace import (ENGINE_TRACKS, NULL, SCHEMA_VERSION, NullTracer,
                             Tracer, activate, get_active,
                             record_kernel_config, req_track, set_active)

__all__ = [
    "ENGINE_TRACKS", "NULL", "SCHEMA_VERSION", "NullTracer", "Tracer",
    "activate", "format_timeline", "get_active", "record_kernel_config",
    "req_track", "save_chrome", "set_active", "timeline", "to_chrome",
    "validate_chrome",
]
