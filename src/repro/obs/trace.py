"""Event-level serving trace: a bounded ring of spans and instants.

``Tracer`` is the low-overhead recorder the serving stack threads its
hooks through (``serve/engine.py``, ``serve/scheduler.py``,
``tune/dispatch.py``).  Design constraints, in order:

  * **cheap when off** — engines hold a :data:`NULL` tracer by default;
    every hook is a no-op method call, no branching at call sites;
  * **bounded** — events land in a ring buffer (``capacity`` newest
    kept, ``dropped`` counts the rest), so a week-long serve cannot OOM
    the host because someone left tracing on;
  * **deterministic under test** — the clock is injectable (tests pass
    a fake), timestamps are microseconds since tracer construction;
  * **schema-versioned** — every exported artifact carries
    :data:`SCHEMA_VERSION` so downstream consumers (Perfetto loaders,
    the perf-trajectory gate, future async-loop debugging) can detect
    drift.

Events are plain dicts (see :meth:`Tracer.emit`) with two shapes:
complete spans (``ph == "X"``, with ``dur``) and instants
(``ph == "i"``).  Every event lives on a *track*: ``"engine/<phase>"``
for engine phases (tick, admission, prefix, prefill, decode, sync,
sample, preempt, evict, kernel) or ``"req/<uid>"`` for per-request
timelines.  ``obs/export.py`` maps tracks onto Chrome trace-event
process/thread lanes.

Double-buffered ticks (``PagedServeEngine.step_async``) interleave the
lanes on purpose: tick N's ``decode_dispatch`` span (``engine/decode``,
``mode="async"``) precedes tick N-1's ``device_sync`` span inside the
same ``tick`` span — the overlap the async host loop exists for is
directly visible as that ordering.  Sync spans carry ``sync_tick`` (the
tick whose tokens they wait for) and token instants on ``req/<uid>``
tracks consequently land one tick after their ``decode_dispatch``; the
tick-top deadline sweep and cancellations add ``deadline`` / ``fail``
instants on the request track.

The module-level *active tracer* is how code that cannot be handed a
tracer instance (the ``tune.dispatch`` config resolver, called from
deep inside op wrappers) still records: engines ``set_active`` their
tracer at construction and dispatch calls
:func:`record_kernel_config`, which no-ops unless a tracer is active.
"""
from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager, nullcontext
from typing import Dict, List, Optional

SCHEMA_VERSION = 1

# the engine-phase track catalogue; export groups these into one
# process lane, in this order
ENGINE_TRACKS = (
    "engine/tick", "engine/admission", "engine/prefix", "engine/prefill",
    "engine/decode", "engine/sync", "engine/sample", "engine/preempt",
    "engine/evict", "engine/kernel",
)


def req_track(uid) -> str:
    """The per-request track name for a request uid."""
    return f"req/{uid}"


class _Span:
    """Class-based context manager for :meth:`Tracer.span` — spans are
    the tracer's hottest path (several per engine tick) and a generator
    contextmanager costs ~3x more per entry than this slotted object,
    which matters for the <= 5% trace-overhead budget the serving bench
    enforces."""

    __slots__ = ("tr", "name", "track", "cat", "args", "t0", "bridge")

    def __init__(self, tr, name, track, cat, args):
        self.tr = tr
        self.name = name
        self.track = track
        self.cat = cat
        self.args = args
        self.bridge = None

    def __enter__(self):
        tr = self.tr
        if tr._annotation is not None:
            self.bridge = tr._annotation(self.name)
            self.bridge.__enter__()
        self.t0 = tr.now_us()
        return tr

    def __exit__(self, *exc):
        tr = self.tr
        tr.emit(self.name, "X", self.t0, self.track, self.cat,
                dur=tr.now_us() - self.t0, args=self.args)
        if self.bridge is not None:
            self.bridge.__exit__(*exc)
        return False


class Tracer:
    """Span/instant recorder over an injectable clock and a ring buffer.

    ``capacity`` bounds retained events (newest win); ``profiler_bridge``
    additionally wraps every span in a ``jax.profiler.TraceAnnotation``
    so host spans line up with device profiles captured via
    ``jax.profiler.trace`` (silently disabled when jax is unavailable —
    the tracer itself has no jax dependency).
    """

    def __init__(self, clock=time.perf_counter, capacity: int = 1 << 16,
                 profiler_bridge: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.clock = clock
        self.capacity = capacity
        self._t0 = clock()
        self._buf: deque = deque(maxlen=capacity)
        self.total = 0              # events ever emitted (incl. dropped)
        self.tick: int = -1         # engine tick, tagged onto every event
        self._annotation = None
        if profiler_bridge:
            try:
                from jax.profiler import TraceAnnotation
                self._annotation = TraceAnnotation
            except Exception:       # jax absent or too old: host-only trace
                self._annotation = None

    # ------------------------------------------------------------------
    def now_us(self) -> float:
        """Microseconds since tracer construction."""
        return (self.clock() - self._t0) * 1e6

    def emit(self, name: str, ph: str, ts: float, track: str,
             cat: str = "engine", dur: Optional[float] = None,
             args: Optional[dict] = None) -> None:
        ev = {"name": name, "ph": ph, "ts": ts, "track": track, "cat": cat}
        if dur is not None:
            ev["dur"] = dur
        a = dict(args) if args else {}
        if self.tick >= 0 and "tick" not in a:
            a["tick"] = self.tick
        if a:
            ev["args"] = a
        self._buf.append(ev)
        self.total += 1

    def instant(self, name: str, *, track: str = "engine/tick",
                cat: str = "engine", **args) -> None:
        self.emit(name, "i", self.now_us(), track, cat, args=args)

    def span(self, name: str, *, track: str = "engine/tick",
             cat: str = "engine", **args) -> "_Span":
        """Record a complete span (``ph == "X"``) around the body."""
        return _Span(self, name, track, cat, args)

    # ------------------------------------------------------------------
    @property
    def events(self) -> List[dict]:
        return list(self._buf)

    @property
    def dropped(self) -> int:
        return self.total - len(self._buf)

    def tracks(self) -> List[str]:
        """Distinct tracks with at least one event, engine lanes first
        (catalogue order), then request lanes by first appearance."""
        seen: Dict[str, None] = {}
        for ev in self._buf:
            seen.setdefault(ev["track"], None)
        eng = [t for t in ENGINE_TRACKS if t in seen]
        eng += [t for t in seen if t.startswith("engine/")
                and t not in ENGINE_TRACKS]
        return eng + [t for t in seen if not t.startswith("engine/")]

    def clear(self) -> None:
        self._buf.clear()
        self.total = 0


class NullTracer:
    """API-compatible no-op: engines hold this when tracing is off so
    hook call sites stay branch-free.  ``span`` hands back a shared
    null context; nothing is ever recorded."""

    tick = -1
    capacity = 0
    total = 0
    dropped = 0
    events: List[dict] = []

    def emit(self, *a, **kw) -> None:
        pass

    def instant(self, *a, **kw) -> None:
        pass

    def span(self, *a, **kw):
        return nullcontext()

    def now_us(self) -> float:
        return 0.0

    def tracks(self) -> List[str]:
        return []

    def clear(self) -> None:
        pass


NULL = NullTracer()

# ---------------------------------------------------------------------------
# active tracer: the escape hatch for call sites that cannot be handed a
# tracer instance (kernel-config resolution inside op wrappers)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[Tracer] = None


def set_active(tracer: Optional[Tracer]) -> None:
    global _ACTIVE
    _ACTIVE = tracer


def get_active() -> Optional[Tracer]:
    return _ACTIVE


@contextmanager
def activate(tracer: Optional[Tracer]):
    prev = get_active()
    set_active(tracer)
    try:
        yield tracer
    finally:
        set_active(prev)


def record_kernel_config(kernel: str, source: str, config, **meta) -> None:
    """Record one kernel-launch config resolution on the active tracer.

    Called by ``tune.dispatch.kernel_config`` at every resolution point
    so traces show which launches ran a *tuned* config and which fell
    back to the *heuristic* (``source``: ``"cache"`` | ``"tuned"`` |
    ``"heuristic"``).  Dispatch runs eagerly while jit traces, so these
    events mark (re)compilations, not per-tick launches.  No-op without
    an active tracer.
    """
    t = _ACTIVE
    if t is None:
        return
    t.instant(f"kernel_config:{kernel}", track="engine/kernel",
              cat="kernel", kernel=kernel, source=source,
              config=config.to_dict(), **meta)


def record_kernel_unsupported(kernel: str, reason: str, **meta) -> None:
    """Record one failed capability negotiation on the active tracer.

    Called by ``tune.dispatch.kernel_unsupported_reason`` when a probe
    rejects a kernel for a problem, with the SPECIFIC cap that failed
    (``"window"``, ``"kv_dtype"``, ``"latent"``, ``"tp"``, ...) — so a
    trace of a gathered-fallback run says *why* it gathered instead of
    collapsing every reason into one boolean.  No-op without an active
    tracer.
    """
    t = _ACTIVE
    if t is None:
        return
    t.instant(f"kernel_unsupported:{kernel}", track="engine/kernel",
              cat="kernel", kernel=kernel, reason=reason, **meta)
