"""AdamW + schedules + gradient clipping + int8 gradient compression.

Pure-JAX (no optax on this box).  State is a pytree (m, v, count) matching
params; everything shards with the params' shardings (ZeRO-style when the
params are FSDP-sharded).

Gradient compression (``compress_grads``/``decompress_grads``): per-tensor
symmetric int8 quantization with an error-feedback residual — applied
*before* the cross-pod all-reduce so the wire bytes drop 4x; the residual
carries the quantization error into the next step (Seide et al. / 1-bit
Adam lineage).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: object
    v: object


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"        # cosine | linear | constant


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps) /
                        jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        decay = (0.5 * (1 + jnp.cos(jnp.pi * frac)) if cfg.schedule == "cosine"
                 else 1.0 - frac)
    return cfg.lr * warm * decay


def init_state(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = state.count + 1
    lr = schedule_lr(cfg, count)
    b1c = 1 - cfg.beta1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.beta1 * m + (1 - cfg.beta1) * g
        v_new = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        step_ = lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - step_).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(count, new_m, new_v), metrics


# ---------------------------------------------------------------------------
# gradient compression (int8 + error feedback)
# ---------------------------------------------------------------------------


def compress_grads(grads, residual=None):
    """-> (int8 tree, scales tree, new residual).  g ~= int8 * scale."""
    if residual is None:
        residual = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        return q, scale, new_r

    flat, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    outs = [one(g, r) for g, r in zip(flat, flat_r)]
    unf = lambda i: jax.tree_util.tree_unflatten(treedef, [o[i] for o in outs])
    return unf(0), unf(1), unf(2)


def decompress_grads(q_tree, scale_tree):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree)
