"""Roofline extraction from compiled XLA artifacts.

Three terms per (arch x shape x mesh), all **per device** (cost_analysis
is per-device after SPMD partitioning — verified empirically):

    compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
    memory     = HLO_bytes / HBM_bw                (819 GB/s)
    collective = collective_bytes / link_bw        (~50 GB/s/link ICI)

``collective_bytes`` is not in cost_analysis: we parse the optimized HLO
and sum the *result* shapes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute (post-SPMD shapes are
per-device, consistent with the other two terms).

**Trip-count correction**: XLA's cost_analysis counts a while-loop body
ONCE (verified in this container), so scanned-layer models undercount by
~n_layers.  ``layer_extrapolated_costs`` therefore lowers two UNROLLED
models that differ by exactly one layer-period and extrapolates linearly
— exact for homogeneous stacks — while the full scanned model is still
compiled for the memory-fit proof.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# hardware constants (assignment: TPU v5e-class target)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[.\w]*\(", re.MULTILINE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """{'all-reduce': bytes, ...} summed over the module (per device)."""
    out: dict = {}
    for shape_str, op in _COLL_RE.findall(hlo_text):
        out[op] = out.get(op, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device
    coll_bytes: float            # per device
    coll_breakdown: dict
    arg_bytes: float = 0.0
    temp_bytes: float = 0.0
    out_bytes: float = 0.0
    alias_bytes: float = 0.0     # donated buffers (counted once)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def fraction_of_roofline(self) -> float:
        """compute term / binding term — 1.0 means compute-roofline-bound."""
        return self.t_compute / max(self.t_bound, 1e-30)

    def device_memory_gb(self) -> float:
        return (self.arg_bytes + self.temp_bytes + self.out_bytes
                - self.alias_bytes) / 2**30

    def row(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_accessed,
            "coll_bytes_per_dev": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.fraction_of_roofline(),
            "device_mem_gb": self.device_memory_gb(),
            "coll_breakdown": self.coll_breakdown,
        }


def from_compiled(compiled) -> Roofline:
    """Roofline terms straight from one compiled executable."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):        # older jax: one dict per device
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    coll = collective_bytes(text)
    return Roofline(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        arg_bytes=float(ma.argument_size_in_bytes),
        temp_bytes=float(ma.temp_size_in_bytes),
        out_bytes=float(ma.output_size_in_bytes),
        alias_bytes=float(ma.alias_size_in_bytes),
    )


def extrapolate(r1: Roofline, r2: Roofline, n1: float, n2: float,
                n_total: float, mem: Optional[Roofline] = None) -> Roofline:
    """Linear layer-count extrapolation (exact for homogeneous periods).

    r1/r2: rooflines of unrolled models with n1/n2 layer-periods;
    n_total: periods in the full model; mem: optional full-model (scanned)
    compile supplying the true memory-fit numbers.
    """
    def ext(a, b):
        slope = (b - a) / max(n2 - n1, 1e-9)
        return a + slope * (n_total - n1)

    coll = {k: ext(r1.coll_breakdown.get(k, 0), r2.coll_breakdown.get(k, 0))
            for k in set(r1.coll_breakdown) | set(r2.coll_breakdown)}
    base = mem if mem is not None else r2
    return Roofline(
        flops=ext(r1.flops, r2.flops),
        bytes_accessed=ext(r1.bytes_accessed, r2.bytes_accessed),
        coll_bytes=ext(r1.coll_bytes, r2.coll_bytes),
        coll_breakdown=coll,
        arg_bytes=base.arg_bytes, temp_bytes=base.temp_bytes,
        out_bytes=base.out_bytes, alias_bytes=base.alias_bytes,
    )


def serve_analytic_bytes(cfg, shape, n_active_params: float, bits: int,
                         n_model: int = 16, n_data: int = 16) -> dict:
    """Analytic per-device HBM bytes for one serve step, three variants.

    The CPU dry-run backend neither fuses the dequant chain nor performs
    in-place cache updates, so its `bytes accessed` overstates a TPU
    execution.  These closed-form numbers use each execution path's
    *intended* traffic: the Pallas kernel's is fixed by its BlockSpecs
    (weights stream packed, LUT/dense tiles live in VMEM only) and is
    validated against ref.py in tests.

      dense_bf16  — FPE baseline: bf16 weights (2 B/w)
      xla_bf16    — bcq_xla fused dequant: packed read + bf16 dense (2.56 B/w)
      kernel_q    — lut_gemm/bcq_matmul Pallas kernels: packed only (q/8 B/w)

    plus the (shared) KV/state-cache read traffic per step.
    """
    w_global = n_active_params
    w_dense = 2.0 * w_global / n_model
    w_packed = (bits / 8.0) * w_global / n_model
    b_loc = shape.global_batch // n_data

    cache = 0.0
    if cfg.is_ssm_only or cfg.is_hybrid:
        d_inner = cfg.ssm_expand * cfg.d_model
        h = d_inner // cfg.ssm_head_dim
        n_mamba = sum(1 for i in range(cfg.n_layers)
                      if cfg.layer_kind(i) == "mamba")
        state = b_loc * max(h // n_model, 1) * cfg.ssm_head_dim * cfg.ssm_state * 4
        cache += n_mamba * state * 2                    # read + write
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")
    if n_attn and cfg.attention != "none":
        length = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
        if cfg.attention == "mla":
            per = b_loc * (length // n_model) * \
                (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * 2
        else:
            hd = cfg.head_dim_
            hkv = cfg.n_kv_heads
            shard = n_model if hkv % n_model == 0 else \
                (n_model if hd % n_model == 0 else 1)
            per = b_loc * length * hkv * hd * 2 * 2 // shard   # k + v
        cache += n_attn * per

    out = {}
    for name, wb in [("dense_bf16", w_dense), ("xla_bf16", w_dense + w_packed),
                     ("kernel_q", w_packed)]:
        total = wb + cache
        out[name] = {"bytes_per_dev": total, "t_memory_s": total / HBM_BW,
                     "weight_bytes": wb, "cache_bytes": cache}
    return out


def model_flops(cfg, shape, n_active_params: float,
                n_total_params: float) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) — global, forward+
    backward for train; 2*N*D forward-only for serving shapes.

    Encoder-decoder: the encoder's params see encoder_seq frames, not the
    decoder token count — counted separately (whisper's 24+24 layers over
    1500-frame inputs otherwise overstate useful FLOPs ~2x).
    """
    mult = 6.0 if shape.kind == "train" else 2.0
    dec_tokens = (shape.global_batch * shape.seq_len
                  if shape.kind != "decode" else shape.global_batch)
    if cfg is not None and getattr(cfg, "is_encdec", False):
        # split params by stack depth share (enc and dec layers are same-width)
        enc_frac = cfg.n_encoder_layers / (cfg.n_encoder_layers + cfg.n_layers)
        n_enc = n_active_params * enc_frac
        n_dec = n_active_params - n_enc
        enc_tokens = shape.global_batch * cfg.encoder_seq \
            if shape.kind != "decode" else 0      # encoder cached at decode
        return mult * (n_dec * dec_tokens + n_enc * enc_tokens)
    return mult * n_active_params * dec_tokens
