"""Whole-model post-training quantization into plane bundles.

Walks a params tree, finds linear weights by leaf name, and replaces each
with a :class:`~repro.core.plane.PlaneBundle` — after which every
``linear_apply`` call site executes the LUT/BCQ/ternary path of the
configured backend.  This is the internal PTQ engine behind the
declarative entry point ``repro.quant.quantize_model(params, QuantSpec,
axes)``.  Supports:

  * per-layer bit maps (mixed precision, Fig. 17) — fractional widths
    below 2 (the :data:`~repro.core.plane.TERNARY_BITS` sentinel) route
    that layer onto the ternary format (MxGLUT-style format mixing),
  * "bcq" (alternating non-uniform), "rtn" (uniform-as-BCQ) and
    "ternary" (sign+mask bundle) methods,
  * scan-stacked params ([L, out, in] -> packed [L, q, out, in/8] so
    lax.scan still slices layer-by-layer),
  * expert banks ([E, f, d] folded to [E*f, d]; rows are independent so
    this is exact per-expert quantization),
  * abstract mode for the dry-run (ShapeDtypeStructs, no allocation).

Weight leaves quantized (QUANT_KEYS): attention/MLA projections, MLP and
expert matrices, SSM in/out projections.  Routers, norms, biases, convs
and embeddings stay FP (standard weight-only practice; embeddings are
lookups, not GEMMs).
"""
from __future__ import annotations

import functools
from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcq as bcq_mod
from repro.core.bcq import BCQWeight

QUANT_KEYS = {
    "q", "k", "v", "o", "q_a", "q_b", "kv_a", "kv_b",
    "gate", "up", "down", "shared_gate", "shared_up", "shared_down",
    "in_proj", "out_proj", "unembed",
}

# leaves that match QUANT_KEYS but must stay FP
_SKIP_KEYS = {"router", "conv_w", "conv_b", "tok", "pos"}


def _walk(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, path + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, path + (i,))
    else:
        yield path, tree


def _set_path(tree, path, value):
    if not path:
        return value
    head, rest = path[0], path[1:]
    if isinstance(tree, dict):
        out = dict(tree)
        out[head] = _set_path(tree[head], rest, value)
        return out
    out = list(tree)
    out[head] = _set_path(tree[head], rest, value)
    return type(tree)(out) if isinstance(tree, tuple) else out


_INPUT_AXES = {"embed", "lora", "mlp", "heads", "kv_heads", "vocab"}


def _is_quant_leaf(path, leaf, axes=None) -> bool:
    """True for genuine [out, in] GEMM weights.

    Name collision guard: qwen's QKV *bias* is also called "q_b" (like
    MLA's q_b projection) and, scan-stacked, is 2-D — so when logical
    axes are available we additionally require the last (input) axis to
    be a contraction axis, which biases ('heads',) fail.
    """
    name = path[-1] if path else ""
    if name in _SKIP_KEYS or name not in QUANT_KEYS:
        return False
    if not (hasattr(leaf, "ndim") and leaf.ndim >= 2):
        return False
    if axes:
        rank = len(axes) - (1 if axes[0] == "layers" else 0)
        return axes[-1] in _INPUT_AXES and rank >= 2
    return True


def collect_linears(params, axes_tree=None) -> dict:
    """{'/'.join(path): array} for every quantizable weight.

    Pass ``axes_tree`` (``Model.axes()``) to apply the same logical-axes
    name-collision guard the quantizer uses (qwen's scan-stacked q_b
    bias), so bit plans and quantization agree on the layer set.
    """
    out = {}
    for p, l in _walk(params):
        axes = _axes_of(axes_tree, p) if axes_tree is not None else None
        if _is_quant_leaf(p, l, axes):
            out["/".join(map(str, p))] = l
    return out


def _axes_of(axes_tree, path):
    node = axes_tree
    try:
        for p in path:
            node = node[p]
        return node
    except (KeyError, IndexError, TypeError):
        return None


_BATCH_AXES = ("layers", "experts")


def _lead_batch(axes, ndim):
    """# of leading dims kept as quantization batch dims.

    'layers' (lax.scan slices it) and 'experts' (EP-sharded; folding E into
    the row dim would merge a sharded dim and force an all-gather on every
    dequantize — measured ~65 GB/step on mixtral decode).
    """
    n = 0
    axes = axes or ()
    while n < len(axes) and axes[n] in _BATCH_AXES and ndim - n > 2:
        n += 1
    return n


def _quantize_leaf(w, axes, bits, method, group_size, iters):
    """Quantize one weight leaf, handling stacked leading batch dims.

    ``bits`` may be fractional: widths below 2 select the ternary format
    regardless of ``method`` (the mixed-precision planner's sentinel).
    """
    # format registry lookup (lazy import: repro.quant.api imports this
    # module); every registered format lowers into PlaneBundle planes
    from repro.quant.formats import format_for_bits
    fmt = format_for_bits(method, bits)
    nb = _lead_batch(axes, w.ndim)

    def quant2d(w2):
        return fmt.quantize(w2, bits=fmt.plane_bits(bits),
                            group_size=group_size, iters=iters)

    if nb:
        lead = w.shape[:nb]
        rows = int(np.prod(w.shape[nb:-1]))
        cols = w.shape[-1]
        w3 = w.reshape(int(np.prod(lead)), rows, cols).astype(jnp.float32)
        stacked = jax.lax.map(lambda wi: quant2d(wi), w3)
        unflat = lambda a: a.reshape(*lead, *a.shape[1:])
        return BCQWeight(packed=unflat(stacked.packed),
                         alpha=unflat(stacked.alpha),
                         z=None if stacked.z is None else unflat(stacked.z),
                         group_size=int(group_size),
                         in_features=cols, out_features=rows,
                         kind=stacked.kind)
    rows = int(np.prod(w.shape[:-1]))
    return quant2d(w.reshape(rows, w.shape[-1]).astype(jnp.float32))


def quantize_model(params, axes_tree=None, *, bits=4, method: str = "bcq",
                   group_size: int = 128, iters: int = 5,
                   bit_map: Optional[Mapping[str, float]] = None):
    """Replace every quantizable linear with a PlaneBundle.

    bit_map: optional {'path/like/this': bits} per-layer override (mixed
    precision; fractional widths below 2 select ternary).  axes_tree:
    logical-axes tree (Model.axes()) used to detect scan-stacked
    weights; optional for unrolled models.

    This is the PTQ *engine*; the public surface is the declarative
    entry point, which also plans mixed precision and returns a
    manifest::

        from repro.quant import QuantSpec, quantize_model
        qparams, manifest = quantize_model(params, QuantSpec(...), axes)
    """
    out = params
    for path, leaf in list(_walk(params)):
        axes = _axes_of(axes_tree, path) if axes_tree is not None else None
        if not _is_quant_leaf(path, leaf, axes):
            continue
        key = "/".join(map(str, path))
        b = bit_map.get(key, bits) if bit_map else bits
        wq = _quantize_leaf(leaf, axes, b, method, group_size, iters)
        out = _set_path(out, path, wq)
    return out


def abstract_quantized_params(abstract_tree, axes_tree, *, bits=4,
                              group_size: int = 128):
    """ShapeDtypeStruct version of quantize_model for the dry-run.

    Maps each quantizable linear's SDS to the BCQWeight SDS bundle with the
    same stacking rules — no weight is ever allocated.
    """
    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    out = abstract_tree
    for path, leaf in list(_walk(abstract_tree)):
        axes = _axes_of(axes_tree, path)
        if not _is_quant_leaf(path, leaf, axes):
            continue
        nb = _lead_batch(axes, len(leaf.shape))
        lead_dims = tuple(leaf.shape[:nb])
        rows = int(np.prod(leaf.shape[nb:-1]))
        cols = leaf.shape[-1]
        npad = -(-cols // group_size) * group_size
        ngr = npad // group_size
        wq = BCQWeight(
            packed=sds((*lead_dims, bits, rows, npad // 8), jnp.uint8),
            alpha=sds((*lead_dims, bits, rows, ngr), jnp.float32),
            z=sds((*lead_dims, rows, ngr), jnp.float32),
            group_size=group_size, in_features=cols, out_features=rows,
        )
        out = _set_path(out, path, wq)
    return out
