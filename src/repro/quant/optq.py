"""OPTQ/GPTQ — the uniform-quantization baseline the paper compares against.

The paper's Fig 17 FIGNA rows use OPTQ [10] (Frantar et al.): second-order
post-training quantization.  Columns are quantized one at a time; the
rounding error of each column is propagated into the not-yet-quantized
columns through the inverse-Hessian factor, minimizing output error on a
calibration set:

    H     = 2 X^T X + lambda I          (X: calibration activations)
    Hinv  = cholesky(H^{-1})            (upper)
    for i in columns:
        q_i   = round_to_grid(w_i)
        err_i = (w_i - q_i) / Hinv[i, i]
        W[:, i+1:] -= err_i (x) Hinv[i, i+1:]

The quantized integer codes map EXACTLY into the BCQ(+offset) format
(alpha_i = s*2^{i-1}, z = s*((2^q-1)/2 - z0)), so the FIGLUT engine
executes OPTQ checkpoints natively — the interoperability the paper's
Table I claims for BCQ-format accelerators.

Pure JAX, jittable (lax.fori over columns with dynamic slices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bcq import BCQWeight, pack_planes


def _grid_quant(col, scale, zero, levels):
    """Round one column to its per-row uniform grid."""
    q = jnp.clip(jnp.round(col / scale + zero), 0, levels)
    return (q - zero) * scale


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "damp"))
def _optq_core(w, h, bits, group_size, damp=0.01):
    """w: [out, in] f32; h: [in, in] Hessian (2 X^T X). Returns wq dense +
    per-(row, group) scale/zero."""
    out, n = w.shape
    levels = (1 << bits) - 1
    g = group_size
    n_groups = n // g

    # dampened inverse Hessian, Cholesky factor (upper) as in GPTQ
    diag_mean = jnp.mean(jnp.diag(h))
    hd = h + damp * diag_mean * jnp.eye(n, dtype=h.dtype)
    hinv = jnp.linalg.inv(hd)
    hinv_u = jnp.linalg.cholesky(hinv, upper=True)        # [in, in]

    # per-group asymmetric grids from the (pre-compensation) weights
    wg = w.reshape(out, n_groups, g)
    wmin = wg.min(-1)
    wmax = wg.max(-1)
    scale = jnp.maximum((wmax - wmin) / levels, 1e-12)    # [out, G]
    zero = jnp.round(-wmin / scale)                       # [out, G]

    def body(i, carry):
        w_work, w_q = carry
        col = jax.lax.dynamic_slice_in_dim(w_work, i, 1, axis=1)[:, 0]
        gi = i // g
        s = jax.lax.dynamic_slice_in_dim(scale, gi, 1, axis=1)[:, 0]
        z = jax.lax.dynamic_slice_in_dim(zero, gi, 1, axis=1)[:, 0]
        qcol = _grid_quant(col, s, z, levels)
        d = jax.lax.dynamic_slice(hinv_u, (i, i), (1, 1))[0, 0]
        err = (col - qcol) / jnp.maximum(d, 1e-9)         # [out]
        # propagate into remaining columns:  w[:, i+1:] -= err * Hinv_u[i, i+1:]
        row = jax.lax.dynamic_slice_in_dim(hinv_u, i, 1, axis=0)[0]  # [in]
        mask = (jnp.arange(n) > i).astype(w.dtype)
        w_work = w_work - jnp.outer(err, row * mask)
        w_q = jax.lax.dynamic_update_slice_in_dim(
            w_q, qcol[:, None], i, axis=1)
        return w_work, w_q

    w_q0 = jnp.zeros_like(w)
    _, w_q = jax.lax.fori_loop(0, n, body, (w, w_q0))
    return w_q, scale, zero


def uniform_to_bcq(w_q: jax.Array, scale: jax.Array, zero: jax.Array,
                   bits: int, group_size: int, in_features: int) -> BCQWeight:
    """Exact mapping of uniform (code, scale, zero) grids into BCQ form."""
    out, n = w_q.shape
    levels = (1 << bits) - 1
    n_groups = n // group_size
    wg = w_q.reshape(out, n_groups, group_size)
    codes = jnp.clip(jnp.round(wg / scale[..., None] + zero[..., None]),
                     0, levels).astype(jnp.int32)
    planes = []
    for i in range(bits):
        bit = (codes >> i) & 1
        planes.append((bit * 2 - 1).astype(jnp.float32))
    planes = jnp.stack(planes).reshape(bits, out, n)
    pow2 = (2.0 ** jnp.arange(bits, dtype=jnp.float32)) / 2.0
    alpha = scale[None] * pow2[:, None, None]
    z = scale * (levels / 2.0 - zero)
    return BCQWeight(packed=pack_planes(planes), alpha=alpha.astype(jnp.float32),
                     z=z.astype(jnp.float32), group_size=group_size,
                     in_features=in_features, out_features=out)


def optq_quantize(w: jax.Array, x_cal: jax.Array, bits: int,
                  group_size: int = 128, damp: float = 0.01) -> BCQWeight:
    """OPTQ-quantize one [out, in] weight given calibration inputs
    x_cal [n_samples, in]; returns the BCQ-format weight FIGLUT executes."""
    w = jnp.asarray(w, jnp.float32)
    out, n = w.shape
    g = int(group_size)
    npad = -(-n // g) * g
    if npad != n:
        w = jnp.pad(w, ((0, 0), (0, npad - n)), mode="edge")
        x_cal = jnp.pad(jnp.asarray(x_cal, jnp.float32),
                        ((0, 0), (0, npad - n)))
    x_cal = jnp.asarray(x_cal, jnp.float32)
    h = 2.0 * (x_cal.T @ x_cal) / x_cal.shape[0]
    w_q, scale, zero = _optq_core(w, h, int(bits), g, damp)
    return uniform_to_bcq(w_q, scale, zero, int(bits), g, n)


def capture_calibration(model, params, batches, max_samples: int = 256):
    """Run eager forward passes and record each linear's input activations.

    Returns {path: f32[n_samples, in_features]} keyed by param path —
    the calibration sets OPTQ consumes (the paper's OPTQ baseline uses a
    WikiText-2 calibration set the same way).
    """
    from repro.core import quantized_linear as ql
    from repro.quant.ptq import _walk, _is_quant_leaf

    id2path = {}
    for path, leaf in _walk(params):
        if _is_quant_leaf(path, leaf) and hasattr(leaf, "shape"):
            id2path[id(leaf)] = path
    store: dict = {}

    def hook(w, x):
        p = id2path.get(id(w))
        if p is None:
            return
        flat = np.asarray(x.astype(jnp.float32)).reshape(-1, x.shape[-1])
        take = min(max_samples, flat.shape[0])
        idx = np.random.default_rng(0).choice(flat.shape[0], take,
                                              replace=False)
        store.setdefault(p, []).append(flat[idx])

    ql.set_capture(hook)
    try:
        for batch in batches:
            model.forward(params, batch)          # eager
    finally:
        ql.set_capture(None)
    return {p: np.concatenate(v)[:max_samples] for p, v in store.items()}


def optq_quantize_model(params, axes_tree, calib_fn, *, bits=4,
                        group_size: int = 64, keys=None):
    """OPTQ over a model's linears using layer-input calibration.

    calib_fn(path) -> [n_samples, in_features] calibration activations for
    the weight at ``path`` (callers typically capture layer inputs with a
    forward hook pass; benchmarks use input-distribution surrogates).
    """
    from repro.quant.ptq import _walk, _set_path, _is_quant_leaf, _axes_of
    out = params
    for path, leaf in list(_walk(params)):
        axes = _axes_of(axes_tree, path)
        if not _is_quant_leaf(path, leaf, axes):
            continue
        if keys is not None and path[-1] not in keys:
            continue
        if leaf.ndim != 2:
            continue                      # stacked weights: PTQ path covers
        x_cal = calib_fn(path, leaf.shape[-1])
        wq = optq_quantize(leaf, x_cal, bits=bits, group_size=group_size)
        out = _set_path(out, path, wq)
    return out
