"""The public quantization entry point: ``quantize_model(params, spec)``.

Ties the pieces together:

  1. resolve the per-layer bit plan from the spec — a uniform integer,
     or (fractional ``bits`` like ``2.4``) a sensitivity-driven mixed-
     precision allocation via :func:`repro.core.mixed_precision.
     allocate_bits` over every quantizable linear (paper Fig. 17), plus
     explicit per-layer ``spec.overrides`` pins applied last;
  2. quantize the tree through the format registry
     (:mod:`repro.quant.formats`) with the scan/expert stacking rules of
     :mod:`repro.quant.ptq`;
  3. return the quantized tree *and* a :class:`QuantManifest` — per-layer
     format/plane-bits/bytes plus achieved parameter-weighted average
     bits — which the launcher prints, CI uploads, and the quantized
     checkpoint embeds.
"""
from __future__ import annotations

import dataclasses
import functools
import json
from typing import Any, Mapping, Optional, Tuple

import numpy as np

from repro.core import mixed_precision as mp
from repro.core.bcq import BCQWeight
from repro.quant import formats as formats_mod
from repro.quant.spec import QuantSpec


# ---------------------------------------------------------------------------
# manifest
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QuantManifest:
    """What actually got quantized, layer by layer."""

    spec: dict
    layers: list                      # [{path, format, plane_bits, ...}]
    n_layers: int = 0
    n_weights: int = 0                # scalar weights quantized
    dense_bytes: int = 0              # bf16 baseline footprint
    quant_bytes: int = 0              # packed planes + scales
    avg_plane_bits: float = 0.0       # parameter-weighted stored planes
    avg_effective_bits: float = 0.0   # quant_bytes * 8 / n_weights

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def from_dict(cls, d: Mapping) -> "QuantManifest":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    def summary(self) -> str:
        comp = (self.dense_bytes / self.quant_bytes
                if self.quant_bytes else float("inf"))
        return (f"{self.n_layers} layers / {self.n_weights:,} weights "
                f"quantized: avg {self.avg_plane_bits:.2f} plane-bits "
                f"({self.avg_effective_bits:.2f} stored bits/weight incl. "
                f"scales), {self.quant_bytes/2**20:.1f} MiB vs "
                f"{self.dense_bytes/2**20:.1f} MiB bf16 ({comp:.1f}x)")


# ---------------------------------------------------------------------------
# bit planning
# ---------------------------------------------------------------------------


def plan_bits(linears: Mapping[str, Any], spec: QuantSpec,
              x_cal: Optional[Mapping[str, Any]] = None) -> dict:
    """Per-layer bit plan for a spec: uniform, or mixed for fractional bits.

    Stacked leaves ([L, out, in] / [E, f, d]) are handled by the
    sensitivity probe directly (it flattens and row-subsamples); sizes
    stay parameter-weighted over the full leaves.
    """
    fmt = formats_mod.get_format(spec.format)
    unknown = [k for k in spec.overrides_map if k not in linears]
    if unknown:
        raise ValueError(
            f"spec.overrides name layers that are not quantizable linears: "
            f"{unknown}; known layers: {sorted(linears)}")
    if fmt.fixed_plane_bits is not None:
        if spec.overrides:
            raise ValueError(
                f"format {spec.format!r} stores a fixed "
                f"{fmt.fixed_plane_bits} planes per layer; per-layer bit "
                "overrides are not supported")
        return {k: fmt.fixed_plane_bits for k in linears}
    if spec.bits < 1:
        raise ValueError(
            f"spec.bits={spec.bits:g}: need >= 1 bit to quantize "
            "(an unquantized model shouldn't call quantize_model)")

    if spec.is_fractional:
        # probe with the format that will actually be applied — BCQ's
        # reconstruction error misranks layers for rtn/other formats,
        # and sub-2-bit candidates (the ternary sentinel) must be
        # measured with the ternary quantizer
        def _probe_quantize(w2, *, bits, group_size, iters):
            f = formats_mod.format_for_bits(spec.format, bits)
            return f.quantize(w2, bits=f.plane_bits(max(bits, 1)),
                              group_size=group_size, iters=iters)
        sens = functools.partial(mp.layer_sensitivity, iters=2, max_rows=192,
                                 quantizer=_probe_quantize)
        plan = mp.allocate_bits(linears, target_avg_bits=spec.bits,
                                candidates=spec.candidate_bits,
                                group_size=spec.group_size, x_cal=x_cal,
                                sensitivity_fn=sens)
    else:
        plan = {k: spec.int_bits for k in linears}

    for key, b in spec.overrides_map.items():
        if key in plan:
            plan[key] = float(b) if float(b) < 2 else int(b)
    return plan


# ---------------------------------------------------------------------------
# quantize_model
# ---------------------------------------------------------------------------


def quantize_model(params, spec: QuantSpec, axes_tree=None, *,
                   x_cal: Optional[Mapping[str, Any]] = None,
                   ) -> Tuple[Any, QuantManifest]:
    """Quantize every eligible linear of ``params`` per ``spec``.

    Returns ``(quantized_params, manifest)``.  ``axes_tree``
    (``Model.axes()``) enables scan-stack detection; ``x_cal`` optionally
    supplies per-layer calibration activations for the mixed-precision
    sensitivity probe.
    """
    from repro.quant import ptq  # lazy: ptq uses the format registry

    fmt = formats_mod.get_format(spec.format)
    linears = ptq.collect_linears(params, axes_tree)
    plan = plan_bits(linears, spec, x_cal=x_cal)

    qparams = ptq.quantize_model(
        params, axes_tree, bits=fmt.plane_bits(max(spec.bits, 1)),
        method=spec.format, group_size=spec.group_size, iters=spec.iters,
        bit_map=plan)

    manifest = build_manifest(qparams, spec, plan, linears,
                              axes_tree=axes_tree)
    return qparams, manifest


def build_manifest(qparams, spec: QuantSpec, plan: Mapping[str, int],
                   linears: Mapping[str, Any], axes_tree=None) -> QuantManifest:
    from repro.quant import ptq  # lazy: ptq uses the format registry

    quantized = {"/".join(map(str, p)): leaf
                 for p, leaf in ptq._walk(qparams)
                 if isinstance(leaf, BCQWeight)}
    layers, n_weights, dense_bytes, quant_bytes, plane_acc = [], 0, 0, 0, 0.0
    for key in sorted(quantized):
        wq = quantized[key]
        # packed is [*lead, q, rows, in/8]; the plane axis is always -3
        planes = int(wq.packed.shape[-3])
        shape = tuple(int(s) for s in np.shape(linears[key])) \
            if key in linears else None
        n = int(np.prod(shape)) if shape else \
            int(np.prod(wq.packed.shape[:-3])) * wq.out_features * wq.in_features
        # nbytes() reads the bundle that was actually stored — for
        # ternary that is sign+mask planes, ONE alpha row and no offset,
        # so the manifest no longer overstates ternary model size
        qb = int(wq.nbytes())
        layers.append({
            "path": key,
            "format": "ternary" if wq.kind == "ternary" else spec.format,
            "plane_bits": planes,
            # information-theoretic width (ternary stores 2 planes but
            # carries log2(3) bits); == plane_bits for dense-coded formats
            "effective_bits": float(wq.effective_bits),
            "group_size": int(wq.group_size),
            "shape": list(shape) if shape else None,
            "dense_bytes": 2 * n, "quant_bytes": qb,
        })
        n_weights += n
        dense_bytes += 2 * n
        quant_bytes += qb
        plane_acc += planes * n
    avg_plane = plane_acc / n_weights if n_weights else 0.0
    return QuantManifest(
        spec=spec.to_dict(), layers=layers, n_layers=len(layers),
        n_weights=n_weights, dense_bytes=dense_bytes,
        quant_bytes=quant_bytes, avg_plane_bits=avg_plane,
        avg_effective_bits=(quant_bytes * 8 / n_weights) if n_weights else 0.0)
