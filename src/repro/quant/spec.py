"""QuantSpec — the single declarative description of a quantization run.

One frozen, hashable dataclass replaces the ``bits/method/group_size/
iters/backend`` kwargs that used to be hand-threaded through
``configs/base.py``, ``quantize/ptq.py`` and ``launch/serve.py``:

    spec = QuantSpec(format="bcq", bits=2.4, group_size=64, backend="auto")
    qparams, manifest = repro.quant.quantize_model(params, spec, model.axes())

Fields:
  * ``format``      — key into the format registry (:mod:`repro.quant.formats`):
                      ``bcq`` (alternating non-uniform), ``rtn`` (uniform
                      round-to-nearest mapped exactly into BCQ planes; alias
                      ``uniform``), ``ternary`` ({-a, 0, +a} mapped into two
                      BCQ planes).
  * ``bits``        — integer, or a *fractional average* (e.g. ``2.4``) which
                      triggers sensitivity-driven mixed precision via
                      :func:`repro.core.mixed_precision.allocate_bits`
                      (paper Fig. 17 / the 2.4-bit iso-perplexity point).
  * ``group_size``  — input-dim scaling-factor group (LUT-GEMM convention).
  * ``iters``       — alternating-refinement rounds for the ``bcq`` solver.
  * ``backend``     — execution *preference* into the backend registry
                      (:mod:`repro.quant.backends`): ``auto`` lets capability
                      negotiation pick; an explicit name is honoured when the
                      backend supports the weight, otherwise the fallback
                      chain (pallas -> bcq_xla -> dense) engages.
  * ``candidates``  — mixed-precision candidate bit-widths; ``()`` derives
                      ``(floor(bits), ceil(bits), ceil(bits)+1)``.
  * ``overrides``   — per-layer ``{'stack/scan/0/mixer/q': bits}`` pins
                      (stored as a sorted tuple of pairs so the spec stays
                      hashable and usable inside the frozen ModelConfig).

The JSON round-trip (``to_json``/``from_json``, ``save``/``load``) is what
the launcher's ``--spec`` flag and the quantized-checkpoint manifest use.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Mapping, Optional, Sequence, Tuple, Union

# alias map kept here (not in the registry) so the spec module stays
# dependency-free; formats.py validates registry membership at quantize time
_FORMAT_ALIASES = {"uniform": "rtn", "int": "rtn", "nonuniform": "bcq"}


def canonical_format(name: str) -> str:
    name = (name or "bcq").strip().lower()
    return _FORMAT_ALIASES.get(name, name)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    format: str = "bcq"
    bits: Optional[float] = None      # None -> format default (4; ternary 2)
    group_size: int = 128
    iters: int = 5
    backend: str = "auto"
    candidates: Tuple[int, ...] = ()
    overrides: Tuple[Tuple[str, int], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "format", canonical_format(self.format))
        if self.bits is None:
            object.__setattr__(self, "bits",
                               2.0 if self.format == "ternary" else 4.0)
        elif self.format == "ternary" and float(self.bits) != 2:
            # never silently serve 2-plane ternary as "N-bit" results
            raise ValueError(
                f"format 'ternary' always stores 2 planes; bits="
                f"{self.bits:g} conflicts (omit bits or pass 2)")
        object.__setattr__(self, "bits", float(self.bits))
        if isinstance(self.overrides, Mapping):
            object.__setattr__(
                self, "overrides",
                tuple(sorted((str(k), int(v)) for k, v in self.overrides.items())))
        else:
            object.__setattr__(
                self, "overrides",
                tuple(sorted((str(k), int(v)) for k, v in self.overrides)))
        object.__setattr__(self, "candidates",
                           tuple(int(c) for c in self.candidates))
        if self.bits < 0:
            raise ValueError(f"bits must be >= 0, got {self.bits}")
        if self.group_size <= 0:
            raise ValueError(f"group_size must be positive, got {self.group_size}")

    # ------------------------------------------------------------------
    # derived views
    # ------------------------------------------------------------------
    @property
    def is_fractional(self) -> bool:
        """True when ``bits`` is a fractional average -> mixed precision."""
        return self.bits != int(self.bits)

    @property
    def is_mixed(self) -> bool:
        return self.is_fractional or bool(self.overrides)

    @property
    def int_bits(self) -> int:
        """Uniform bit-width (only meaningful when not fractional)."""
        return int(self.bits)

    @property
    def candidate_bits(self) -> Tuple[int, ...]:
        """Mixed-precision candidate set (explicit or derived from bits)."""
        if self.candidates:
            return tuple(sorted(set(self.candidates)))
        lo = max(1, math.floor(self.bits))
        hi = math.ceil(self.bits)
        return tuple(sorted({lo, hi, hi + 1}))

    @property
    def overrides_map(self) -> dict:
        return dict(self.overrides)

    # ------------------------------------------------------------------
    # construction / migration
    # ------------------------------------------------------------------
    def replace(self, **kw) -> "QuantSpec":
        return dataclasses.replace(self, **kw)

    @classmethod
    def from_legacy(cls, *, bits: Union[int, float] = 4, method: str = "bcq",
                    group_size: int = 128, iters: int = 5,
                    backend: str = "auto",
                    bit_map: Optional[Mapping[str, int]] = None) -> "QuantSpec":
        """Shim for the pre-registry kwargs (one-release deprecation path)."""
        return cls(format=method, bits=bits, group_size=group_size,
                   iters=iters, backend=backend or "auto",
                   overrides=dict(bit_map) if bit_map else ())

    # ------------------------------------------------------------------
    # JSON round-trip
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["candidates"] = list(self.candidates)
        d["overrides"] = {k: v for k, v in self.overrides}
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "QuantSpec":
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        # legacy spelling: {"method": ..} instead of {"format": ..}
        if "format" not in kw and "method" in d:
            kw["format"] = d["method"]
        unknown = sorted(set(d) - fields - {"method"})
        if unknown:
            # a typo'd key ("groupsize") silently falling back to the
            # default would quantize at a different quality/memory point
            raise ValueError(f"unknown QuantSpec fields {unknown}; "
                             f"valid: {sorted(fields)}")
        return cls(**kw)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "QuantSpec":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2) + "\n")

    @classmethod
    def load(cls, path: str) -> "QuantSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    def describe(self) -> str:
        b = f"{self.bits:g}"
        tag = f"{self.format}-{b}bit"
        if self.is_mixed:
            tag += f" (mixed, candidates={list(self.candidate_bits)})"
        return f"{tag} g{self.group_size} backend={self.backend}"
