"""QuantSpec — the single declarative description of a quantization run.

One frozen, hashable dataclass replaces the ``bits/method/group_size/
iters/backend`` kwargs that used to be hand-threaded through
``configs/base.py``, ``quantize/ptq.py`` and ``launch/serve.py``:

    spec = QuantSpec(format="bcq", bits=2.4, group_size=64, backend="auto")
    qparams, manifest = repro.quant.quantize_model(params, spec, model.axes())

Fields:
  * ``format``      — key into the format registry (:mod:`repro.quant.formats`):
                      ``bcq`` (alternating non-uniform), ``rtn`` (uniform
                      round-to-nearest mapped exactly into BCQ planes; alias
                      ``uniform``), ``ternary`` ({-a, 0, +a} mapped into two
                      BCQ planes).
  * ``bits``        — integer, or a *fractional average* (e.g. ``2.4``) which
                      triggers sensitivity-driven mixed precision via
                      :func:`repro.core.mixed_precision.allocate_bits`
                      (paper Fig. 17 / the 2.4-bit iso-perplexity point).
  * ``group_size``  — input-dim scaling-factor group (LUT-GEMM convention).
  * ``iters``       — alternating-refinement rounds for the ``bcq`` solver.
  * ``backend``     — execution *preference* into the backend registry
                      (:mod:`repro.quant.backends`): ``auto`` lets capability
                      negotiation pick; an explicit name is honoured when the
                      backend supports the weight, otherwise the fallback
                      chain (pallas -> bcq_xla -> dense) engages.
  * ``candidates``  — mixed-precision candidate bit-widths; ``()`` derives
                      ``(floor(bits), ceil(bits), ceil(bits)+1)``.
  * ``overrides``   — per-layer ``{'stack/scan/0/mixer/q': bits}`` pins
                      (stored as a sorted tuple of pairs so the spec stays
                      hashable and usable inside the frozen ModelConfig).

The JSON round-trip (``to_json``/``from_json``, ``save``/``load``) is what
the launcher's ``--spec`` flag and the quantized-checkpoint manifest use.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Mapping, Optional, Sequence, Tuple, Union

# information rate of the ternary format, log2(3) — kept as a literal so
# this module stays dependency-free (core.plane.TERNARY_BITS is the same
# value and the two are asserted equal in tests)
TERNARY_BITS = 1.585

# alias map kept here (not in the registry) so the spec module stays
# dependency-free; formats.py validates registry membership at quantize time
_FORMAT_ALIASES = {"uniform": "rtn", "int": "rtn", "nonuniform": "bcq"}


def canonical_format(name: str) -> str:
    name = (name or "bcq").strip().lower()
    return _FORMAT_ALIASES.get(name, name)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    format: str = "bcq"
    bits: Optional[float] = None      # None -> format default (4; ternary 2)
    group_size: int = 128
    iters: int = 5
    backend: str = "auto"
    candidates: Tuple[float, ...] = ()
    overrides: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "format", canonical_format(self.format))
        if self.bits is None:
            object.__setattr__(self, "bits",
                               TERNARY_BITS if self.format == "ternary"
                               else 4.0)
        elif self.format == "ternary":
            # ternary carries log2(3) ≈ 1.585 bits/weight in 2 stored
            # planes (sign + mask); accept the rate spellings and the
            # stored-plane count, reject anything else so 2-plane
            # ternary is never silently served as "N-bit" results
            if float(self.bits) in (2.0, 1.58, TERNARY_BITS):
                object.__setattr__(self, "bits", TERNARY_BITS)
            else:
                raise ValueError(
                    f"format 'ternary' stores 2 planes at rate log2(3); "
                    f"bits={self.bits:g} conflicts (omit bits, or pass "
                    f"1.58/1.585/2)")
        object.__setattr__(self, "bits", float(self.bits))
        if self.bits == 1.58:
            # the colloquial "1.58-bit" spelling names the same log2(3)
            # rate; canonicalize so plans and cache keys agree
            object.__setattr__(self, "bits", TERNARY_BITS)
        pairs = (self.overrides.items()
                 if isinstance(self.overrides, Mapping) else self.overrides)
        # sub-2 widths are the fractional ternary sentinel and must keep
        # their float spelling; integer widths stay ints for readability
        _w = lambda v: float(v) if float(v) < 2 else int(v)
        object.__setattr__(
            self, "overrides",
            tuple(sorted((str(k), _w(v)) for k, v in pairs)))
        object.__setattr__(
            self, "candidates",
            tuple(float(c) if float(c) < 2 else int(c)
                  for c in self.candidates))
        if self.bits < 0:
            raise ValueError(f"bits must be >= 0, got {self.bits}")
        if self.group_size <= 0:
            raise ValueError(f"group_size must be positive, got {self.group_size}")

    # ------------------------------------------------------------------
    # derived views
    # ------------------------------------------------------------------
    @property
    def is_fractional(self) -> bool:
        """True when ``bits`` is a fractional average -> mixed precision.

        The ternary *format* is excluded: its fractional rate names a
        fixed layout, not a mixed-precision request."""
        return self.format != "ternary" and self.bits != int(self.bits)

    @property
    def is_mixed(self) -> bool:
        return self.is_fractional or bool(self.overrides)

    @property
    def int_bits(self) -> int:
        """Uniform bit-width (only meaningful when not fractional)."""
        return int(self.bits)

    @property
    def candidate_bits(self) -> Tuple[float, ...]:
        """Mixed-precision candidate set (explicit or derived from bits)."""
        if self.candidates:
            return tuple(sorted(set(self.candidates)))
        hi = math.ceil(self.bits)
        if self.bits < 2:
            # sub-2-bit budgets admit the ternary fast path as the low
            # candidate (e.g. 1.58 -> ternary/2/3-bit per-layer mixing);
            # budgets >= 2 keep the historical integer ladder
            return tuple(sorted({TERNARY_BITS, max(hi, 2), max(hi, 2) + 1}))
        lo = max(1, math.floor(self.bits))
        return tuple(sorted({lo, hi, hi + 1}))

    @property
    def overrides_map(self) -> dict:
        return dict(self.overrides)

    # ------------------------------------------------------------------
    # construction / migration
    # ------------------------------------------------------------------
    def replace(self, **kw) -> "QuantSpec":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # JSON round-trip
    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["candidates"] = list(self.candidates)
        d["overrides"] = {k: v for k, v in self.overrides}
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "QuantSpec":
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        # legacy spelling: {"method": ..} instead of {"format": ..}
        if "format" not in kw and "method" in d:
            kw["format"] = d["method"]
        unknown = sorted(set(d) - fields - {"method"})
        if unknown:
            # a typo'd key ("groupsize") silently falling back to the
            # default would quantize at a different quality/memory point
            raise ValueError(f"unknown QuantSpec fields {unknown}; "
                             f"valid: {sorted(fields)}")
        return cls(**kw)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "QuantSpec":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2) + "\n")

    @classmethod
    def load(cls, path: str) -> "QuantSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    def describe(self) -> str:
        b = f"{self.bits:g}"
        tag = f"{self.format}-{b}bit"
        if self.is_mixed:
            tag += f" (mixed, candidates={list(self.candidate_bits)})"
        return f"{tag} g{self.group_size} backend={self.backend}"
