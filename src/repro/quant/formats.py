"""Weight-format registry: every format lowers into a plane bundle.

FIGLUT's engine executes *one* representation — packed sign planes with
per-(row, group) scales (``core.plane.PlaneBundle``) — and the paper's
claim that a fixed design "efficiently supports different bit precisions
and quantization methods" is realized in software by mapping every
supported format into that representation at quantize time:

  * ``bcq``     — alternating non-uniform BCQ (ShiftAddLLM-class solver);
  * ``rtn``     — round-to-nearest *uniform* quantization mapped exactly
                  into BCQ(+offset) planes (Eq. (3); runs OPTQ/AWQ/RTN
                  checkpoints on the same engine);
  * ``ternary`` — {-a, 0, +a} weights with MSE-optimal (octav-style
                  alternating fixed-point) clipping, emitted as a
                  first-class ``kind="ternary"`` bundle: one sign plane
                  + one nonzero-mask plane, a single shared-magnitude
                  alpha row and no offset — the layout the dedicated
                  ``kernels/ternary_matmul`` Pallas kernel consumes.

New formats register with :func:`register_format` and immediately work
through ``quantize_model``/``linear_apply`` without touching model code —
the kernels only ever see planes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bcq as bcq_mod
from repro.core.bcq import BCQWeight, pack_planes
from repro.core.plane import TERNARY_BITS, PlaneBundle


@dataclasses.dataclass(frozen=True)
class FormatInfo:
    """One registered weight format.

    ``quantize(w2d, bits, group_size, iters) -> PlaneBundle`` must be
    pure JAX (it runs under ``lax.map`` for scan-stacked leaves).
    ``fixed_plane_bits`` pins the stored plane count regardless of the
    requested bits (ternary is always 2 planes: sign + mask); ``None``
    means the request decides.  ``effective_bits`` is the
    information-theoretic width reported in manifests and used by the
    mixed-precision planner (ternary stores 2 planes but carries
    log2(3) ≈ 1.585 bits).
    """

    name: str
    quantize: Callable[..., BCQWeight]
    fixed_plane_bits: Optional[int] = None
    effective_bits: Optional[float] = None
    description: str = ""

    def plane_bits(self, requested_bits: float) -> int:
        if self.fixed_plane_bits is not None:
            return self.fixed_plane_bits
        return int(requested_bits)


_REGISTRY: Dict[str, FormatInfo] = {}


def register_format(info: FormatInfo) -> FormatInfo:
    _REGISTRY[info.name] = info
    return info


def get_format(name: str) -> FormatInfo:
    from repro.quant.spec import canonical_format
    key = canonical_format(name)
    if key not in _REGISTRY:
        raise KeyError(f"unknown quant format {name!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def available_formats() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def format_for_bits(name: str, bits: float) -> FormatInfo:
    """Resolve the format a planner bit-width lands on.

    Integer widths keep the requested format; the fractional
    :data:`~repro.core.plane.TERNARY_BITS` sentinel (anything below 2)
    selects the ternary format — this is how a mixed-precision plan
    mixes ternary/2/3/4-bit layers through one dispatch (MxGLUT).
    """
    if bits < 2:
        return get_format("ternary")
    return get_format(name)


# ---------------------------------------------------------------------------
# built-in formats
# ---------------------------------------------------------------------------


def _quantize_bcq(w2d, *, bits: int, group_size: int, iters: int) -> BCQWeight:
    return bcq_mod.quantize(w2d, bits=bits, group_size=group_size, iters=iters)


def _quantize_rtn(w2d, *, bits: int, group_size: int, iters: int = 0) -> BCQWeight:
    del iters
    return bcq_mod.from_uniform(w2d, bits=bits, group_size=group_size)


def quantize_ternary(w_dense: jax.Array, *, bits: int = 2,
                     group_size: int = 128, iters: int = 0,
                     clip_iters: int = 12) -> PlaneBundle:
    """MSE-optimal ternarization emitted as a ``kind="ternary"`` bundle.

    Per (row, group) the {-a, 0, +a} codebook that minimizes
    ||w - a·t||² satisfies a fixed point (octav-style alternating
    optimal clipping, the ternary Lloyd-Max condition):

        keep set  S(a) = { |w| > a/2 }          (nearest-codeword rule)
        magnitude a    = mean(|w| over S(a))    (LS-optimal given S)

    iterated from a₀ = mean|w| — strictly better than the fixed
    TWN 0.7·mean|w| threshold it replaces, and exact on inputs that are
    already ternary.  The bundle layout is plane 0 = sign bit
    (1 encodes +), plane 1 = nonzero mask (1 encodes keep), a single
    alpha row ``a`` and ``z=None`` — strictly fewer stored bytes than
    the generic 2-plane BCQ encoding (one scale row instead of two,
    no offset row).  ``bits``/``iters`` are accepted for
    registry-signature uniformity and ignored.
    """
    del bits, iters
    w = jnp.asarray(w_dense, jnp.float32)
    if w.ndim != 2:
        raise ValueError(f"expected 2-D weight, got {w.shape}")
    out, n = w.shape
    g = int(group_size)
    n_pad = -(-n // g) * g
    if n_pad != n:
        w = jnp.pad(w, ((0, 0), (0, n_pad - n)), mode="edge")
    n_groups = n_pad // g
    wg = w.reshape(out, n_groups, g)

    absw = jnp.abs(wg)
    a = absw.mean(axis=-1)                                      # [out, G]
    for _ in range(clip_iters):
        mask = absw > (a[..., None] / 2.0)
        cnt = jnp.maximum(mask.sum(axis=-1), 1)
        a = (absw * mask).sum(axis=-1) / cnt
    mask = absw > (a[..., None] / 2.0)

    sign = jnp.where(wg >= 0, 1.0, -1.0)
    keep = jnp.where(mask, 1.0, -1.0)                 # bit 1 = nonzero
    planes = jnp.stack([sign, keep]).reshape(2, out, n_pad)
    return PlaneBundle(packed=pack_planes(planes),
                       alpha=a[None].astype(jnp.float32), z=None,
                       group_size=g, in_features=n, out_features=out,
                       kind="ternary")


register_format(FormatInfo(
    name="bcq", quantize=_quantize_bcq,
    description="alternating non-uniform BCQ (greedy init + LS refinement)"))
register_format(FormatInfo(
    name="rtn", quantize=_quantize_rtn,
    description="uniform round-to-nearest, exact BCQ(+offset) mapping"))
register_format(FormatInfo(
    name="ternary", quantize=quantize_ternary, fixed_plane_bits=2,
    effective_bits=TERNARY_BITS,
    description="octav-clipped {-a,0,+a} as sign+mask plane bundle "
                "(1 alpha row, no offset; dedicated ternary_matmul kernel)"))
