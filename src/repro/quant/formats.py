"""Weight-format registry: every format lowers into BCQ bit-planes.

FIGLUT's engine executes *one* representation — packed ±1 planes with
per-(row, group) scales (``core.bcq.BCQWeight``) — and the paper's claim
that a fixed design "efficiently supports different bit precisions and
quantization methods" is realized in software by mapping every supported
format into that representation at quantize time:

  * ``bcq``     — alternating non-uniform BCQ (ShiftAddLLM-class solver);
  * ``rtn``     — round-to-nearest *uniform* quantization mapped exactly
                  into BCQ(+offset) planes (Eq. (3); runs OPTQ/AWQ/RTN
                  checkpoints on the same engine);
  * ``ternary`` — {-a, 0, +a} weights (TWN-style threshold) encoded into
                  two planes with alpha_1 = alpha_2 = a/2, so
                  (a/2)(b_1 + b_2) ∈ {-a, 0, +a} reconstructs exactly.

New formats register with :func:`register_format` and immediately work
through ``quantize_model``/``linear_apply`` without touching model code —
the kernels only ever see planes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bcq as bcq_mod
from repro.core.bcq import BCQWeight, pack_planes


@dataclasses.dataclass(frozen=True)
class FormatInfo:
    """One registered weight format.

    ``quantize(w2d, bits, group_size, iters) -> BCQWeight`` must be pure
    JAX (it runs under ``lax.map`` for scan-stacked leaves).
    ``fixed_plane_bits`` pins the stored plane count regardless of the
    requested bits (ternary is always 2 planes); ``None`` means the
    request decides.  ``effective_bits`` is the information-theoretic
    width reported in manifests (ternary stores 2 planes but carries
    log2(3) ≈ 1.58 bits).
    """

    name: str
    quantize: Callable[..., BCQWeight]
    fixed_plane_bits: Optional[int] = None
    effective_bits: Optional[float] = None
    description: str = ""

    def plane_bits(self, requested_bits: float) -> int:
        if self.fixed_plane_bits is not None:
            return self.fixed_plane_bits
        return int(requested_bits)


_REGISTRY: Dict[str, FormatInfo] = {}


def register_format(info: FormatInfo) -> FormatInfo:
    _REGISTRY[info.name] = info
    return info


def get_format(name: str) -> FormatInfo:
    from repro.quant.spec import canonical_format
    key = canonical_format(name)
    if key not in _REGISTRY:
        raise KeyError(f"unknown quant format {name!r}; "
                       f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def available_formats() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in formats
# ---------------------------------------------------------------------------


def _quantize_bcq(w2d, *, bits: int, group_size: int, iters: int) -> BCQWeight:
    return bcq_mod.quantize(w2d, bits=bits, group_size=group_size, iters=iters)


def _quantize_rtn(w2d, *, bits: int, group_size: int, iters: int = 0) -> BCQWeight:
    del iters
    return bcq_mod.from_uniform(w2d, bits=bits, group_size=group_size)


def quantize_ternary(w_dense: jax.Array, *, bits: int = 2,
                     group_size: int = 128, iters: int = 0,
                     threshold: float = 0.7) -> BCQWeight:
    """TWN-style ternarization encoded as 2-plane BCQ.

    Per (row, group): delta = threshold * mean|w|; weights above delta keep
    their sign and share the magnitude a = mean(|w| over the kept set);
    the rest snap to 0.  The plane encoding

        t = +1 -> (b1, b2) = (+1, +1)
        t =  0 -> (b1, b2) = (+1, -1)
        t = -1 -> (b1, b2) = (-1, -1)

    with alpha_1 = alpha_2 = a/2 and z = 0 reconstructs (a/2)(b1 + b2)
    = a*t exactly, so the fixed bit-serial engine executes ternary
    checkpoints with zero representational error beyond ternarization
    itself.  ``bits``/``iters`` are accepted for registry-signature
    uniformity and ignored (ternary is always 2 planes).
    """
    del bits, iters
    w = jnp.asarray(w_dense, jnp.float32)
    if w.ndim != 2:
        raise ValueError(f"expected 2-D weight, got {w.shape}")
    out, n = w.shape
    g = int(group_size)
    n_pad = -(-n // g) * g
    if n_pad != n:
        w = jnp.pad(w, ((0, 0), (0, n_pad - n)), mode="edge")
    n_groups = n_pad // g
    wg = w.reshape(out, n_groups, g)

    absw = jnp.abs(wg)
    delta = threshold * absw.mean(axis=-1, keepdims=True)       # [out, G, 1]
    mask = absw > delta
    cnt = jnp.maximum(mask.sum(axis=-1), 1)                     # [out, G]
    a = (absw * mask).sum(axis=-1) / cnt                        # magnitude
    t = jnp.sign(wg) * mask                                     # {-1, 0, +1}

    p1 = jnp.where(t < 0, -1.0, 1.0)
    p2 = jnp.where(t > 0, 1.0, -1.0)
    planes = jnp.stack([p1, p2]).reshape(2, out, n_pad)
    alpha = jnp.broadcast_to((a / 2.0)[None], (2, out, n_groups))
    z = jnp.zeros((out, n_groups), jnp.float32)
    return BCQWeight(packed=pack_planes(planes),
                     alpha=alpha.astype(jnp.float32), z=z,
                     group_size=g, in_features=n, out_features=out)


register_format(FormatInfo(
    name="bcq", quantize=_quantize_bcq,
    description="alternating non-uniform BCQ (greedy init + LS refinement)"))
register_format(FormatInfo(
    name="rtn", quantize=_quantize_rtn,
    description="uniform round-to-nearest, exact BCQ(+offset) mapping"))
register_format(FormatInfo(
    name="ternary", quantize=quantize_ternary, fixed_plane_bits=2,
    effective_bits=1.585,
    description="TWN-style {-a,0,+a} encoded as 2 BCQ planes (alpha/2 each)"))
