"""repro.quant — the unified quantization API.

    from repro.quant import QuantSpec, quantize_model

    spec = QuantSpec(format="bcq", bits=2.4, group_size=64)
    qparams, manifest = quantize_model(params, spec, model.axes())

See :mod:`repro.quant.spec` (declarative config), :mod:`repro.quant.
formats` (bcq / rtn / ternary -> BCQ planes), :mod:`repro.quant.backends`
(capability-negotiated execution with fallback chains), :mod:`repro.
quant.api` (quantize + manifest) and :mod:`repro.quant.checkpoint`
(pre-quantized checkpoints).

Only :mod:`repro.quant.spec` (stdlib-only) loads eagerly — the heavier
submodules resolve lazily via PEP 562 so ``import repro.configs`` (which
embeds QuantSpec in ModelConfig) stays light and cycle-free.
"""
from repro.quant.spec import TERNARY_BITS, QuantSpec, canonical_format

_LAZY = {
    # formats
    "FormatInfo": "formats", "available_formats": "formats",
    "format_for_bits": "formats", "get_format": "formats",
    "register_format": "formats", "quantize_ternary": "formats",
    # backends
    "BackendInfo": "backends", "available_backends": "backends",
    "execute_linear": "backends", "fallback_chain": "backends",
    "get_backend": "backends", "kernel_for": "backends",
    "register_backend": "backends", "resolve_backend": "backends",
    # api
    "QuantManifest": "api", "build_manifest": "api", "plan_bits": "api",
    "quantize_model": "api",
    # checkpoint
    "load_quantized": "checkpoint", "save_quantized": "checkpoint",
}

__all__ = ["QuantSpec", "TERNARY_BITS", "canonical_format", *sorted(_LAZY)]


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.quant.{_LAZY[name]}")
        value = getattr(mod, name)
        globals()[name] = value          # cache for subsequent lookups
        return value
    raise AttributeError(f"module 'repro.quant' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
