"""Execution-backend registry: capability negotiation + fallback chain.

``linear_apply`` used to pick its execution path with an ``isinstance``
check plus a raw string; now every linear resolves here:

    name = resolve_backend(preference, w)     # capability negotiation
    y    = execute_linear(x, w, backend=preference)

Each registered backend declares

  * ``available()`` — can it run *at all* on this host (Pallas kernels run
    anywhere via interpret mode, so this is almost always True);
  * ``native()``    — is it the hardware-native path here (Pallas on TPU);
    ``auto`` resolution only considers native backends, so a CPU host
    auto-selects ``bcq_xla`` instead of interpret-mode Pallas, while an
    *explicit* preference still runs interpreted (tests, kernel bring-up);
  * ``supports(w)`` — per-weight capability: plane count, group-size
    granularity, problem geometry (consults
    :func:`repro.tune.dispatch.kernel_supports` for the Pallas kernels).

Resolution walks the preference's fallback chain —
``ternary_pallas``/``mxu_pallas``/``lut_pallas`` -> ``bcq_xla`` ->
``dense`` — and returns the first backend that is usable and supports the
weight, so a new format or an odd group size degrades gracefully instead
of crashing a serve tick.  ``supports`` is *kind-aware*: the dedicated
ternary kernel only claims ``kind="ternary"`` bundles and the generic
plane kernels only ``kind="bcq"``, while the XLA fallbacks execute any
kind through the kind-aware ``plane.dequantize``.

Dense (unquantized) array leaves resolve to the plain einsum path, making
this the single dispatch point for *every* linear in the model stack.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.bcq import BCQWeight
from repro.core import lut_gemm as _lg


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class BackendInfo:
    name: str
    execute: Callable[..., jax.Array]          # (x, w, out_dtype) -> y
    supports: Callable[[BCQWeight], bool]
    available: Callable[[], bool]
    native: Callable[[], bool]
    kernel: Optional[str] = None               # repro.tune kernel id
    description: str = ""


_REGISTRY: Dict[str, BackendInfo] = {}

#: resolution order for ``backend="auto"`` (best native first).
#: ``ternary_pallas`` heads the chain but only claims ``kind="ternary"``
#: bundles, so generic BCQ weights resolve exactly as before.
AUTO_CHAIN: Tuple[str, ...] = ("ternary_pallas", "mxu_pallas", "lut_pallas",
                               "bcq_xla", "dense")

#: explicit-preference fallback chains (first entry = the preference)
FALLBACK_CHAINS: Dict[str, Tuple[str, ...]] = {
    "ternary_pallas": ("ternary_pallas", "bcq_xla", "dense"),
    "mxu_pallas": ("mxu_pallas", "bcq_xla", "dense"),
    "lut_pallas": ("lut_pallas", "bcq_xla", "dense"),
    "bcq_xla": ("bcq_xla", "dense"),
    "bcq_xla_planes": ("bcq_xla_planes", "bcq_xla", "dense"),
    "dense": ("dense",),
    "auto": AUTO_CHAIN,
}


def register_backend(info: BackendInfo,
                     chain: Optional[Tuple[str, ...]] = None) -> BackendInfo:
    _REGISTRY[info.name] = info
    if chain is not None:
        FALLBACK_CHAINS[info.name] = chain
    elif info.name not in FALLBACK_CHAINS:
        FALLBACK_CHAINS[info.name] = (info.name, "bcq_xla", "dense")
    return info


def get_backend(name: str) -> BackendInfo:
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_backends() -> Tuple[str, ...]:
    return tuple(n for n in _REGISTRY if _REGISTRY[n].available())


def fallback_chain(preference: Optional[str]) -> Tuple[str, ...]:
    pref = preference or "auto"
    if pref not in FALLBACK_CHAINS:
        raise KeyError(f"unknown backend preference {pref!r}; known: "
                       f"{sorted(FALLBACK_CHAINS)}")
    return FALLBACK_CHAINS[pref]


# ---------------------------------------------------------------------------
# resolution + execution
# ---------------------------------------------------------------------------


def resolve_backend(preference: Optional[str], w: BCQWeight) -> str:
    """Pick the backend that will execute this weight.

    The head of an *explicit* chain only needs ``available()`` (interpret
    mode is a legitimate explicit request); fallback entries and ``auto``
    require ``native()`` so we never silently degrade onto an emulated
    kernel.  ``dense`` always supports everything, so resolution total.
    """
    pref = preference or "auto"
    chain = fallback_chain(pref)
    for i, name in enumerate(chain):
        info = get_backend(name)
        explicit = i == 0 and pref != "auto"
        usable = info.available() if explicit else info.native()
        if usable and info.supports(w):
            return name
    return "dense"


def execute_linear(x: jax.Array, w, *, backend: Optional[str] = None,
                   out_dtype=None) -> jax.Array:
    """y = x @ W^T for a dense array or BCQWeight leaf.

    This is the single execution-dispatch point of the model stack:
    ``backend`` is a *preference*, and capability negotiation picks the
    first link of its fallback chain that can run this weight.
    """
    out_dtype = out_dtype or x.dtype
    if not isinstance(w, BCQWeight):
        return jnp.einsum("...n,mn->...m", x, w.astype(x.dtype),
                          preferred_element_type=jnp.float32).astype(out_dtype)
    name = resolve_backend(backend, w)
    return get_backend(name).execute(x, w, out_dtype)


def kernel_for(preference: Optional[str]) -> Optional[str]:
    """The repro.tune kernel id the preference would launch (for pretune):
    None when resolution lands on an XLA/dense path."""
    pref = preference or "auto"
    for i, name in enumerate(fallback_chain(pref)):
        info = get_backend(name)
        explicit = i == 0 and pref != "auto"
        if info.available() if explicit else info.native():
            return info.kernel
    return None


# ---------------------------------------------------------------------------
# built-in backends (executors live in repro.core.lut_gemm / repro.kernels)
# ---------------------------------------------------------------------------


def _supports_any(w: BCQWeight) -> bool:
    return True


def _supports_bcq_planes(w: BCQWeight) -> bool:
    # the per-plane grouped contraction reads independent ±1 planes;
    # ternary (sign+mask) bundles take the kind-aware fused paths instead
    return w.kind == "bcq"


def _supports_pallas(kernel: str):
    def check(w: BCQWeight) -> bool:
        from repro.tune.dispatch import kernel_supports
        if w.packed.ndim != 3:          # stacked leaves only run inside scan
            return False
        return kernel_supports(kernel, m=w.out_features, n=w.in_features,
                               group_size=w.group_size, bits=w.bits,
                               kind=w.kind)
    return check


def _exec(backend_name: str):
    def run(x, w, out_dtype):
        return _lg.bcq_apply(x, w, backend=backend_name, out_dtype=out_dtype)
    return run


register_backend(BackendInfo(
    name="dense", execute=_exec("dense"), supports=_supports_any,
    available=lambda: True, native=lambda: True,
    description="dequantize to f32 and matmul (FPE baseline, Table IV)"))

register_backend(BackendInfo(
    name="bcq_xla", execute=_exec("bcq_xla"), supports=_supports_any,
    available=lambda: True, native=lambda: True,
    description="pure-XLA packed execution (pjit-traceable everywhere)"))

register_backend(BackendInfo(
    name="bcq_xla_planes", execute=_exec("bcq_xla_planes"),
    supports=_supports_bcq_planes, available=lambda: True,
    native=lambda: False,
    description="per-plane grouped-contraction XLA variant"))

register_backend(BackendInfo(
    name="lut_pallas", execute=_exec("lut_pallas"),
    supports=_supports_pallas("lut_gemm"),
    available=lambda: True, native=_on_tpu, kernel="lut_gemm",
    description="paper-faithful FIGLUT Pallas kernel (interpret off-TPU)"))

register_backend(BackendInfo(
    name="mxu_pallas", execute=_exec("mxu_pallas"),
    supports=_supports_pallas("bcq_matmul"),
    available=lambda: True, native=_on_tpu, kernel="bcq_matmul",
    description="dequant-in-VMEM MXU Pallas kernel (interpret off-TPU)"))

register_backend(BackendInfo(
    name="ternary_pallas", execute=_exec("ternary_pallas"),
    supports=_supports_pallas("ternary_matmul"),
    available=lambda: True, native=_on_tpu, kernel="ternary_matmul",
    description="dedicated 1.58-bit kernel: in-kernel sign decode onto "
                "the half-LUT, single alpha row (interpret off-TPU)"))
