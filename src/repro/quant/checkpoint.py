"""Quantized-checkpoint save/load on top of ``train.checkpoint``.

Serving used to re-run PTQ at every launch (~minutes of solver time for
a real model).  ``save_quantized`` persists the *already quantized* tree
— BCQWeight leaves are encoded as plain dict bundles the numpy-backed
checkpointer understands, with the static fields stored as 0-d arrays —
plus the :class:`QuantSpec` and manifest in the checkpoint ``extra``
blob.  ``load_quantized`` rebuilds the exact same pytree, so a serve
from a loaded checkpoint is token-for-token identical to
quantize-at-launch (tested in tests/test_quant_api.py).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.bcq import BCQWeight
from repro.core.plane import KINDS
from repro.quant.api import QuantManifest
from repro.quant.spec import QuantSpec
from repro.train import checkpoint as ckpt

_BCQ_TAG = "__bcq_weight__"


def _encode(tree):
    if isinstance(tree, BCQWeight):
        # the offset row is optional (ternary has none) and the layout
        # kind rides along as an index into plane.KINDS — the numpy
        # checkpointer only understands array leaves
        bundle = {
            "packed": tree.packed, "alpha": tree.alpha,
            "group_size": np.int64(tree.group_size),
            "in_features": np.int64(tree.in_features),
            "out_features": np.int64(tree.out_features),
            "kind": np.int64(KINDS.index(tree.kind)),
        }
        if tree.z is not None:
            bundle["z"] = tree.z
        return {_BCQ_TAG: bundle}
    if isinstance(tree, dict):
        return {k: _encode(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        out = [_encode(v) for v in tree]
        return type(tree)(out) if isinstance(tree, tuple) else out
    return tree


def _decode(tree):
    if isinstance(tree, dict):
        if _BCQ_TAG in tree:
            d = tree[_BCQ_TAG]
            return BCQWeight(
                packed=jnp.asarray(d["packed"], jnp.uint8),
                alpha=jnp.asarray(d["alpha"], jnp.float32),
                z=(jnp.asarray(d["z"], jnp.float32)
                   if d.get("z") is not None else None),
                group_size=int(d["group_size"]),
                in_features=int(d["in_features"]),
                out_features=int(d["out_features"]),
                # pre-kind checkpoints carry no field -> "bcq" (index 0)
                kind=KINDS[int(d.get("kind", 0))])
        return {k: _decode(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        out = [_decode(v) for v in tree]
        return type(tree)(out) if isinstance(tree, tuple) else out
    if tree is None:
        return None
    return jnp.asarray(tree)


def save_quantized(ckpt_dir: str, params, spec: QuantSpec,
                   manifest: Optional[QuantManifest] = None,
                   step: int = 0, arch: str = "",
                   extra_meta: Optional[dict] = None) -> str:
    """Atomically persist a quantized params tree + its spec/manifest.

    ``extra_meta`` (JSON-serializable) rides along in the checkpoint
    extra blob — the launcher records model dimensions there so a
    reduced-config checkpoint can't be loaded into a full-size model.
    """
    extra = {"quant_spec": spec.to_dict(), "arch": arch,
             **(extra_meta or {})}
    if manifest is not None:
        extra["manifest"] = manifest.to_dict()
    return ckpt.save(ckpt_dir, step, _encode(params), extra=extra)


def load_quantized(ckpt_dir: str, step: Optional[int] = None,
                   ) -> Tuple[Any, QuantSpec, Optional[QuantManifest], dict]:
    """Restore ``(params, spec, manifest, extra)`` from a quantized ckpt."""
    tree, _, extra = ckpt.restore(ckpt_dir, step)
    params = _decode(tree)
    if "quant_spec" not in extra:
        raise ValueError(f"{ckpt_dir} is not a quantized checkpoint "
                         "(no quant_spec in manifest extra)")
    spec = QuantSpec.from_dict(extra["quant_spec"])
    manifest = (QuantManifest.from_dict(extra["manifest"])
                if extra.get("manifest") else None)
    return params, spec, manifest, extra
