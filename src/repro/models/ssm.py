"""Mamba2 SSD (state-space duality) block — chunked train/prefill + O(1)
decode state.

Follows the minimal-SSD formulation of Dao & Gu (arXiv:2405.21060):
per-head scalar decay A, input-dependent dt (softplus), shared B/C
projections (n_groups=1).  The sequence is processed in chunks:

  intra-chunk:  y_intra = ((C_q . B_k) * decay(q,k) * lower-tri) @ x
  chunk state:  S_c     = sum_k decay_to_end(k) * dt_k * B_k (x) x_k
  inter-chunk:  h_{c+1} = exp(sum_chunk dtA) * h_c + S_c   (lax.scan)
  y            = y_intra + C . h_prefix (decayed)

Decode is the SSM recurrence on a [B, H, P, N] state + a depthwise-conv
ring buffer — constant memory in sequence length, which is why the
long_500k cell is natural for SSM/hybrid architectures.

FIGLUT applies to in_proj / out_proj (the dominant GEMMs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantized_linear import linear_apply
from repro.models.module import ParamDesc
from repro.parallel.sharding import shard_act


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads


def ssm_desc(cfg):
    d = cfg.d_model
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * n            # x, B, C all pass the conv
    return {
        # in_proj emits [z (gate), xBC (conv path), dt] like mamba2
        "in_proj": ParamDesc((2 * d_inner + 2 * n + h, d), jnp.bfloat16,
                             ("mlp", "embed")),
        "conv_w": ParamDesc((cfg.ssm_conv, conv_dim), jnp.bfloat16,
                            (None, "mlp"), "normal"),
        "conv_b": ParamDesc((conv_dim,), jnp.float32, ("mlp",), "zeros"),
        "A_log": ParamDesc((h,), jnp.float32, ("heads",), "zeros"),
        "D": ParamDesc((h,), jnp.float32, ("heads",), "ones"),
        "dt_bias": ParamDesc((h,), jnp.float32, ("heads",), "zeros"),
        "out_norm": ParamDesc((d_inner,), jnp.float32, ("mlp",), "ones"),
        "out_proj": ParamDesc((d, d_inner), jnp.bfloat16, ("embed", "mlp")),
    }


def ssm_cache_desc(cfg, batch: int):
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * n
    return {
        "conv": ParamDesc((batch, cfg.ssm_conv - 1, conv_dim),
                          jnp.dtype(cfg.dtype),
                          ("batch", None, "mlp"), "zeros"),
        "state": ParamDesc((batch, h, cfg.ssm_head_dim, n), jnp.float32,
                           ("batch", "heads", None, None), "zeros"),
    }


def _split_proj(cfg, proj):
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner: 2 * d_inner + 2 * n]
    dt = proj[..., 2 * d_inner + 2 * n:]
    return z, xbc, dt


def _gated_norm(x, z, scale, eps=1e-6):
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps) * scale
    return y


def ssd_chunked(xh, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD scan.

    xh: [b, l, h, p]; dt: [b, l, h] (post-softplus); A: [h] (negative);
    B, C: [b, l, n]  (n_groups = 1, shared across heads).
    h0: optional initial state [b, h, p, n].
    Returns (y [b, l, h, p], h_final [b, h, p, n]).
    """
    b, l, h, p = xh.shape
    n = B.shape[-1]
    nc = -(-l // chunk)
    pad = nc * chunk - l
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    lc = chunk

    # chunk-major for the scan: [nc, b, lc, ...]
    xc = jnp.moveaxis(xh.reshape(b, nc, lc, h, p), 1, 0).astype(jnp.float32)
    dtc = jnp.moveaxis(dt.reshape(b, nc, lc, h), 1, 0).astype(jnp.float32)
    Bc = jnp.moveaxis(B.reshape(b, nc, lc, n), 1, 0).astype(jnp.float32)
    Cc = jnp.moveaxis(C.reshape(b, nc, lc, n), 1, 0).astype(jnp.float32)
    tri = jnp.tril(jnp.ones((lc, lc), jnp.float32))

    def step(hprev, inp):
        xi, dti, Bi, Ci = inp                        # per-chunk [b, lc, ...]
        dA = dti * A[None, None, :]                  # [b, lc, h]  (<= 0)
        cums = jnp.cumsum(dA, axis=1)
        total = cums[:, -1, :]                       # [b, h]

        # intra-chunk: decay(q,k) = exp(cums_q - cums_k) for q >= k
        diff = cums[:, :, None, :] - cums[:, None, :, :]     # [b, q, k, h]
        decay = jnp.exp(diff) * tri[None, :, :, None]
        cb = jnp.einsum("bqn,bkn->bqk", Ci, Bi)
        gates = cb[..., None] * decay * dti[:, None, :, :]   # [b, q, k, h]
        # pin batch/head sharding on the quadratic intra-chunk tensors —
        # same nested-scan-residual GSPMD failure as attention scores
        gates = shard_act(gates, ("batch", None, None, "heads"))
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", gates, xi)

        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", Ci, jnp.exp(cums), hprev)

        # state update to end of chunk
        decay_to_end = jnp.exp(total[:, None, :] - cums)     # [b, lc, h]
        s_chunk = jnp.einsum("bkn,bkh,bkhp->bhpn",
                             Bi, dti * decay_to_end, xi)
        hnew = jnp.exp(total)[:, :, None, None] * hprev + s_chunk
        return hnew, y_intra + y_inter

    h_init = (jnp.zeros((b, h, p, n), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, yc = jax.lax.scan(step, h_init, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, nc * lc, h, p)
    return y[:, :l], h_last


def ssm_apply(params, cfg, x, *, cache=None, backend=None):
    """Mamba2 block. x: [B, S, d].

    cache=None: train/prefill-from-scratch (returns y only).
    cache given: S==1 decode step OR prefill that fills the cache;
                 returns (y, cache).
    """
    b, s, d = x.shape
    d_inner, h = ssm_dims(cfg)
    n = cfg.ssm_state
    p = cfg.ssm_head_dim
    conv_dim = d_inner + 2 * n
    kw = cfg.ssm_conv

    proj = linear_apply(params["in_proj"], x, backend=backend)
    z, xbc, dt = _split_proj(cfg, proj)
    A = -jnp.exp(params["A_log"])                        # negative decay

    if cache is not None and s == 1:
        # ---------------- decode: O(1) state update --------------------
        conv_hist = cache["conv"]                         # [B, kw-1, conv_dim]
        window = jnp.concatenate([conv_hist.astype(jnp.float32),
                                  xbc.astype(jnp.float32)], axis=1)
        conv_out = (window * params["conv_w"].astype(jnp.float32)[None]
                    ).sum(1) + params["conv_b"]
        xbc_t = jax.nn.silu(conv_out)                     # [B, conv_dim]
        new_conv = window[:, 1:].astype(conv_hist.dtype)

        xt = xbc_t[:, :d_inner].reshape(b, h, p)
        Bt = xbc_t[:, d_inner:d_inner + n]
        Ct = xbc_t[:, d_inner + n:]
        dtt = jax.nn.softplus(dt[:, 0] + params["dt_bias"])  # [B, h]
        dA = jnp.exp(dtt * A[None])                          # [B, h]
        state = cache["state"]
        state = dA[:, :, None, None] * state + \
            jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", Ct, state)
        y = y + params["D"][None, :, None] * xt
        y = y.reshape(b, 1, d_inner)
        y = _gated_norm(y, z, params["out_norm"])
        out = linear_apply(params["out_proj"], y.astype(x.dtype),
                           backend=backend)
        return out, {"conv": new_conv, "state": state}

    # ---------------- train / prefill (chunked SSD) --------------------
    # depthwise causal conv over the sequence
    xbc_f = xbc.astype(jnp.float32)
    pad_left = (jnp.zeros((b, kw - 1, conv_dim), jnp.float32) if cache is None
                else cache["conv"].astype(jnp.float32))
    xpad = jnp.concatenate([pad_left, xbc_f], axis=1)
    conv_out = sum(
        xpad[:, i: i + s] * params["conv_w"][i].astype(jnp.float32)[None, None]
        for i in range(kw)) + params["conv_b"]
    xbc_c = jax.nn.silu(conv_out)

    xh = xbc_c[..., :d_inner].reshape(b, s, h, p)
    xh = shard_act(xh, ("batch", None, "heads", None))
    Bm = xbc_c[..., d_inner:d_inner + n]
    Cm = xbc_c[..., d_inner + n:]
    dtm = jax.nn.softplus(dt + params["dt_bias"][None, None])
    dtm = shard_act(dtm, ("batch", None, "heads"))

    h0 = None if cache is None else cache["state"]
    y, h_last = ssd_chunked(xh, dtm, A, Bm, Cm, cfg.ssm_chunk, h0=h0)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner)
    y = _gated_norm(y, z, params["out_norm"])
    out = linear_apply(params["out_proj"], y.astype(x.dtype), backend=backend)

    if cache is None:
        return out
    new_conv = xpad[:, -(kw - 1):].astype(cache["conv"].dtype) if kw > 1 \
        else cache["conv"]
    return out, {"conv": new_conv, "state": h_last}
