"""Composable pure-JAX model zoo (dense GQA / MLA / MoE / SSM / hybrid /
enc-dec / stub-fronted VLM & audio), quantizable end-to-end via FIGLUT."""
from repro.models.model import Model
from repro.models.module import (ParamDesc, init_params, abstract_params,
                                 logical_axes, param_count)

__all__ = ["Model", "ParamDesc", "init_params", "abstract_params",
           "logical_axes", "param_count"]
