"""Decoder/encoder blocks and the layer-stack assembler.

Supports heterogeneous stacks (jamba: mamba/attn interleave, MoE on every
2nd layer; deepseek: dense layer 0 then MoE) via per-layer (mixer, mlp)
kinds from the config, and two execution modes:

  * unrolled — plain python loop (smoke tests, CPU examples, roofline
    cost extraction where while-loop bodies would be undercounted);
  * scan     — the stack after an unrolled prefix is grouped into the
    architecture's repeating *period* (lcm of mixer/MoE patterns); params
    of each position-within-period are stacked with a leading "layers"
    axis and one lax.scan step executes a full period in true layer order.
    O(1) HLO size for 60-72-layer models -> fast 512-device compiles.

Activation checkpointing (remat) wraps each block on the train path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp_desc, mlp_apply, norm_desc, norm_apply
from repro.models.module import ParamDesc, is_desc


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def block_desc(cfg, kind: str, mlp_kind: str, cross: bool = False,
               d_ff: Optional[int] = None):
    """Params of one block: norm->mixer[->norm->cross][->norm->mlp]."""
    p = {"ln1": norm_desc(cfg)}
    p["mixer"] = attn.attn_desc(cfg) if kind == "attn" else ssm_mod.ssm_desc(cfg)
    if cross:
        p["ln_cross"] = norm_desc(cfg)
        p["cross"] = attn.cross_attn_desc(cfg)
    if cfg.d_ff or mlp_kind == "moe":
        p["ln2"] = norm_desc(cfg)
        p["mlp"] = (moe_mod.moe_desc(cfg) if mlp_kind == "moe"
                    else mlp_desc(cfg, d_ff))
    return p


def block_apply(params, cfg, kind: str, mlp_kind: str, x, positions, *,
                cache=None, cache_at=None, causal=True, enc_out=None,
                backend=None):
    """Returns (x, new_cache); cache is None on the train path."""
    h = norm_apply(params["ln1"], x)
    if kind == "attn":
        mixer = attn.mla_apply if cfg.attention == "mla" else attn.gqa_apply
        kw = {} if cfg.attention == "mla" else {"causal": causal}
        if cache is not None and "self" in (cache or {}):
            h, self_c = mixer(params["mixer"], cfg, h, positions,
                              cache=cache["self"], cache_at=cache_at,
                              backend=backend, **kw)
            cache = {**cache, "self": self_c}
        else:
            h = mixer(params["mixer"], cfg, h, positions, backend=backend, **kw)
    else:
        if cache is not None and "ssm" in cache:
            h, ssm_c = ssm_mod.ssm_apply(params["mixer"], cfg, h,
                                         cache=cache["ssm"], backend=backend)
            cache = {**cache, "ssm": ssm_c}
        else:
            h = ssm_mod.ssm_apply(params["mixer"], cfg, h, backend=backend)
    x = x + h.astype(x.dtype)

    if "cross" in params and (enc_out is not None or
                              (cache is not None and "cross_k" in cache)):
        h = norm_apply(params["ln_cross"], x)
        if enc_out is not None:
            ck, cv = attn.cross_kv(params["cross"], cfg, enc_out, backend)
            if cache is not None:
                cache = {**cache, "cross_k": ck.astype(cache["cross_k"].dtype),
                         "cross_v": cv.astype(cache["cross_v"].dtype)}
        else:
            ck, cv = cache["cross_k"], cache["cross_v"]
        h = attn.cross_attend(params["cross"], cfg, h, ck, cv, backend=backend)
        x = x + h.astype(x.dtype)

    if "mlp" in params:
        h = norm_apply(params["ln2"], x)
        h = (moe_mod.moe_apply(params["mlp"], cfg, h, backend=backend)
             if mlp_kind == "moe" else mlp_apply(params["mlp"], h, backend=backend))
        x = x + h.astype(x.dtype)
    return x, cache


# ---------------------------------------------------------------------------
# layer plan / scan grouping
# ---------------------------------------------------------------------------


def layer_plan(cfg):
    """(mixer_kind, mlp_kind) per decoder layer."""
    return [(cfg.layer_kind(i), cfg.mlp_kind(i)) for i in range(cfg.n_layers)]


def scan_grouping(cfg):
    """(prefix, period, repeats): layers[prefix:] tile with ``period``."""
    plan = layer_plan(cfg)
    pre = cfg.first_dense_layers
    body = plan[pre:]
    if not body:
        return pre, 0, 0
    for period in range(1, len(body) + 1):
        if len(body) % period:
            continue
        if all(body[i] == body[i % period] for i in range(len(body))):
            return pre, period, len(body) // period
    raise AssertionError("unreachable: period=len(body) always tiles")


def stack_descs(tree, n: int):
    """Add a leading stacked-layers dim to every ParamDesc in a tree."""
    def f(d):
        if not is_desc(d):
            return d
        axes = ("layers", *(d.axes if d.axes else (None,) * len(d.shape)))
        return ParamDesc((n, *d.shape), d.dtype, axes, d.init, d.scale)
    return jax.tree_util.tree_map(f, tree, is_leaf=is_desc)


def stack_desc_tree(cfg, cross: bool = False):
    """Decoder-stack descriptors: {'layers': [...]} or {'prefix','scan'}."""
    plan = layer_plan(cfg)
    if not cfg.scan_layers:
        return {"layers": [block_desc(cfg, k, m, cross) for k, m in plan]}
    pre, period, reps = scan_grouping(cfg)
    out = {}
    if pre:
        out["prefix"] = [block_desc(cfg, *plan[i], cross) for i in range(pre)]
    if reps:
        out["scan"] = [stack_descs(block_desc(cfg, *plan[pre + j], cross), reps)
                       for j in range(period)]
    return out


def map_stack(desc_or_params, fn_layer, cfg):
    """Apply fn_layer(layer_index, subtree) over every physical layer slot.

    Used to build per-layer caches matching the param layout.
    """
    plan = layer_plan(cfg)
    if "layers" in desc_or_params:
        return {"layers": [fn_layer(i) for i in range(len(plan))]}
    pre, period, reps = scan_grouping(cfg)
    out = {}
    if pre:
        out["prefix"] = [fn_layer(i) for i in range(pre)]
    if reps:
        # group j stacks layers pre+j, pre+j+period, ... — kinds identical,
        # so one representative cache desc stacked over repeats
        out["scan"] = [stack_descs(fn_layer(pre + j), reps)
                       for j in range(period)]
    return out


# ---------------------------------------------------------------------------
# stack execution
# ---------------------------------------------------------------------------


def _run_block(bparams, cfg, kind, mlpk, x, positions, cache, cache_at,
               causal, enc_out, backend):
    if cfg.remat and cache is None:
        from repro.parallel.sharding import shard_act

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def fn(bp, x_in):
            y, _ = block_apply(bp, cfg, kind, mlpk, x_in, positions,
                               cache=None, cache_at=None, causal=causal,
                               enc_out=enc_out, backend=backend)
            return y
        # shard the remat stash: the saved per-layer block input is the
        # dominant train-memory term ([L, B, S, d] bf16); sharding its
        # embed dim over the model axis cuts it 16x for one extra
        # all-gather per layer in the backward recompute ("act_embed"
        # rule, enabled by the train launcher).
        x = shard_act(x, ("batch", None, "act_embed"))
        return fn(bparams, x), None
    return block_apply(bparams, cfg, kind, mlpk, x, positions, cache=cache,
                       cache_at=cache_at, causal=causal, enc_out=enc_out,
                       backend=backend)


def stack_apply(params, cfg, x, positions, *, caches=None, cache_at=None,
                causal=True, enc_out=None, backend=None):
    """Run the decoder stack; returns (x, new_caches-or-None)."""
    plan = layer_plan(cfg)

    if "layers" in params:                                   # unrolled
        new = [] if caches is not None else None
        for i, bp in enumerate(params["layers"]):
            c = caches["layers"][i] if caches is not None else None
            x, c2 = _run_block(bp, cfg, *plan[i], x, positions, c, cache_at,
                               causal, enc_out, backend)
            if new is not None:
                new.append(c2)
        return x, ({"layers": new} if new is not None else None)

    pre, period, reps = scan_grouping(cfg)
    new_caches = {} if caches is not None else None

    if "prefix" in params:
        outs = []
        for j, bp in enumerate(params["prefix"]):
            c = caches["prefix"][j] if caches is not None else None
            x, c2 = _run_block(bp, cfg, *plan[j], x, positions, c, cache_at,
                               causal, enc_out, backend)
            outs.append(c2)
        if new_caches is not None:
            new_caches["prefix"] = outs

    if "scan" in params:
        groups = params["scan"]
        cstacks = caches["scan"] if caches is not None else None

        def body(x_in, layer_slice):
            bps, cs = layer_slice
            new_cs = []
            y = x_in
            for j in range(period):
                kind, mlpk = plan[pre + j]
                cj = cs[j] if cs is not None else None
                y, c2 = _run_block(bps[j], cfg, kind, mlpk, y, positions,
                                   cj, cache_at, causal, enc_out, backend)
                new_cs.append(c2)
            return y, (new_cs if cs is not None else None)

        if caches is None:
            x, _ = jax.lax.scan(lambda c, g: body(c, (g, None)), x, groups)
        else:
            x, cs_new = jax.lax.scan(body, x, (groups, cstacks))
            new_caches["scan"] = cs_new
    return x, new_caches
