"""Mixture-of-Experts: top-k router + capacity-based scatter dispatch.

Dataflow (dropless-style with a fixed per-expert capacity so shapes stay
static for pjit):

  1. router logits -> softmax -> top-k (gates renormalized over the k),
  2. each (token, k) assignment gets a *position inside its expert* via a
     cumulative count; assignments beyond capacity C are dropped
     (C = ceil(T * k / E) * capacity_factor),
  3. expert inputs are gathered into [E, C, d] (scatter by (expert, pos)),
  4. experts run as a batched einsum over E — with the "experts" logical
     axis sharded over the model axis this is expert parallelism, and XLA
     inserts the dispatch all-to-alls,
  5. outputs are gathered back to token order, weighted by gates, summed
     over k, and added to shared-expert output (deepseek-style) if present.

FIGLUT integration: every expert weight is a quantizable linear (the
bit-plane format is per-2D-matrix, so the stacked [E, f, d] expert bank is
quantized per expert by ``repro.quant.ptq``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bcq import BCQWeight, dequantize
from repro.models.module import ParamDesc


def moe_desc(cfg):
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    p = {
        "router": ParamDesc((e, d), jnp.float32, ("experts", "embed")),
        "gate": ParamDesc((e, f, d), jnp.bfloat16, ("experts", "mlp", "embed")),
        "up": ParamDesc((e, f, d), jnp.bfloat16, ("experts", "mlp", "embed")),
        "down": ParamDesc((e, d, f), jnp.bfloat16, ("experts", "embed", "mlp")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_gate"] = ParamDesc((fs, d), jnp.bfloat16, ("mlp", "embed"))
        p["shared_up"] = ParamDesc((fs, d), jnp.bfloat16, ("mlp", "embed"))
        p["shared_down"] = ParamDesc((d, fs), jnp.bfloat16, ("embed", "mlp"))
    return p


def _expert_bank(w, shape3d):
    """Dense [E, out, in] view of an expert weight (dequantize if BCQ).

    Expert banks are quantized with E as a leading batch dim (packed
    [E, q, out, in/8]) so the dequantized dense bank keeps the expert-
    parallel sharding — folding E into the row dim merges a sharded dim
    and forces a whole-bank all-gather on every layer.  Reconstruction is
    vmapped over E in bf16 (the serve compute dtype).
    """
    if isinstance(w, BCQWeight):
        if w.packed.ndim == 4:          # [E, q, out, in/8]
            def sub(p, a, z=None):
                return dequantize(
                    BCQWeight(packed=p, alpha=a, z=z,
                              group_size=w.group_size,
                              in_features=w.in_features,
                              out_features=w.out_features, kind=w.kind),
                    jnp.bfloat16)
            if w.z is None:             # ternary banks carry no offset row
                dense = jax.vmap(sub)(w.packed, w.alpha)
            else:
                dense = jax.vmap(sub)(w.packed, w.alpha, w.z)
            return dense.reshape(shape3d)
        return dequantize(w, jnp.bfloat16).reshape(shape3d)
    return w


def moe_apply(params, cfg, x, backend=None):
    """x: [B, S, d] -> [B, S, d].  Static shapes throughout (pjit-safe).

    Dispatch is GROUPED per batch row (GShard groups): each row gets its
    own expert-capacity quota and computes positions-in-expert locally, so
    the dispatch scatter never crosses the data axis.  With xin sharded
    (experts->model, rows->data), cross-device traffic is the intended
    [tokens, d] all-to-all — a GLOBAL argsort dispatch instead produces a
    partial-sum [E, C, d] buffer that GSPMD resolves with a full
    all-reduce (~30 TB/device/step measured on deepseek train_4k).
    """
    b, s, d = x.shape
    e = cfg.n_experts
    k = cfg.experts_per_token
    n = s * k                                              # assignments/row
    cap = int(-(-s * k // e) * cfg.capacity_factor)
    cap = max(4, min(cap, s))

    # ---- 1. route ----------------------------------------------------
    logits = jnp.einsum("bsd,ed->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)               # [B, S, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- 2. positions within (row, expert) via per-row sort ranking ----
    flat_e = experts.reshape(b, n)                         # [B, S*k]
    order = jnp.argsort(flat_e, axis=1, stable=True)       # token priority
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    counts = jnp.zeros((b, e), jnp.int32).at[rows, flat_e].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts           # [B, E]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    ranks_sorted = (jnp.arange(n, dtype=jnp.int32)[None]
                    - jnp.take_along_axis(starts, sorted_e, axis=1))
    flat_pos = jnp.zeros((b, n), jnp.int32).at[rows, order].set(ranks_sorted)
    keep = flat_pos < cap

    # ---- 3. dispatch: [E, B, C, d] (row-local scatter) -----------------
    token_idx = jnp.arange(n, dtype=jnp.int32) // k        # [n], within row
    safe_e = jnp.where(keep, flat_e, 0)
    safe_p = jnp.where(keep, flat_pos, cap - 1)
    from repro.parallel.sharding import shard_act
    contrib = jnp.where(keep[..., None], x[:, jnp.arange(n) // k, :], 0
                        ).astype(x.dtype)                  # [B, n, d]
    # pin batch sharding on the dispatch/combine operands: their autodiff
    # cotangents otherwise come out replicated and partial-summed — a
    # 120 GiB f32 all-reduce per MoE layer on deepseek train_4k
    contrib = shard_act(contrib, ("batch", None, None))

    # vmapped row-local scatter/gather: lowers to gather/scatter WITH
    # batch dims, which GSPMD partitions along the data axis (a flat
    # fancy-index over [E, B, C, d] gets replicated instead)
    def disp_row(c_r, se_r, sp_r):
        return jnp.zeros((e, cap, d), x.dtype).at[se_r, sp_r].add(
            c_r, mode="drop")

    xin = jax.vmap(disp_row)(contrib, safe_e, safe_p)      # [B, E, C, d]
    xin = shard_act(xin, ("batch", "experts", None, None))

    # ---- 4. batched expert FFN (EP over experts, DP over rows) ---------
    f = cfg.moe_d_ff or cfg.d_ff
    wg = _expert_bank(params["gate"], (e, f, d))
    wu = _expert_bank(params["up"], (e, f, d))
    wd = _expert_bank(params["down"], (e, d, f))
    xin_c = xin.astype(wg.dtype)
    g = jnp.einsum("becd,efd->becf", xin_c, wg,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("becd,efd->becf", xin_c, wu,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    yout = jnp.einsum("becf,edf->becd", h.astype(wd.dtype), wd,
                      preferred_element_type=jnp.float32)  # [B, E, C, d]
    # NOTE: casting the combine path to bf16 does NOT shrink the EP
    # cross-shard all-reduces — XLA promotes the reduction back to f32
    # ("add.clone_promoted"); the identified next lever is a shard_map
    # all-to-all dispatch (est. ~16x on this term), see EXPERIMENTS §Perf.
    yout = shard_act(yout, ("batch", "experts", None, None))

    # ---- 5. combine (row-local gather) ---------------------------------
    vals = jax.vmap(lambda yo_r, se_r, sp_r: yo_r[se_r, sp_r])(
        yout, safe_e, safe_p)                              # [B, n, d]
    vals = shard_act(vals, ("batch", None, None))
    vals = jnp.where(keep[..., None], vals, 0.0) * \
        gates.reshape(b, n)[..., None].astype(x.dtype)
    y = jax.vmap(lambda v_r: jnp.zeros((s, d), jnp.float32)
                 .at[token_idx].add(v_r.astype(jnp.float32)))(vals)
    y = shard_act(y, ("batch", None, None))

    if "shared_gate" in params:
        from repro.core.quantized_linear import linear_apply
        sg = linear_apply(params["shared_gate"], x, backend=backend)
        su = linear_apply(params["shared_up"], x, backend=backend)
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        y = y + linear_apply(params["shared_down"], sh, backend=backend
                             ).astype(jnp.float32)

    return y.astype(x.dtype)


def router_aux_loss(params, x, cfg):
    """Load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,ed->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, experts = jax.lax.top_k(probs, cfg.experts_per_token)
    frac = jnp.mean(jax.nn.one_hot(experts, cfg.n_experts).sum(1), axis=0)
    return cfg.n_experts * jnp.sum(frac * probs.mean(0))
