"""Shared building blocks: norms, rotary embeddings, MLPs, embeddings.

Every linear weight is declared as a ParamDesc [out, in] and executed via
``core.linear_apply`` — which transparently runs dense arrays or
BCQ-quantized ``BCQWeight`` leaves on the configured backend.  That single
dispatch point is how FIGLUT integrates as a first-class feature across
all ten architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantized_linear import linear_apply
from repro.models.module import ParamDesc


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_desc(cfg, dim=None):
    d = dim or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": ParamDesc((d,), jnp.float32, ("embed",), "ones")}
    return {"scale": ParamDesc((d,), jnp.float32, ("embed",), "ones"),
            "bias": ParamDesc((d,), jnp.float32, ("embed",), "zeros")}


def norm_apply(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    if "bias" in params:                       # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"] + params["bias"]
    else:                                      # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D] (D even), positions: [B, S] or [S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_desc(cfg, d_ff=None):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    if cfg.mlp_act == "swiglu":
        return {
            "gate": ParamDesc((f, d), jnp.bfloat16, ("mlp", "embed")),
            "up": ParamDesc((f, d), jnp.bfloat16, ("mlp", "embed")),
            "down": ParamDesc((d, f), jnp.bfloat16, ("embed", "mlp")),
        }
    return {
        "up": ParamDesc((f, d), jnp.bfloat16, ("mlp", "embed")),
        "up_b": ParamDesc((f,), jnp.float32, ("mlp",), "zeros"),
        "down": ParamDesc((d, f), jnp.bfloat16, ("embed", "mlp")),
        "down_b": ParamDesc((d,), jnp.float32, ("embed",), "zeros"),
    }


def mlp_apply(params, x, backend=None):
    if "gate" in params:
        g = linear_apply(params["gate"], x, backend=backend)
        u = linear_apply(params["up"], x, backend=backend)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return linear_apply(params["down"], h, backend=backend)
    h = linear_apply(params["up"], x, params.get("up_b"), backend=backend)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return linear_apply(params["down"], h, params.get("down_b"), backend=backend)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def embed_desc(cfg):
    d = {"tok": ParamDesc((cfg.padded_vocab, cfg.d_model), jnp.bfloat16,
                          ("vocab", "embed"), "embed")}
    if cfg.pos == "learned":
        d["pos"] = ParamDesc((cfg.max_seq_len, cfg.d_model), jnp.bfloat16,
                             (None, "embed"), "embed")
    if not cfg.tie_embeddings:
        d["unembed"] = ParamDesc((cfg.padded_vocab, cfg.d_model), jnp.bfloat16,
                                 ("vocab", "embed"))
    return d


def embed_apply(params, tokens, positions=None):
    x = jnp.take(params["tok"], tokens, axis=0)        # [B, S, d]
    if "pos" in params and positions is not None:
        x = x + jnp.take(params["pos"], positions, axis=0)
    return x


def unembed_apply(params, x, backend=None):
    from repro.parallel.sharding import shard_act
    w = params.get("unembed", params["tok"])           # tied if absent
    logits = linear_apply(w, x, backend=backend, out_dtype=jnp.float32)
    axes = ("batch",) + (None,) * (logits.ndim - 2) + ("vocab",)
    return shard_act(logits, axes)
