"""Minimal functional module system: parameter descriptors -> params.

No flax/haiku on this box, and the dry-run must build parameter *shapes*
without allocating 236B-scale weights — so model definitions construct
trees of :class:`ParamDesc` (shape, dtype, logical axes, initializer), and
three interpreters consume them:

  * ``init_params``     — materialize real arrays (tests/examples/training)
  * ``abstract_params`` — ShapeDtypeStructs only (the dry-run path)
  * ``logical_axes``    — same-structure tree of logical-axis tuples, fed to
                          ``parallel.sharding.to_named_sharding``

Logical axis names used across the zoo:
  "embed"    — d_model            -> usually replicated (or fsdp)
  "vocab"    — vocabulary         -> model
  "heads"    — attention heads    -> model
  "kv_heads" — kv heads           -> model (with replication fallback)
  "head_dim" — per-head dim       -> None
  "mlp"      — ffn hidden         -> model
  "experts"  — MoE expert count   -> model (EP) / None
  "layers"   — stacked-scan layer -> None
  "lora"     — MLA latent dim     -> None
  "state"    — SSM state dim      -> None
  "fsdp"     — weight-sharded dp  -> data (when fsdp enabled)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDesc:
    """Declarative parameter: everything needed to init/shard/abstract it."""
    shape: tuple
    dtype: Any = jnp.float32
    axes: tuple = ()                 # logical axes, len == len(shape)
    init: str = "normal"             # normal | zeros | ones | embed | scan_normal
    scale: float = 0.02

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} rank != shape {self.shape}")


def is_desc(x) -> bool:
    return isinstance(x, ParamDesc)


def _leaf_init(key, d: ParamDesc) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init in ("normal", "embed", "scan_normal"):
        return (jax.random.normal(key, d.shape, jnp.float32) * d.scale
                ).astype(d.dtype)
    raise ValueError(f"unknown init {d.init}")


def init_params(rng: jax.Array, tree) -> Any:
    """Materialize a descriptor tree into real arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_desc)
    keys = jax.random.split(rng, len(leaves))
    out = [_leaf_init(k, d) if is_desc(d) else d for k, d in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(tree) -> Any:
    """ShapeDtypeStruct tree — zero allocation (dry-run path)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype) if is_desc(d) else d,
        tree, is_leaf=is_desc)


def logical_axes(tree) -> Any:
    """Tree of logical-axis tuples matching the descriptor tree."""
    return jax.tree_util.tree_map(
        lambda d: d.axes if is_desc(d) else None, tree, is_leaf=is_desc)


def param_count(tree) -> int:
    total = 0
    for d in jax.tree_util.tree_leaves(tree, is_leaf=is_desc):
        if is_desc(d):
            total += int(np.prod(d.shape))
    return total


def param_bytes(tree) -> int:
    total = 0
    for d in jax.tree_util.tree_leaves(tree, is_leaf=is_desc):
        if is_desc(d):
            total += int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
    return total


def tree_map_with_path(fn, tree, path=()):
    """tree_map over dict/list/tuple trees, calling ``fn(path, leaf)``
    with the tuple of keys/indices leading to each leaf."""
    if isinstance(tree, dict):
        return {k: tree_map_with_path(fn, v, path + (k,))
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(tree_map_with_path(fn, v, path + (i,))
                          for i, v in enumerate(tree))
    return fn(path, tree)
