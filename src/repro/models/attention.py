"""Attention: GQA / MLA / sliding-window, blockwise (flash-style) softmax,
unified KV caches (full + ring-buffer), cross-attention.

Memory design: prefill/train never materialize the full [Sq, Skv] score
matrix — queries and keys are processed in chunks with online softmax
(lax.scan over KV blocks inside a scan over Q blocks), so 32k-sequence
cells fit.  An optional ``kv_map_fn`` decompresses latent (MLA) KV blocks
inside the inner scan, keeping decompressed K/V transient.

KV cache layout (GQA):  {k, v: [B, L, Hkv, D], pos: [B, L] int32}
``pos`` holds the absolute position stored in each slot (-1 = empty); a
ring buffer (sliding window) is just L = window with slot = pos % L —
masking via ``pos`` makes full and ring caches the same code path.

MLA cache: {ckv: [B, L, lora], krope: [B, L, rope_dim], pos: [B, L]} —
the paper-exact compressed cache; decode uses the absorbed formulation
(scores in latent space) so per-step cost is O(L * lora), not O(L * H * d).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.quantized_linear import linear_apply
from repro.models.layers import apply_rope
from repro.models.module import ParamDesc
from repro.parallel.sharding import shard_act

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter descriptors
# ---------------------------------------------------------------------------


def attn_desc(cfg):
    d = cfg.d_model
    hd = cfg.head_dim_
    if cfg.attention == "mla":
        qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        p = {}
        if cfg.q_lora_rank:
            p["q_a"] = ParamDesc((cfg.q_lora_rank, d), jnp.bfloat16, ("lora", "embed"))
            p["q_a_norm"] = ParamDesc((cfg.q_lora_rank,), jnp.float32, ("lora",), "ones")
            p["q_b"] = ParamDesc((cfg.n_heads * qk_head, cfg.q_lora_rank),
                                 jnp.bfloat16, ("heads", "lora"))
        else:
            p["q"] = ParamDesc((cfg.n_heads * qk_head, d), jnp.bfloat16,
                               ("heads", "embed"))
        p["kv_a"] = ParamDesc((cfg.kv_lora_rank + cfg.qk_rope_head_dim, d),
                              jnp.bfloat16, ("lora", "embed"))
        p["kv_a_norm"] = ParamDesc((cfg.kv_lora_rank,), jnp.float32, ("lora",), "ones")
        p["kv_b"] = ParamDesc(
            (cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim), cfg.kv_lora_rank),
            jnp.bfloat16, ("heads", "lora"))
        p["o"] = ParamDesc((d, cfg.n_heads * cfg.v_head_dim), jnp.bfloat16,
                           ("embed", "heads"))
        return p
    p = {
        "q": ParamDesc((cfg.n_heads * hd, d), jnp.bfloat16, ("heads", "embed")),
        "k": ParamDesc((cfg.n_kv_heads * hd, d), jnp.bfloat16, ("kv_heads", "embed")),
        "v": ParamDesc((cfg.n_kv_heads * hd, d), jnp.bfloat16, ("kv_heads", "embed")),
        "o": ParamDesc((d, cfg.n_heads * hd), jnp.bfloat16, ("embed", "heads")),
    }
    if cfg.qkv_bias:
        p["q_b"] = ParamDesc((cfg.n_heads * hd,), jnp.float32, ("heads",), "zeros")
        p["k_b"] = ParamDesc((cfg.n_kv_heads * hd,), jnp.float32, ("kv_heads",), "zeros")
        p["v_b"] = ParamDesc((cfg.n_kv_heads * hd,), jnp.float32, ("kv_heads",), "zeros")
    return p


def cross_attn_desc(cfg):
    """Whisper decoder cross-attention (K/V from encoder output)."""
    return attn_desc(cfg)


# ---------------------------------------------------------------------------
# blockwise attention core
# ---------------------------------------------------------------------------


def _mask(qpos, kpos, causal: bool, window: int):
    """qpos [..., Sq, 1], kpos [..., 1, Sk] -> additive mask."""
    ok = kpos >= 0
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= (qpos - kpos) < window
    return jnp.where(ok, 0.0, NEG_INF)


def blockwise_attention(q, k, v, qpos, kpos, *, causal=True, window=0,
                        scale=None, q_chunk=512, kv_chunk=1024,
                        kv_map_fn: Optional[Callable] = None):
    """Online-softmax attention.

    q: [B, Sq, H, Dq]; k: [B, Sk, Hkv, Dq] (or latent [B, Sk, *] with
    kv_map_fn); v: [B, Sk, Hkv, Dv] (or None with kv_map_fn).
    qpos: [B, Sq] absolute positions; kpos: [B, Sk] (-1 = empty slot).
    kv_map_fn(k_blk, v_blk) -> (k [B,c,Hkv,Dq], v [B,c,Hkv,Dv]).
    Returns [B, Sq, H, Dv] in q.dtype (FP32 accumulation).
    """
    b, sq, h, dq = q.shape
    sk = k.shape[1]
    if kv_map_fn is None:
        kv_map_fn = lambda kb, vb: (kb, vb)
        hkv = k.shape[2]
        dv = v.shape[-1]
    else:
        kb0, vb0 = jax.eval_shape(kv_map_fn, k[:, :1], None if v is None else v[:, :1])
        hkv, dv = kb0.shape[2], vb0.shape[-1]
    rep = h // hkv
    scale = scale if scale is not None else dq ** -0.5

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq = -(-sq // q_chunk)
    nk = -(-sk // kv_chunk)
    sq_pad, sk_pad = nq * q_chunk, nk * kv_chunk
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, sq_pad - sq)))
    if sk_pad != sk:
        pad = [(0, 0), (0, sk_pad - sk)] + [(0, 0)] * (k.ndim - 2)
        k = jnp.pad(k, pad)
        if v is not None:
            v = jnp.pad(v, [(0, 0), (0, sk_pad - sk)] + [(0, 0)] * (v.ndim - 2))
        kpos = jnp.pad(kpos, ((0, 0), (0, sk_pad - sk)), constant_values=-1)

    qc = q.reshape(b, nq, q_chunk, h, dq).transpose(1, 0, 2, 3, 4)
    qposc = qpos.reshape(b, nq, q_chunk).transpose(1, 0, 2)
    kc = k.reshape(b, nk, kv_chunk, *k.shape[2:])
    kc = jnp.moveaxis(kc, 1, 0)
    vc = None
    if v is not None:
        vc = jnp.moveaxis(v.reshape(b, nk, kv_chunk, *v.shape[2:]), 1, 0)
    kposc = jnp.moveaxis(kpos.reshape(b, nk, kv_chunk), 1, 0)

    def q_block(qi, qpi):
        # qi: [B, qc, H, Dq] -> grouped [B, qc, Hkv, rep, Dq].  Operands
        # stay in their storage dtype (bf16 on TPU) with FP32 accumulation
        # — the MXU-native mode; upcasting K/V blocks to f32 would double
        # the cache-read bytes that dominate long-context cells.
        qg = (qi.reshape(b, q_chunk, hkv, rep, dq)
              .astype(jnp.float32) * scale).astype(qi.dtype)

        def kv_step(carry, inp):
            acc, m, l = carry
            kb, vb, kpb = (inp if vc is not None else (inp[0], None, inp[1]))
            kb, vb = kv_map_fn(kb, vb)
            s = jnp.einsum("bqhrd,bkhd->bqhrk", qg, kb.astype(qg.dtype),
                           preferred_element_type=jnp.float32)
            # keep score blocks (and thus the autodiff residual stack built
            # from them) sharded — GSPMD drops batch sharding on nested-scan
            # residuals without this (observed 16 GiB vs 1 GiB per device)
            s = shard_act(s, ("batch", None, "kv_heads", None, None))
            s = s + _mask(qpi[:, :, None, None, None],
                          kpb[:, None, None, None, :], causal, window)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhrk,bkhd->bqhrd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        init = (jnp.zeros((b, q_chunk, hkv, rep, dv), jnp.float32),
                jnp.full((b, q_chunk, hkv, rep), NEG_INF, jnp.float32),
                jnp.zeros((b, q_chunk, hkv, rep), jnp.float32))
        xs = (kc, vc, kposc) if vc is not None else (kc, kposc)
        (acc, m, l), _ = jax.lax.scan(kv_step, init, xs)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(b, q_chunk, h, dv)

    outs = jax.lax.map(lambda args: q_block(*args), (qc, qposc))

    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq_pad, h, dv)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------


def cache_desc_gqa(cfg, batch: int, length: int):
    hd = cfg.head_dim_
    hkv = cfg.n_kv_heads * cfg.kv_replication
    dt = jnp.dtype(cfg.dtype)
    if cfg.sliding_window:
        length = min(length, cfg.sliding_window)
    c = {
        "k": ParamDesc((batch, length, hkv, hd),
                       jnp.int8 if cfg.kv_cache_bits == 8 else dt,
                       ("batch", None, "kv_heads", "head_dim"), "zeros"),
        "v": ParamDesc((batch, length, hkv, hd),
                       jnp.int8 if cfg.kv_cache_bits == 8 else dt,
                       ("batch", None, "kv_heads", "head_dim"), "zeros"),
        "pos": ParamDesc((batch, length), jnp.int32, ("batch", None), "zeros"),
    }
    if cfg.kv_cache_bits == 8:
        # symmetric per-(slot, head) scales
        c["k_scale"] = ParamDesc((batch, length, hkv), jnp.float32,
                                 ("batch", None, "kv_heads"), "zeros")
        c["v_scale"] = ParamDesc((batch, length, hkv), jnp.float32,
                                 ("batch", None, "kv_heads"), "zeros")
    return c


def _quantize_kv(t):
    """bf16 [B,S,H,D] -> (int8 values, f32 per-(slot,head) scales)."""
    tf = t.astype(jnp.float32)
    scale = jnp.max(jnp.abs(tf), axis=-1) / 127.0 + 1e-9
    q = jnp.clip(jnp.round(tf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def cache_desc_mla(cfg, batch: int, length: int):
    # the MLA latent has no heads dim to shard over the model axis, so the
    # SEQUENCE dim is sharded instead ("kv_seq" -> model): decode attention
    # over a sequence-sharded cache is a partial softmax + small all-reduce,
    # vs 16x cache replication otherwise.
    dt = jnp.dtype(cfg.dtype)
    return {
        "ckv": ParamDesc((batch, length, cfg.kv_lora_rank), dt,
                         ("batch", "kv_seq", "lora"), "zeros"),
        "krope": ParamDesc((batch, length, cfg.qk_rope_head_dim), dt,
                           ("batch", "kv_seq", None), "zeros"),
        "pos": ParamDesc((batch, length), jnp.int32, ("batch", "kv_seq"),
                         "zeros"),
    }


def paged_cache_desc(cfg, batch: int, num_blocks: int, block_size: int,
                     max_blocks_per_seq: int):
    """Paged per-layer cache: the contiguous descriptors with batch ->
    num_blocks and length -> block_size (the pool), plus the block table.

    Sliding-window attention keeps its ring cache (paging a ring buys
    nothing: the window is already a fixed-size reservation), so paged
    caches are only built for full-attention configs.
    """
    if cfg.sliding_window:
        raise ValueError("paged KV cache requires sliding_window == 0 "
                         "(ring caches are already fixed-size)")
    base = (cache_desc_mla if cfg.attention == "mla" else cache_desc_gqa)(
        cfg, num_blocks, block_size)
    base["block_tables"] = ParamDesc((batch, max_blocks_per_seq), jnp.int32,
                                     ("batch", None), "zeros")
    return base


def empty_pos(pos_like):
    return jnp.full_like(pos_like, -1)


# --- paged layout -----------------------------------------------------------
#
# A paged per-layer cache stores every buffer as a shared pool
# [num_blocks, block_size, ...] plus a ``block_tables`` leaf
# [B, max_blocks_per_seq] int32 mapping each sequence's logical block i
# (positions [i*bs, (i+1)*bs)) to a physical pool block (-1 = unallocated).
# Physical block 0 is reserved as a trash block: any write whose target is
# out of range or unallocated lands there, and ``paged_view`` masks every
# slot reached through a -1 table entry, so trash contents are never read.
# The same ``pos``-based masking that drives the contiguous cache then
# makes a gathered view of the pool indistinguishable from a contiguous
# cache to the attention math.
#
# Decode (S == 1) does not need the gathered view at all: the fused
# Pallas kernels (``kernels/paged_attention``) apply the identical
# liveness mask inside the kernel while reading pool blocks directly
# through the block table — float, int8 (per-slot scales ride the same
# block DMA) and MLA-latent pools all run fused.  Chunked prefill
# (S > 1) has its own fused kernel reading prior context straight from
# the pool with per-query causal masking, so ``paged_view`` is only
# materialized on the remaining gathered fallbacks: sliding-window
# masking, mesh-indivisible head counts, and MLA *prefill* (which needs
# the decompressing ``kv_map_fn``) — see ``paged_decode_attend`` /
# ``paged_prefill_attend`` / ``mla_paged_decode_attend``.


def is_paged(cache: dict) -> bool:
    return "block_tables" in cache


def kv_entry_bytes(cfg) -> int:
    """KV-cache storage bytes per (token, layer) — the unit of the
    decode-bandwidth accounting in serve metrics and benchmarks."""
    if cfg.attention == "mla":
        return (cfg.kv_lora_rank + cfg.qk_rope_head_dim) \
            * jnp.dtype(cfg.dtype).itemsize
    hkv = cfg.n_kv_heads * cfg.kv_replication
    d = cfg.head_dim_
    if cfg.kv_cache_bits == 8:
        return 2 * hkv * d + 2 * hkv * 4        # int8 K/V + f32 scales
    return 2 * hkv * d * jnp.dtype(cfg.dtype).itemsize


def paged_view(cache: dict) -> dict:
    """Gather a per-sequence contiguous view of a paged cache.

    Returns a dict shaped like a contiguous cache ([B, max_blocks * bs,
    ...]) whose ``pos`` is -1 wherever the slot is not live — directly
    consumable by ``decode_attend`` / ``blockwise_attention``.
    """
    table = cache["block_tables"]                 # [B, nblk]
    b, nblk = table.shape
    bs = cache["pos"].shape[1]
    safe = jnp.maximum(table, 0).reshape(-1)
    view = {}
    for key, val in cache.items():
        if key == "block_tables":
            continue
        g = jnp.take(val, safe, axis=0)           # [B*nblk, bs, ...]
        view[key] = g.reshape(b, nblk * bs, *val.shape[2:])
    # A slot is live iff its table entry is allocated AND its stored
    # position equals its logical view index (position p always lands at
    # view index p).  The second check is what makes pool recycling
    # safe: a freed block re-allocated at a different logical index
    # still holds the previous owner's pos values, which would otherwise
    # pass the kpos <= qpos mask and leak dead K/V into attention.
    allocated = jnp.repeat(table >= 0, bs, axis=1)            # [B, nblk*bs]
    iota = jnp.arange(nblk * bs, dtype=jnp.int32)[None]
    view["pos"] = jnp.where(allocated & (view["pos"] == iota),
                            view["pos"], -1)
    return view


def _paged_insert(cache: dict, updates: dict, at) -> dict:
    """Scatter S new entries into the block pool via the block tables.

    Position p of row b lives at physical slot ``table[b, p // bs] * bs
    + p % bs``.  Writes with a negative position (masked left-pads), a
    logical block beyond the table, or an unallocated table entry are
    routed to the reserved trash block 0.
    """
    table = cache["block_tables"]                 # [B, nblk]
    nb, bs = cache["pos"].shape
    b, nblk = table.shape
    s = next(iter(updates.values())).shape[1]
    at = jnp.asarray(at, jnp.int32)
    if at.ndim == 0:
        at = jnp.broadcast_to(at, (b,))
    positions = at[:, None] + jnp.arange(s, dtype=jnp.int32)[None]   # [B, S]
    blk = positions // bs
    phys = jnp.take_along_axis(table, jnp.clip(blk, 0, nblk - 1), axis=1)
    valid = (positions >= 0) & (blk < nblk) & (phys >= 0)
    phys = jnp.where(valid, phys, 0)              # invalid -> trash block
    flat = phys * bs + positions % bs             # [B, S] into [nb*bs]

    new = dict(cache)
    for key, val in updates.items():
        buf = cache[key]
        fb = buf.reshape(nb * bs, *buf.shape[2:])
        new[key] = fb.at[flat].set(val.astype(buf.dtype)).reshape(buf.shape)
    posf = cache["pos"].reshape(nb * bs)
    new["pos"] = posf.at[flat].set(
        jnp.where(valid, positions, -1)).reshape(nb, bs)
    return new


def cache_insert(cache: dict, updates: dict, at):
    """Write S new entries starting at absolute position ``at``.

    ``at`` is a scalar or per-row [B] vector (ragged continuous batching).
    Slot convention: position p lives at slot p % L (ring semantics; a
    full-length cache is the special case L >= max position).  Paged
    caches (``block_tables`` present) scatter through the block table
    instead — see ``_paged_insert``.
    ``updates`` maps cache keys -> [B, S, ...] new values.
    """
    if is_paged(cache):
        return _paged_insert(cache, updates, at)
    b, length = cache["pos"].shape
    s = next(iter(updates.values())).shape[1]
    if s > length:
        # writing more than the ring holds (SWA prefill > window): only the
        # trailing `length` entries survive.
        updates = {k: v[:, -length:] for k, v in updates.items()}
        at = at + (s - length)
        s = length
    at = jnp.asarray(at, jnp.int32)
    if at.ndim == 0:
        at = jnp.broadcast_to(at, (b,))
    positions = at[:, None] + jnp.arange(s, dtype=jnp.int32)[None]   # [B, S]
    slots = positions % length
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None]

    new = dict(cache)
    for key, val in updates.items():
        new[key] = cache[key].at[bidx, slots].set(val.astype(cache[key].dtype))
    new["pos"] = cache["pos"].at[bidx, slots].set(positions)
    return new


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def _split_heads(x, n, d):
    return x.reshape(*x.shape[:-1], n, d)


def gqa_apply(params, cfg, x, positions, *, cache=None, cache_at=None,
              causal=True, backend=None):
    """GQA/MHA/SWA attention.

    x: [B, S, d]; positions: [B, S].
    cache=None          -> train/eval full-sequence attention.
    cache + cache_at    -> write new KV at ``cache_at`` then attend to cache
                           (prefill: S>1; decode: S=1). Returns (out, cache).
    """
    b, s, d = x.shape
    hd = cfg.head_dim_
    h, hkv = cfg.n_heads, cfg.n_kv_heads

    q = _split_heads(linear_apply(params["q"], x, params.get("q_b"),
                                  backend=backend), h, hd)
    k = _split_heads(linear_apply(params["k"], x, params.get("k_b"),
                                  backend=backend), hkv, hd)
    v = _split_heads(linear_apply(params["v"], x, params.get("v_b"),
                                  backend=backend), hkv, hd)
    if cfg.kv_replication > 1:
        # replicate kv heads so the cache shards over TP > n_kv_heads:
        # q head i groups with effective kv head i // (H / (hkv*r))
        k = jnp.repeat(k, cfg.kv_replication, axis=2)
        v = jnp.repeat(v, cfg.kv_replication, axis=2)
        hkv = hkv * cfg.kv_replication
    q = shard_act(q, ("batch", None, "heads", None))
    k = shard_act(k, ("batch", None, "kv_heads", None))
    v = shard_act(v, ("batch", None, "kv_heads", None))

    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = blockwise_attention(q, k, v, positions, positions, causal=causal,
                                  window=cfg.sliding_window)
    elif cfg.kv_cache_bits == 8:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        cache = cache_insert(cache, {"k": kq, "v": vq,
                                     "k_scale": ks, "v_scale": vs}, cache_at)
        if s == 1:
            if is_paged(cache):
                # the router's fused int8 kernel folds the per-slot
                # scales in-kernel (decode_attend's ordering)
                out = paged_decode_attend(q, cache, positions,
                                          window=cfg.sliding_window,
                                          mode=cfg.paged_kernel)
            else:
                out = decode_attend(q, cache, positions,
                                    window=cfg.sliding_window)
        elif is_paged(cache):
            # chunked prefill: earlier chunks are only in the cache
            # (unlike the whole-prompt path below, the cache is NOT
            # empty here) — the router reads pool blocks directly and
            # dequantizes in-kernel, or gathers + dequantizes the view
            out = paged_prefill_attend(q, cache, positions,
                                       mode=cfg.paged_kernel)
        else:
            # prefill: attend over the fresh bf16 K/V (the cache was empty,
            # so causal/windowed attention over the prompt is equivalent) —
            # quantization error then only affects subsequent decode reads
            out = blockwise_attention(q, k, v, positions, positions,
                                      causal=True, window=cfg.sliding_window)
    elif s == 1:
        # decode fast path: contract in cache layout, bf16 reads; paged
        # caches route through the fused-vs-gathered kernel selector
        cache = cache_insert(cache, {"k": k, "v": v}, cache_at)
        if is_paged(cache):
            out = paged_decode_attend(q, cache, positions,
                                      window=cfg.sliding_window,
                                      mode=cfg.paged_kernel)
        else:
            out = decode_attend(q, cache, positions, window=cfg.sliding_window)
    else:
        cache = cache_insert(cache, {"k": k, "v": v}, cache_at)
        if is_paged(cache):
            out = paged_prefill_attend(q, cache, positions,
                                       mode=cfg.paged_kernel)
        else:
            out = blockwise_attention(q, cache["k"], cache["v"], positions,
                                      cache["pos"], causal=True,
                                      window=cfg.sliding_window)
    out = out.reshape(b, s, h * hd)
    out = linear_apply(params["o"], out, backend=backend)
    return (out, cache) if cache is not None else out


def decode_attend(q, cache, positions, *, window=0, scale=None):
    """Single-token attention against a cache, in storage layout.

    The generic blockwise path reshapes/transposes the whole cache into
    chunk-major order and upcasts chunks to f32 — ~4 extra cache-sized
    copies per layer that dominate the decode memory roofline.  Here the
    contractions run directly on the [B, L, Hkv, D] buffers in bf16
    (FP32 accumulation via preferred_element_type), no reshuffling.

    q: [B, 1, H, D]; positions: [B, 1] absolute position of the token.
    """
    k, v, kpos = cache["k"], cache["v"], cache["pos"]
    b, s, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else d ** -0.5
    if k.dtype == jnp.int8:
        # int8 KV: fold the per-slot scale into the score after an int8-read
        # contraction (the dequant multiply fuses into the dot epilogue)
        qg = (q.reshape(b, hkv, rep, d).astype(jnp.float32) * scale)
        sc = jnp.einsum("bhrd,blhd->bhrl", qg.astype(jnp.bfloat16),
                        k.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        sc = sc * cache["k_scale"].transpose(0, 2, 1)[:, :, None, :]
        v_eff = v.astype(jnp.bfloat16)
    else:
        # scale in f32 THEN round to the storage dtype — identical rounding
        # to the blockwise path so decode == forward to f32-accum noise
        qg = (q.reshape(b, hkv, rep, d).astype(jnp.float32) * scale
              ).astype(k.dtype)
        sc = jnp.einsum("bhrd,blhd->bhrl", qg, k,
                        preferred_element_type=jnp.float32)  # [B,Hkv,rep,L]
        v_eff = v
    # pin the (small) score sharding: when the cache shards head_dim over
    # the model axis, GSPMD otherwise prefers ALL-GATHERING the whole KV
    # cache per layer (~34 GB/step at 32k) over all-reducing these scores
    sc = shard_act(sc, ("batch", "kv_heads", None, "kv_seq"))
    ok = (kpos >= 0) & (kpos <= positions[:, :1])
    if window:
        ok &= (positions[:, :1] - kpos) < window
    sc = jnp.where(ok[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    if k.dtype == jnp.int8:
        p = p * cache["v_scale"].transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bhrl,blhd->bhrd", p.astype(v_eff.dtype), v_eff,
                     preferred_element_type=jnp.float32)
    out = shard_act(out, ("batch", "kv_heads", None, "head_dim"))
    return out.reshape(b, 1, h, d).astype(q.dtype)


PAGED_KERNEL_MODES = ("auto", "fused", "gather")


# --- sharded paged decode --------------------------------------------------
#
# When the paged serve engine runs over a TP/DP mesh, the KV pool leaves
# are sharded over "kv_heads" -> model and the fused kernel must launch
# per model-shard (a Pallas call has no GSPMD partitioning rule, so under
# plain jit XLA would all-gather the pool).  The engine declares the mesh
# through ``paged_shard_scope`` around its (trace-triggering) decode
# calls, and ``paged_decode_attend`` routes the kernel through
# ``shard_map`` over the mesh: each shard reads its own kv-head slice of
# every pool block, block tables/positions ride along replicated (or
# data-sharded with the batch rows), and no cross-device traffic happens
# inside the step at all — heads are embarrassingly parallel in decode
# attention.  The per-shard head counts feed ``tune.dispatch`` for the
# capability probe and ``block_h`` clamping, so head counts that do not
# divide the mesh fall back to the gathered ``paged_view`` path exactly
# like the other unsupported variants.

_PAGED_SHARD = {"mesh": None, "tp": 1, "shard_batch": False}


@contextlib.contextmanager
def paged_shard_scope(mesh, *, tp: int = 1, shard_batch: bool = False):
    """Declare the serving mesh for paged decode tracing.

    Active while the engine's jitted ``decode_step`` traces (tracing
    happens inside the first call, so the engine wraps every call);
    restores the previous scope on exit so engines with different
    meshes (or none) can coexist in one process."""
    prev = dict(_PAGED_SHARD)
    _PAGED_SHARD.update(mesh=mesh, tp=tp, shard_batch=shard_batch)
    try:
        yield
    finally:
        _PAGED_SHARD.update(prev)


def _fused_selected(mode: str, supported: bool) -> bool:
    """The single fused-vs-gather routing rule, shared by the device
    path (:func:`paged_decode_attend`) and the host mirror
    (:func:`paged_kernel_mode`) so the engine's labeling/metrics can
    never drift from the path the decode step actually takes: explicit
    "fused" runs wherever the kernel is supported (interpret mode
    off-TPU); "auto" additionally requires it to be hardware-native."""
    if mode not in PAGED_KERNEL_MODES:
        raise ValueError(f"paged_kernel must be one of {PAGED_KERNEL_MODES}, "
                         f"got {mode!r}")
    if mode == "gather" or not supported:
        return False
    return mode == "fused" or jax.default_backend() == "tpu"


def _paged_cache_caps(cache: dict, n_heads: int) -> dict:
    """The capability axes of a paged cache leaf, as the ``caps`` kwargs
    for ``tune.dispatch.kernel_unsupported_reason``.  MLA latent pools
    (``ckv`` leaf) probe with ``latent=True`` and kv heads == q heads
    (no replication in the absorbed formulation — heads are
    embarrassingly parallel over latent blocks)."""
    if "ckv" in cache:
        return dict(n_kv_heads=n_heads, kv_dtype=cache["ckv"].dtype,
                    latent=True)
    return dict(n_kv_heads=cache["k"].shape[2], kv_dtype=cache["k"].dtype,
                latent=False)


def fused_paged_supported(cache: dict, n_heads: int, *, window: int = 0,
                          tp: int = 1,
                          kernel: str = "paged_attention") -> bool:
    """Can a fused Pallas kernel serve this paged cache leaf?  Float,
    int8 (per-slot scale fold) and MLA-latent pools are covered for
    decode; float and int8 for chunked prefill
    (``kernel="paged_prefill"``).  Sliding-window masking, head counts
    that don't divide a ``tp``-way model mesh, and MLA prefill fall back
    to the gathered path — the capability boundary (and the per-cap
    fallback reason) lives in ``tune.dispatch.kernel_unsupported_reason``.
    """
    from repro.tune.dispatch import kernel_unsupported_reason
    if not is_paged(cache):
        return False
    bs = cache["pos"].shape[1]
    pages = cache["block_tables"].shape[-1]
    return kernel_unsupported_reason(
        kernel, m=n_heads, n=pages * bs, group_size=bs, window=window,
        tp=tp, **_paged_cache_caps(cache, n_heads)) is None


def _cfg_paged_caps(cfg) -> dict:
    """Config-level mirror of :func:`_paged_cache_caps` (for the host-
    side mode resolvers, which have no cache leaf to inspect)."""
    if cfg.attention == "mla":
        return dict(n_kv_heads=cfg.n_heads, kv_dtype=cfg.dtype, latent=True)
    return dict(n_kv_heads=cfg.n_kv_heads * cfg.kv_replication,
                kv_dtype="int8" if cfg.kv_cache_bits == 8 else cfg.dtype,
                latent=False)


def paged_kernel_mode(cfg, *, block_size: int, pages: int,
                      tp: int = 1) -> str:
    """Host-side mirror of the decode routing decision: resolve
    ``cfg.paged_kernel`` to the path ("fused" | "gather") a decode step
    on this config's paged cache will actually take — PER VARIANT, so
    an int8-KV or MLA config reports "fused" iff its own kernel variant
    really runs (no silent "fused" label on a gathered step).  Used by
    the serve engine for labeling and KV-bandwidth accounting — the
    device-side decisions in :func:`paged_decode_attend` /
    :func:`mla_paged_decode_attend` follow the same rule.  ``tp`` is the
    model-axis extent when serving over a mesh (the fused kernel then
    launches per-shard via ``shard_map``)."""
    from repro.tune.dispatch import kernel_supports
    ok = kernel_supports(
        "paged_attention", m=cfg.n_heads, n=pages * block_size,
        group_size=block_size, window=cfg.sliding_window, tp=tp,
        **_cfg_paged_caps(cfg))
    return "fused" if _fused_selected(cfg.paged_kernel, ok) else "gather"


def paged_prefill_mode(cfg, *, block_size: int, pages: int,
                       tp: int = 1) -> str:
    """Host-side mirror of the CHUNKED-PREFILL routing decision —
    :func:`paged_kernel_mode`'s counterpart for ``paged_prefill_attend``.
    MLA prefill always resolves to "gather" (the latent blocks must be
    decompressed through ``kv_map_fn``, which the prefill kernel does
    not fold)."""
    from repro.tune.dispatch import kernel_supports
    ok = kernel_supports(
        "paged_prefill", m=cfg.n_heads, n=pages * block_size,
        group_size=block_size, window=cfg.sliding_window, tp=tp,
        **_cfg_paged_caps(cfg))
    return "fused" if _fused_selected(cfg.paged_kernel, ok) else "gather"


def paged_decode_attend(q, cache, positions, *, window=0, scale=None,
                        mode="auto"):
    """Single-token attention on a PAGED cache.

    When the fused Pallas kernel is selected, the block-table gather
    happens *inside* the kernel (scalar-prefetched index_map) and the
    contiguous ``paged_view`` is never materialized — the decode path
    reads each live pool block exactly once instead of copying the whole
    table-addressable view per layer.  Otherwise: gather (``paged_view``)
    + :func:`decode_attend`, the reference path.

    mode: "auto" (fused only where it is the hardware-native path, i.e.
    on TPU), "fused" (force the kernel; interpret mode off-TPU), or
    "gather".  int8-KV pools route to the scale-folding kernel variant.
    Variants no kernel covers (sliding-window, mesh-indivisible head
    counts) fall back to the gathered path in every mode.

    Inside a :func:`paged_shard_scope` the kernel launches per
    model-shard through ``shard_map``: the pool's kv-head slice stays
    local to each shard and the capability probe / ``block_h`` clamp see
    the per-shard head counts.
    """
    mesh = _PAGED_SHARD["mesh"]
    tp = _PAGED_SHARD["tp"] if mesh is not None else 1
    use = _fused_selected(mode, fused_paged_supported(cache, q.shape[2],
                                                      window=window, tp=tp))
    if use:
        from repro.core.lut_gemm import INTERPRET
        from repro.kernels.paged_attention import (paged_attention,
                                                   paged_attention_int8)
        int8 = cache["k"].dtype == jnp.int8
        if int8:
            fn = functools.partial(paged_attention_int8, scale=scale,
                                   interpret=INTERPRET)
            args = (q[:, 0], cache["k"], cache["v"], cache["k_scale"],
                    cache["v_scale"], cache["pos"], cache["block_tables"],
                    positions[:, 0])
        else:
            fn = functools.partial(paged_attention, scale=scale,
                                   interpret=INTERPRET)
            args = (q[:, 0], cache["k"], cache["v"], cache["pos"],
                    cache["block_tables"], positions[:, 0])
        if mesh is not None:
            from jax.sharding import PartitionSpec as P
            from repro.parallel.sharding import shard_map_compat
            dax = "data" if _PAGED_SHARD["shard_batch"] else None
            pool = P(None, None, "model", None)
            scl = P(None, None, "model")        # scale pools [NB, BS, Hkv]
            in_specs = (P(dax, "model", None),  # q [B, H, D]
                        pool, pool) \
                + ((scl, scl) if int8 else ()) \
                + (P(None, None),               # pos pool
                   P(dax, None),                # block tables
                   P(dax))                      # positions
            out3 = shard_map_compat(
                fn, mesh, in_specs=in_specs,
                out_specs=P(dax, "model", None))(*args)
            return out3[:, None]
        out = fn(*args)
        out = shard_act(out[:, None], ("batch", None, "heads", None))
        return out
    kv = paged_view(cache)
    return decode_attend(q, kv, positions, window=window, scale=scale)


def mla_paged_decode_attend(q_eff, q_rope, cache, positions, *, scale,
                            mode="auto"):
    """Absorbed MLA decode on a PAGED latent cache.

    q_eff: f32 [B, 1, H, lora] (``w_uk`` already absorbed); q_rope:
    [B, 1, H, rope_dim].  Returns the latent context [B, 1, H, lora] —
    the caller applies ``w_uv``.  When the fused kernel is selected the
    latent blocks are read straight from the pool (scores in latent
    space, the ``kv_map_fn`` decompression folded away by absorption)
    and ``paged_view`` is never materialized; otherwise: gather + the
    absorbed reference math.
    """
    mesh = _PAGED_SHARD["mesh"]
    tp = _PAGED_SHARD["tp"] if mesh is not None else 1
    h = q_eff.shape[2]
    use = _fused_selected(mode, fused_paged_supported(cache, h, tp=tp))
    if use:
        from repro.core.lut_gemm import INTERPRET
        from repro.kernels.paged_attention import paged_attention_mla
        fn = functools.partial(paged_attention_mla, scale=float(scale),
                               interpret=INTERPRET)
        args = (q_eff[:, 0], q_rope[:, 0].astype(jnp.float32),
                cache["ckv"], cache["krope"], cache["pos"],
                cache["block_tables"], positions[:, 0])
        if mesh is not None:
            from jax.sharding import PartitionSpec as P
            from repro.parallel.sharding import shard_map_compat
            dax = "data" if _PAGED_SHARD["shard_batch"] else None
            # latent pools have no heads dim: they ride replicated (the
            # paged pool nulls the contiguous cache's "kv_seq" sharding —
            # see model.paged_cache_axes) and the QUERY heads shard
            ctx3 = shard_map_compat(
                fn, mesh,
                in_specs=(P(dax, "model", None),   # q_eff [B, H, lora]
                          P(dax, "model", None),   # q_rope [B, H, dr]
                          P(None, None, None),     # ckv pool
                          P(None, None, None),     # krope pool
                          P(None, None),           # pos pool
                          P(dax, None),            # block tables
                          P(dax)),                 # positions
                out_specs=P(dax, "model", None))(*args)
            return ctx3[:, None]
        return fn(*args)[:, None]
    kv = paged_view(cache)
    return _mla_absorbed_ctx(q_eff, q_rope, kv["ckv"], kv["krope"],
                             kv["pos"], positions, scale)


def paged_prefill_attend(q, cache, positions, *, scale=None, mode="auto"):
    """Chunked-prefill attention on a PAGED cache (current chunk already
    inserted into the pool).

    q: [B, C, H, D]; positions: int32 [B, C] (-1 on pad rows).  When the
    fused kernel is selected, the chunk's queries attend over prior
    context straight from the block pool (scalar-prefetched block-table
    indexing, per-query causal masking across the chunk boundary, int8
    scales folded in-kernel) and ``paged_view`` is never materialized.
    Otherwise: gather + ``blockwise_attention``, the reference path.
    Pad query rows differ harmlessly between the two (kernel: zeros;
    blockwise: unnormalized garbage) — both are discarded downstream.
    """
    mesh = _PAGED_SHARD["mesh"]
    tp = _PAGED_SHARD["tp"] if mesh is not None else 1
    use = _fused_selected(mode, fused_paged_supported(
        cache, q.shape[2], tp=tp, kernel="paged_prefill"))
    if use:
        from repro.core.lut_gemm import INTERPRET
        from repro.kernels.paged_attention import paged_prefill
        int8 = cache["k"].dtype == jnp.int8
        fn = functools.partial(paged_prefill, scale=scale,
                               interpret=INTERPRET)
        args = (q, cache["k"], cache["v"], cache["pos"],
                cache["block_tables"], positions) \
            + ((cache["k_scale"], cache["v_scale"]) if int8 else ())
        if int8:
            fn = functools.partial(
                lambda q_, k_, v_, p_, t_, pos_, ks_, vs_, **kw:
                paged_prefill(q_, k_, v_, p_, t_, pos_,
                              k_scale=ks_, v_scale=vs_, **kw),
                scale=scale, interpret=INTERPRET)
        if mesh is not None:
            from jax.sharding import PartitionSpec as P
            from repro.parallel.sharding import shard_map_compat
            # prefill runs one sequence's chunk at a time (B=1), which a
            # data axis > 1 cannot split — replicate unless B divides
            dax = "data" if (_PAGED_SHARD["shard_batch"]
                             and q.shape[0] % dict(mesh.shape).get(
                                 "data", 1) == 0) else None
            pool = P(None, None, "model", None)
            scl = P(None, None, "model")
            in_specs = (P(dax, None, "model", None),  # q [B, C, H, D]
                        pool, pool,
                        P(None, None),                # pos pool
                        P(dax, None),                 # block tables
                        P(dax, None)) \
                + ((scl, scl) if int8 else ())        # scale pools
            out = shard_map_compat(
                fn, mesh, in_specs=in_specs,
                out_specs=P(dax, None, "model", None))(*args)
            return out
        return shard_act(fn(*args), ("batch", None, "heads", None))
    kv = paged_view(cache)
    if cache["k"].dtype == jnp.int8:
        kd = (kv["k"].astype(jnp.float32)
              * kv["k_scale"][..., None]).astype(q.dtype)
        vd = (kv["v"].astype(jnp.float32)
              * kv["v_scale"][..., None]).astype(q.dtype)
        return blockwise_attention(q, kd, vd, positions, kv["pos"],
                                   causal=True, scale=scale)
    return blockwise_attention(q, kv["k"], kv["v"], positions, kv["pos"],
                               causal=True, scale=scale)


def cross_kv(params, cfg, enc_out, backend=None):
    """Project encoder output to cross-attention K/V (cached at prefill)."""
    hd = cfg.head_dim_
    hkv = cfg.n_kv_heads
    k = _split_heads(linear_apply(params["k"], enc_out, params.get("k_b"),
                                  backend=backend), hkv, hd)
    v = _split_heads(linear_apply(params["v"], enc_out, params.get("v_b"),
                                  backend=backend), hkv, hd)
    return k, v


def cross_attend(params, cfg, x, k, v, backend=None):
    """Decoder cross-attention against (possibly cached) encoder K/V."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    h = cfg.n_heads
    q = _split_heads(linear_apply(params["q"], x, params.get("q_b"),
                                  backend=backend), h, hd)
    qpos = jnp.zeros((b, s), jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(k.shape[1], dtype=jnp.int32)[None],
                            (b, k.shape[1]))
    out = blockwise_attention(q, k, v, qpos, kpos, causal=False)
    return linear_apply(params["o"], out.reshape(b, s, h * hd), backend=backend)


# ---------------------------------------------------------------------------
# MLA attention block (deepseek-v2 / minicpm3)
# ---------------------------------------------------------------------------


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps) * scale
    return y.astype(x.dtype)


def _mla_absorbed_ctx(q_eff, q_rope, ckv_all, krope_all, kpos, positions,
                      scale):
    """Gathered/contiguous absorbed-decode math: latent-space scores +
    softmax + latent context.  q_eff: f32 [B, 1, H, lora]; returns
    [B, 1, H, lora] f32 (the caller applies ``w_uv``).  The mask relies
    on ``kpos`` being -1 on every non-live slot (``paged_view`` sets
    this for paged caches; contiguous caches store -1 on empty slots).
    """
    sc = jnp.einsum("bshl,bkl->bshk", q_eff, ckv_all.astype(jnp.float32))
    sc = sc + jnp.einsum("bshr,bkr->bshk", q_rope.astype(jnp.float32),
                         krope_all.astype(jnp.float32))
    sc = sc * scale
    # mask: slot occupied and slot position <= current decode position
    m = (kpos >= 0)[:, None, None, :] & \
        (kpos[:, None, None, :] <= positions[:, 0][:, None, None, None])
    sc = jnp.where(m, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bshk,bkl->bshl", p, ckv_all.astype(jnp.float32))


def mla_apply(params, cfg, x, positions, *, cache=None, cache_at=None,
              backend=None):
    """Multi-head latent attention with compressed KV cache.

    Prefill/train: decompress latent KV inside the blockwise scan.
    Decode (S==1): absorbed formulation — scores/values in latent space.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    scale = (dn + dr) ** -0.5

    # --- queries -----------------------------------------------------
    if cfg.q_lora_rank:
        qa = linear_apply(params["q_a"], x, backend=backend)
        qa = _rms(qa, params["q_a_norm"])
        q = linear_apply(params["q_b"], qa, backend=backend)
    else:
        q = linear_apply(params["q"], x, backend=backend)
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # --- compressed KV ------------------------------------------------
    kv_a = linear_apply(params["kv_a"], x, backend=backend)   # [B,S,lora+dr]
    ckv = _rms(kv_a[..., :lora], params["kv_a_norm"])
    krope = apply_rope(kv_a[..., lora:][:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]            # shared head

    w_kvb = params["kv_b"]
    # split decompression weight into W_uk [H, dn, lora], W_uv [H, dv, lora]
    from repro.core.bcq import BCQWeight, dequantize
    w_dense = dequantize(w_kvb, jnp.float32) if isinstance(w_kvb, BCQWeight) \
        else w_kvb.astype(jnp.float32)
    w_kvb3 = w_dense.reshape(h, dn + dv, lora)
    w_uk, w_uv = w_kvb3[:, :dn, :], w_kvb3[:, dn:, :]

    if cache is not None:
        cache = cache_insert(cache, {"ckv": ckv, "krope": krope}, cache_at)

    if s == 1 and cache is not None:
        # ---- absorbed decode: O(L * lora) per step -------------------
        q_eff = jnp.einsum("bshn,hnl->bshl", q_nope.astype(jnp.float32), w_uk)
        if is_paged(cache):
            # the router reads latent blocks straight from the pool when
            # the fused MLA kernel is selected (no gathered view)
            ctx = mla_paged_decode_attend(q_eff, q_rope, cache, positions,
                                          scale=scale,
                                          mode=cfg.paged_kernel)
        else:
            ctx = _mla_absorbed_ctx(q_eff, q_rope, cache["ckv"],
                                    cache["krope"], cache["pos"],
                                    positions, scale)
        out = jnp.einsum("bshl,hvl->bshv", ctx, w_uv)          # [B,1,H,dv]
    else:
        if cache is not None:
            # MLA prefill stays on the gathered view: the latent blocks
            # must be decompressed through kv_map_fn (W_uk/W_uv per
            # block), which the fused prefill kernel does not fold
            kv = paged_view(cache) if is_paged(cache) else cache
            ckv_all, krope_all, kpos = kv["ckv"], kv["krope"], kv["pos"]
        else:
            ckv_all, krope_all, kpos = ckv, krope, positions
        # ---- prefill/train: decompress per KV block ------------------
        def kv_map(latent_blk, _):
            c, kr = latent_blk[..., :lora], latent_blk[..., lora:]
            k_nope = jnp.einsum("bkl,hnl->bkhn", c.astype(jnp.float32), w_uk)
            v_b = jnp.einsum("bkl,hvl->bkhv", c.astype(jnp.float32), w_uv)
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kr[:, :, None, :].astype(jnp.float32),
                                          (*k_nope.shape[:2], h, dr))], axis=-1)
            # keep f32: transient inside the KV-block scan; matches the
            # absorbed decode path's precision
            return k_full, v_b

        latent = jnp.concatenate([ckv_all, krope_all], axis=-1)
        # MLA stays f32-operand: the absorbed decode path reassociates the
        # score computation, so both paths run f32 to stay numerically
        # interchangeable (the latent cache is ~8x smaller than a GQA
        # cache, so the bf16-operand byte saving matters much less here).
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1).astype(jnp.float32)
        out = blockwise_attention(q_full, latent, None, positions, kpos,
                                  causal=True, scale=scale, kv_map_fn=kv_map)

    out = out.reshape(b, s, h * dv).astype(x.dtype)
    out = linear_apply(params["o"], out, backend=backend)
    return (out, cache) if cache is not None else out
