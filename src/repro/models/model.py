"""Top-level Model: embeddings -> stack -> head, plus the three entry
points the launchers lower (``loss_fn`` for train_step, ``prefill`` and
``decode_step`` for serve_step).

Frontend stubs per the assignment:
  * VLM (pixtral): ``patch_embeds`` [B, P, d] are prepended to the text
    embeddings (positions continue through the patch region).
  * audio (whisper): ``frames`` [B, Senc, d] are the encoder input; the
    conv/mel stack is out of scope.

Caches are descriptor trees mirroring the layer layout, so the dry-run
can abstract them (``abstract_cache``) without allocating 32k x 128-batch
KV.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.layers import (embed_desc, embed_apply, norm_desc,
                                 norm_apply, unembed_apply)
from repro.models.module import (ParamDesc, abstract_params, init_params,
                                 is_desc, logical_axes, param_count,
                                 tree_map_with_path)


class Model:
    def __init__(self, cfg):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def desc(self):
        cfg = self.cfg
        d = {"embed": embed_desc(cfg),
             "stack": tfm.stack_desc_tree(cfg, cross=cfg.is_encdec),
             "final_norm": norm_desc(cfg)}
        if cfg.is_encdec:
            enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers, n_experts=0,
                                  attn_layer_period=0)
            d["encoder"] = {
                "stack": tfm.stack_desc_tree(enc_cfg, cross=False),
                "final_norm": norm_desc(cfg),
            }
            if cfg.pos == "learned":
                d["encoder"]["pos"] = ParamDesc(
                    (cfg.encoder_seq, cfg.d_model), jnp.bfloat16,
                    (None, "embed"), "embed")
        return d

    def init(self, rng):
        return init_params(rng, self.desc())

    def abstract(self):
        return abstract_params(self.desc())

    def axes(self):
        return logical_axes(self.desc())

    def n_params(self) -> int:
        return param_count(self.desc())

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def _layer_cache_desc(self, i: int, batch: int, length: int):
        cfg = self.cfg
        kind = cfg.layer_kind(i)
        c = {}
        if kind == "attn":
            if cfg.attention == "mla":
                c["self"] = attn.cache_desc_mla(cfg, batch, length)
            else:
                c["self"] = attn.cache_desc_gqa(cfg, batch, length)
        else:
            c["ssm"] = ssm_mod.ssm_cache_desc(cfg, batch)
        if cfg.is_encdec:
            hd = cfg.head_dim_
            c["cross_k"] = ParamDesc((batch, cfg.encoder_seq, cfg.n_kv_heads, hd),
                                     jnp.bfloat16,
                                     ("batch", None, "kv_heads", "head_dim"),
                                     "zeros")
            c["cross_v"] = ParamDesc((batch, cfg.encoder_seq, cfg.n_kv_heads, hd),
                                     jnp.bfloat16,
                                     ("batch", None, "kv_heads", "head_dim"),
                                     "zeros")
        return c

    def cache_desc(self, batch: int, length: int):
        stack = tfm.stack_desc_tree(self.cfg, cross=self.cfg.is_encdec)
        return tfm.map_stack(stack,
                             lambda i: self._layer_cache_desc(i, batch, length),
                             self.cfg)

    def init_cache(self, batch: int, length: int):
        cache = init_params(jax.random.PRNGKey(0), self.cache_desc(batch, length))
        return self._blank_pos(cache)

    def abstract_cache(self, batch: int, length: int):
        return abstract_params(self.cache_desc(batch, length))

    def paged_cache_desc(self, batch: int, num_blocks: int, block_size: int,
                         max_blocks_per_seq: int):
        """Paged KV cache: per-layer block pools shared across sequences
        plus a [batch, max_blocks_per_seq] block table per layer (all
        layers carry the same table values; see serve.paging).

        Only attention-only decoders page: SSM states are O(1) per
        sequence (nothing to page) and encoder-decoder cross-KV is a
        fixed per-row reservation.
        """
        cfg = self.cfg
        if cfg.is_encdec or any(cfg.layer_kind(i) != "attn"
                                for i in range(cfg.n_layers)):
            raise ValueError("paged cache supports attention-only decoders")
        stack = tfm.stack_desc_tree(cfg, cross=False)
        return tfm.map_stack(
            stack,
            lambda i: {"self": attn.paged_cache_desc(
                cfg, batch, num_blocks, block_size, max_blocks_per_seq)},
            cfg)

    def init_paged_cache(self, batch: int, num_blocks: int, block_size: int,
                         max_blocks_per_seq: int):
        cache = init_params(jax.random.PRNGKey(0), self.paged_cache_desc(
            batch, num_blocks, block_size, max_blocks_per_seq))
        return self._blank_pos(cache)

    def paged_cache_axes(self, batch: int, num_blocks: int, block_size: int,
                         max_blocks_per_seq: int):
        """Logical-axes tree for SHARDING a paged cache.

        The pool descriptors are the contiguous ones with batch ->
        num_blocks, so their leading axis is labelled "batch" — but the
        pool dim must never shard over the data axis (every sequence's
        block table can point anywhere in the pool), and neither may the
        within-block sequence dim that MLA labels "kv_seq" (a block is
        the DMA unit of the fused kernel).  Head axes survive, so
        ``build_shardings`` puts the pool's kv_heads (or, via its
        divisibility fallback, head_dim) on the model axis exactly like
        the contiguous cache.  Block tables are replicated host state.
        """
        def fix(path, d):
            if not is_desc(d):
                return d
            axes = d.axes or (None,) * len(d.shape)
            if path and path[-1] == "block_tables":
                axes = (None,) * len(d.shape)
            else:
                axes = tuple(None if a in ("batch", "kv_seq") else a
                             for a in axes)
            return dataclasses.replace(d, axes=axes)
        desc = tree_map_with_path(fix, self.paged_cache_desc(
            batch, num_blocks, block_size, max_blocks_per_seq))
        return logical_axes(desc)

    @staticmethod
    def _blank_pos(cache):
        """Set every 'pos' / 'block_tables' buffer to -1 (empty)."""
        def fix(path, leaf):
            if path and path[-1] in ("pos", "block_tables"):
                return jnp.full_like(leaf, -1)
            return leaf
        return tree_map_with_path(fix, cache)

    # ------------------------------------------------------------------
    # forward paths
    # ------------------------------------------------------------------
    def _embed(self, params, batch: dict, start_pos=0):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        start = jnp.asarray(start_pos, jnp.int32)
        if start.ndim == 0:
            start = jnp.broadcast_to(start, (b,))

        def pos_for(length):
            return start[:, None] + jnp.arange(length, dtype=jnp.int32)[None]

        positions = pos_for(s)
        if "patch_embeds" in batch:                      # VLM stub frontend
            p = batch["patch_embeds"].shape[1]
            x_txt = embed_apply(params["embed"], tokens)
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(x_txt.dtype), x_txt], axis=1)
            s = x.shape[1]
            positions = pos_for(s)
            if cfg.pos == "learned":
                x = x + jnp.take(params["embed"]["pos"],
                                 jnp.maximum(positions, 0), axis=0)
            return x, positions
        # per-row positions (ragged serving batches); negative positions
        # mark masked left-pads — clamp the table lookup, the attention
        # pos-mask hides the garbage row
        x = embed_apply(params["embed"], tokens,
                        jnp.maximum(positions, 0)
                        if cfg.pos == "learned" else None)
        return x, positions

    def encode(self, params, frames):
        """Whisper encoder over precomputed frame embeddings (stub)."""
        cfg = self.cfg
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers, n_experts=0,
                              attn_layer_period=0)
        b, s, _ = frames.shape
        x = frames
        if "pos" in params["encoder"]:
            x = x + params["encoder"]["pos"][None, :s].astype(x.dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x, _ = tfm.stack_apply(params["encoder"]["stack"], enc_cfg, x,
                               positions, causal=False,
                               backend=cfg.backend_preference)
        return norm_apply(params["encoder"]["final_norm"], x)

    def _logits_padded(self, params, batch: dict):
        """[B, S, padded_vocab] — internal; keeps the vocab dim sharded."""
        cfg = self.cfg
        enc_out = None
        if cfg.is_encdec:
            enc_out = self.encode(params, batch["frames"])
        x, positions = self._embed(params, batch)
        x, _ = tfm.stack_apply(params["stack"], cfg, x, positions,
                               enc_out=enc_out, backend=cfg.backend_preference)
        x = norm_apply(params["final_norm"], x)
        return unembed_apply(params["embed"], x, backend=cfg.backend_preference)

    def forward(self, params, batch: dict):
        """Full-sequence logits (training / eval). Returns [B, S, V]."""
        return self._logits_padded(params, batch)[..., : self.cfg.vocab_size]

    def loss_fn(self, params, batch: dict):
        """Next-token cross-entropy, sharded-vocab-safe.

        NEVER gathers the full logits across the model axis: the target
        logit is extracted with an iota==target mask (stays sharded; the
        vocab reduction becomes a partial-sum + all-reduce of [B, S]
        scalars instead of an all-gather of [B, S, V] floats — the
        difference between ~26 GB and ~128 KB of cross-device traffic for
        a 100k vocab at train_4k scale).
        """
        logits = self._logits_padded(params, batch)   # [B, S, Vpad] f32
        tokens = batch["tokens"]
        if "patch_embeds" in batch:                   # loss only on text part
            p = batch["patch_embeds"].shape[1]
            logits = logits[:, p:]
        targets = tokens[:, 1:].astype(jnp.int32)
        logits = logits[:, :-1].astype(jnp.float32)
        iota_v = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        # mask vocab padding out of the partition function
        logits = jnp.where(iota_v < self.cfg.vocab_size, logits, -1e30)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)        # [B, S-1]
        onehot = iota_v == targets[..., None]
        ltgt = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)   # [B, S-1]
        return (lse - ltgt).mean()

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def prefill(self, params, batch: dict, cache, start_pos=0):
        """Run the prompt through the stack, filling the cache.

        ``start_pos`` (scalar or [B]) is the absolute position of the
        first token; a *negative* start marks left-pads — they get
        positions < 0, which the attention pos-mask hides and the cache
        insert treats as dead writes, so padded prompts score exactly
        like unpadded ones.  Returns (last-token logits [B, V], cache).
        """
        cfg = self.cfg
        enc_out = None
        if cfg.is_encdec:
            enc_out = self.encode(params, batch["frames"])
        x, positions = self._embed(params, batch, start_pos)
        x, cache = tfm.stack_apply(params["stack"], cfg, x, positions,
                                   caches=cache,
                                   cache_at=positions[:, 0],
                                   enc_out=enc_out, backend=cfg.backend_preference)
        x = norm_apply(params["final_norm"], x[:, -1:])
        logits = unembed_apply(params["embed"], x,
                               backend=cfg.backend_preference)[:, 0, : cfg.vocab_size]
        return logits, cache

    def prefill_chunk(self, params, batch: dict, cache, start_pos, last_idx):
        """One chunk of a chunked prefill: tokens [B, C] at absolute
        positions ``start_pos + [0, C)``, writing straight into the
        (typically paged) cache and attending over everything cached so
        far.  ``last_idx`` [B] selects the row's last *real* token
        (chunks are right-padded to a length bucket; padded positions
        are dead writes).  Returns (logits at last_idx [B, V], cache).
        """
        cfg = self.cfg
        x, positions = self._embed(params, batch, start_pos)
        x, cache = tfm.stack_apply(params["stack"], cfg, x, positions,
                                   caches=cache, cache_at=positions[:, 0],
                                   backend=cfg.backend_preference)
        b = x.shape[0]
        idx = jnp.asarray(last_idx, jnp.int32)
        if idx.ndim == 0:
            idx = jnp.broadcast_to(idx, (b,))
        x = x[jnp.arange(b), idx][:, None]               # [B, 1, d]
        x = norm_apply(params["final_norm"], x)
        logits = unembed_apply(params["embed"], x,
                               backend=cfg.backend_preference)[:, 0, : cfg.vocab_size]
        return logits, cache

    def decode_and_sample(self, params, tokens, cache, pos, keys,
                          temperature, top_k):
        """Fused decode + on-device sampling: one decode step followed by
        :func:`sample_tokens`, so only the sampled token ids (int32 [B])
        ever cross the host boundary — the async serving engine jits this
        instead of ``decode_step`` and defers the host sync by a full
        tick.  ``keys`` are per-row uint32 [B, 2] PRNG keys; rows with
        ``temperature <= 0`` ignore their key (greedy argmax).  Returns
        (token ids int32 [B], cache).
        """
        logits, cache = self.decode_step(params, tokens, cache, pos)
        return sample_tokens(logits, keys, temperature, top_k), cache

    def decode_step(self, params, tokens, cache, pos):
        """One decode step. tokens: [B, 1]; pos: scalar or [B] absolute
        position of the new token. Returns (logits [B, V], cache)."""
        cfg = self.cfg
        b = tokens.shape[0]
        pos_arr = jnp.asarray(pos, jnp.int32)
        if pos_arr.ndim == 0:
            pos_arr = jnp.broadcast_to(pos_arr, (b,))
        positions = pos_arr[:, None]
        x = embed_apply(params["embed"], tokens,
                        jnp.maximum(positions, 0)
                        if cfg.pos == "learned" else None)
        x, cache = tfm.stack_apply(params["stack"], cfg, x, positions,
                                   caches=cache, cache_at=pos_arr,
                                   backend=cfg.backend_preference)
        x = norm_apply(params["final_norm"], x)
        logits = unembed_apply(params["embed"], x,
                               backend=cfg.backend_preference)[:, 0, : cfg.vocab_size]
        return logits, cache


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


def sample_tokens(logits, keys, temperature, top_k):
    """Batched token sampling as a pure function of ``(logits, key)``.

    Per row: ``temperature <= 0`` is greedy argmax (ties break to the
    lowest index, matching ``np.argmax``); otherwise logits outside the
    ``top_k`` largest (``top_k <= 0`` means no truncation) are masked to
    ``-inf``, the rest are divided by the temperature and sampled via
    ``jax.random.categorical`` under a per-row key.  Because the result
    depends only on the row's logits and key — never on batch position
    or previous draws — the synchronous host-side sampler and the async
    fused :meth:`Model.decode_and_sample` path produce bit-identical
    tokens for the same request state, which is what the engine's
    sync==async equivalence tests assert.

    logits: [B, V] float; keys: uint32 [B, 2] (raw key data, one per
    row); temperature: float [B]; top_k: int32 [B].  Returns int32 [B].
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k = jnp.asarray(top_k, jnp.int32)
    kk = jnp.clip(jnp.where(k <= 0, v, k), 1, v)
    # top-k threshold: the k-th largest logit per row; everything below
    # it leaves the candidate set (ties AT the threshold all stay in,
    # which keeps the mask a pure function of the logit values)
    order = jnp.sort(logits, axis=-1)[:, ::-1]
    thresh = jnp.take_along_axis(order, (kk - 1)[:, None], axis=-1)
    masked = jnp.where(logits < thresh, -jnp.inf, logits)
    temp = jnp.asarray(temperature, jnp.float32)
    scaled = masked / jnp.maximum(temp, 1e-6)[:, None]
    keys = jnp.asarray(keys, jnp.uint32)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temp <= 0.0, greedy, sampled)
