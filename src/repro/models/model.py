"""Top-level Model: embeddings -> stack -> head, plus the three entry
points the launchers lower (``loss_fn`` for train_step, ``prefill`` and
``decode_step`` for serve_step).

Frontend stubs per the assignment:
  * VLM (pixtral): ``patch_embeds`` [B, P, d] are prepended to the text
    embeddings (positions continue through the patch region).
  * audio (whisper): ``frames`` [B, Senc, d] are the encoder input; the
    conv/mel stack is out of scope.

Caches are descriptor trees mirroring the layer layout, so the dry-run
can abstract them (``abstract_cache``) without allocating 32k x 128-batch
KV.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.layers import (embed_desc, embed_apply, norm_desc,
                                 norm_apply, unembed_apply)
from repro.models.module import (ParamDesc, abstract_params, init_params,
                                 logical_axes, param_count)


class Model:
    def __init__(self, cfg):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def desc(self):
        cfg = self.cfg
        d = {"embed": embed_desc(cfg),
             "stack": tfm.stack_desc_tree(cfg, cross=cfg.is_encdec),
             "final_norm": norm_desc(cfg)}
        if cfg.is_encdec:
            enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers, n_experts=0,
                                  attn_layer_period=0)
            d["encoder"] = {
                "stack": tfm.stack_desc_tree(enc_cfg, cross=False),
                "final_norm": norm_desc(cfg),
            }
            if cfg.pos == "learned":
                d["encoder"]["pos"] = ParamDesc(
                    (cfg.encoder_seq, cfg.d_model), jnp.bfloat16,
                    (None, "embed"), "embed")
        return d

    def init(self, rng):
        return init_params(rng, self.desc())

    def abstract(self):
        return abstract_params(self.desc())

    def axes(self):
        return logical_axes(self.desc())

    def n_params(self) -> int:
        return param_count(self.desc())

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def _layer_cache_desc(self, i: int, batch: int, length: int):
        cfg = self.cfg
        kind = cfg.layer_kind(i)
        c = {}
        if kind == "attn":
            if cfg.attention == "mla":
                c["self"] = attn.cache_desc_mla(cfg, batch, length)
            else:
                c["self"] = attn.cache_desc_gqa(cfg, batch, length)
        else:
            c["ssm"] = ssm_mod.ssm_cache_desc(cfg, batch)
        if cfg.is_encdec:
            hd = cfg.head_dim_
            c["cross_k"] = ParamDesc((batch, cfg.encoder_seq, cfg.n_kv_heads, hd),
                                     jnp.bfloat16,
                                     ("batch", None, "kv_heads", "head_dim"),
                                     "zeros")
            c["cross_v"] = ParamDesc((batch, cfg.encoder_seq, cfg.n_kv_heads, hd),
                                     jnp.bfloat16,
                                     ("batch", None, "kv_heads", "head_dim"),
                                     "zeros")
        return c

    def cache_desc(self, batch: int, length: int):
        stack = tfm.stack_desc_tree(self.cfg, cross=self.cfg.is_encdec)
        return tfm.map_stack(stack,
                             lambda i: self._layer_cache_desc(i, batch, length),
                             self.cfg)

    def init_cache(self, batch: int, length: int):
        cache = init_params(jax.random.PRNGKey(0), self.cache_desc(batch, length))
        return self._blank_pos(cache)

    def abstract_cache(self, batch: int, length: int):
        return abstract_params(self.cache_desc(batch, length))

    @staticmethod
    def _blank_pos(cache):
        """Set every 'pos' buffer to -1 (empty slots)."""
        def fix(path, leaf):
            if path and path[-1] == "pos":
                return jnp.full_like(leaf, -1)
            return leaf
        return _tree_map_with_path(fix, cache)

    # ------------------------------------------------------------------
    # forward paths
    # ------------------------------------------------------------------
    def _embed(self, params, batch: dict, start_pos=0):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = start_pos + jnp.arange(s, dtype=jnp.int32)[None]
        positions = jnp.broadcast_to(positions, (b, s))
        if "patch_embeds" in batch:                      # VLM stub frontend
            p = batch["patch_embeds"].shape[1]
            x_txt = embed_apply(params["embed"], tokens)
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(x_txt.dtype), x_txt], axis=1)
            s = x.shape[1]
            positions = start_pos + jnp.arange(s, dtype=jnp.int32)[None]
            positions = jnp.broadcast_to(positions, (b, s))
            if cfg.pos == "learned":
                x = x + jnp.take(params["embed"]["pos"], positions[0], axis=0)
            return x, positions
        x = embed_apply(params["embed"], tokens,
                        positions[0] if cfg.pos == "learned" else None)
        return x, positions

    def encode(self, params, frames):
        """Whisper encoder over precomputed frame embeddings (stub)."""
        cfg = self.cfg
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers, n_experts=0,
                              attn_layer_period=0)
        b, s, _ = frames.shape
        x = frames
        if "pos" in params["encoder"]:
            x = x + params["encoder"]["pos"][None, :s].astype(x.dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x, _ = tfm.stack_apply(params["encoder"]["stack"], enc_cfg, x,
                               positions, causal=False,
                               backend=cfg.gemm_backend)
        return norm_apply(params["encoder"]["final_norm"], x)

    def _logits_padded(self, params, batch: dict):
        """[B, S, padded_vocab] — internal; keeps the vocab dim sharded."""
        cfg = self.cfg
        enc_out = None
        if cfg.is_encdec:
            enc_out = self.encode(params, batch["frames"])
        x, positions = self._embed(params, batch)
        x, _ = tfm.stack_apply(params["stack"], cfg, x, positions,
                               enc_out=enc_out, backend=cfg.gemm_backend)
        x = norm_apply(params["final_norm"], x)
        return unembed_apply(params["embed"], x, backend=cfg.gemm_backend)

    def forward(self, params, batch: dict):
        """Full-sequence logits (training / eval). Returns [B, S, V]."""
        return self._logits_padded(params, batch)[..., : self.cfg.vocab_size]

    def loss_fn(self, params, batch: dict):
        """Next-token cross-entropy, sharded-vocab-safe.

        NEVER gathers the full logits across the model axis: the target
        logit is extracted with an iota==target mask (stays sharded; the
        vocab reduction becomes a partial-sum + all-reduce of [B, S]
        scalars instead of an all-gather of [B, S, V] floats — the
        difference between ~26 GB and ~128 KB of cross-device traffic for
        a 100k vocab at train_4k scale).
        """
        logits = self._logits_padded(params, batch)   # [B, S, Vpad] f32
        tokens = batch["tokens"]
        if "patch_embeds" in batch:                   # loss only on text part
            p = batch["patch_embeds"].shape[1]
            logits = logits[:, p:]
        targets = tokens[:, 1:].astype(jnp.int32)
        logits = logits[:, :-1].astype(jnp.float32)
        iota_v = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        # mask vocab padding out of the partition function
        logits = jnp.where(iota_v < self.cfg.vocab_size, logits, -1e30)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)        # [B, S-1]
        onehot = iota_v == targets[..., None]
        ltgt = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)   # [B, S-1]
        return (lse - ltgt).mean()

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def prefill(self, params, batch: dict, cache):
        """Run the prompt through the stack, filling the cache.

        Returns (last-token logits [B, V], cache).
        """
        cfg = self.cfg
        enc_out = None
        if cfg.is_encdec:
            enc_out = self.encode(params, batch["frames"])
        x, positions = self._embed(params, batch)
        x, cache = tfm.stack_apply(params["stack"], cfg, x, positions,
                                   caches=cache, cache_at=jnp.int32(0),
                                   enc_out=enc_out, backend=cfg.gemm_backend)
        x = norm_apply(params["final_norm"], x[:, -1:])
        logits = unembed_apply(params["embed"], x,
                               backend=cfg.gemm_backend)[:, 0, : cfg.vocab_size]
        return logits, cache

    def decode_step(self, params, tokens, cache, pos):
        """One decode step. tokens: [B, 1]; pos: scalar or [B] absolute
        position of the new token. Returns (logits [B, V], cache)."""
        cfg = self.cfg
        b = tokens.shape[0]
        pos_arr = jnp.asarray(pos, jnp.int32)
        if pos_arr.ndim == 0:
            pos_arr = jnp.broadcast_to(pos_arr, (b,))
        positions = pos_arr[:, None]
        x = embed_apply(params["embed"], tokens,
                        positions[0] if cfg.pos == "learned" else None)
        x, cache = tfm.stack_apply(params["stack"], cfg, x, positions,
                                   caches=cache, cache_at=pos_arr,
                                   backend=cfg.gemm_backend)
        x = norm_apply(params["final_norm"], x)
        logits = unembed_apply(params["embed"], x,
                               backend=cfg.gemm_backend)[:, 0, : cfg.vocab_size]
        return logits, cache


def _tree_map_with_path(fn, tree, path=()):
    if isinstance(tree, dict):
        return {k: _tree_map_with_path(fn, v, path + (k,))
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        t = [_tree_map_with_path(fn, v, path + (i,))
             for i, v in enumerate(tree)]
        return type(tree)(t)
    return fn(path, tree)
