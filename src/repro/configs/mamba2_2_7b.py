"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality).

64L d_model=2560 d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]

Mamba2 block: expand=2 (d_inner 5120), head_dim 64 (80 heads), conv 4.
No separate MLP (d_ff=0): the block IS the layer.  Decode state is O(1)
in sequence length -> long_500k is the natural shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    norm="rmsnorm",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, vocab_size=256, ssm_state=16, ssm_head_dim=16,
        ssm_chunk=16, scan_layers=False, max_seq_len=128,
    )
