"""opt-6.7b — the paper's own evaluation architecture (§IV, Table IV/V).

32L d_model=4096 32H MHA d_ff=16384 vocab=50272, learned positions,
LayerNorm, GELU  [arXiv:2205.01068]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="opt-6.7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=16384,
    vocab_size=50272,
    attention="gqa",
    pos="learned",
    mlp_act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    max_seq_len=2048,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, scan_layers=False, max_seq_len=128,
    )
