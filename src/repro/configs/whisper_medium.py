"""whisper-medium [audio] — encoder-decoder transformer backbone.

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865 — enc-dec, conv
frontend (stub)  [arXiv:2212.04356; unverified]

The conv/mel frontend is a STUB per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, 1500, d_model) to the encoder.
Being an encoder-DECODER, decode shapes run (serve_step over the decoder
with cross-attention); long_500k is skipped (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,                 # decoder layers
    n_encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    attention="gqa",
    pos="learned",
    mlp_act="gelu",
    norm="layernorm",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, n_encoder_layers=2, encoder_seq=16, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        scan_layers=False, max_seq_len=128,
    )
