"""mixtral-8x7b [moe] — 8-expert top-2 MoE with sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2, SWA
[arXiv:2401.04088; hf]

SWA window 4096 makes decode sub-quadratic -> long_500k runs with a
ring-buffer KV cache.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    attention="gqa",
    sliding_window=4096,
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=14336,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, n_experts=4, experts_per_token=2,
        moe_d_ff=128, sliding_window=32, scan_layers=False, max_seq_len=128,
    )
