"""qwen1.5-32b [dense] — GQA decoder with QKV bias.

64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064
[hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    attention="gqa",
    qkv_bias=True,
    mlp_act="swiglu",
    norm="rmsnorm",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, scan_layers=False, max_seq_len=128,
    )
