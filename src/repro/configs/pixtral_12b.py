"""pixtral-12b [vlm] — Pixtral-ViT frontend (stub) + Mistral-Nemo backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified]

The vision frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed patch embeddings (B, P, d_model) which the model prepends to
the text embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    attention="gqa",
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    num_patches=1024,           # stub: 32x32 patch grid of embeddings
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, num_patches=8, scan_layers=False,
        max_seq_len=128,
    )
