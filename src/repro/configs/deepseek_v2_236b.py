"""deepseek-v2-236b [moe] — MLA attention + 160-expert top-6 MoE.

60L d_model=5120 128H d_ff=1536(expert) vocab=102400, MoE 160e top-6,
MLA kv_lora=512, 2 shared + 160 routed  [arXiv:2405.04434; hf]

MLA dims from the paper: q_lora 1536, kv_lora 512, qk_nope 128,
qk_rope 64, v_head 128.  Layer 0 is dense (d_ff 12288).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,             # MLA: full heads after latent decompression
    d_ff=12288,                 # dense layers (layer 0)
    vocab_size=102400,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    mlp_act="swiglu",
    norm="rmsnorm",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
        qk_rope_head_dim=8, v_head_dim=16, n_experts=8, n_shared_experts=1,
        experts_per_token=2, moe_d_ff=32, first_dense_layers=1,
        scan_layers=False, max_seq_len=128,
    )
