"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7 interleave + MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf]

Layer pattern: attention at i % 8 == 4 (9 attention layers, 63 mamba);
MoE replaces the MLP on every 2nd layer.  Mamba layers use the SSD
formulation (DESIGN.md §2 notes this adaptation of Jamba's Mamba-1
layers to the TPU-friendly chunked SSD compute).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attention="gqa",
    attn_layer_period=8,
    attn_layer_offset=4,
    n_experts=16,
    experts_per_token=2,
    moe_d_ff=24576,
    moe_layer_period=2,
    ssm_state=64,
    ssm_head_dim=128,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    mlp_act="swiglu",
    norm="rmsnorm",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, n_experts=4, experts_per_token=2,
        moe_d_ff=128, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
        attn_layer_period=4, attn_layer_offset=2, scan_layers=False,
        max_seq_len=128,
    )
