"""Model / run configuration system.

One :class:`ModelConfig` dataclass describes every architecture in the zoo
(dense GQA, MLA, MoE, SSM, hybrid, enc-dec, stub-fronted VLM/audio).  Each
``src/repro/configs/<arch>.py`` exports ``CONFIG`` with the exact assigned
hyperparameters plus ``reduced()`` for CPU smoke tests.  ``registry()``
resolves ``--arch <id>`` strings.

Shape cells (assigned): train_4k / prefill_32k / decode_32k / long_500k —
see ``SHAPES`` and ``ModelConfig.input_specs``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.spec import QuantSpec

# ---------------------------------------------------------------------------
# assigned shape cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str             # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # attention
    attention: str = "gqa"            # gqa | mla | none
    sliding_window: int = 0           # >0 -> SWA (sub-quadratic full-attn)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    pos: str = "rope"                 # rope | learned | none

    # MLA (deepseek-v2 / minicpm3)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                 # expert hidden dim (d_ff if 0)
    moe_layer_period: int = 1         # MoE every k-th layer
    first_dense_layers: int = 0       # leading dense layers (deepseek)
    capacity_factor: float = 1.25

    # SSM (mamba2 / jamba mamba layers)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_layer_period: int = 0        # hybrid: 1 attn layer every k (jamba 8)
    attn_layer_offset: int = 4

    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 0              # precomputed frame count (stub frontend)

    # vlm stub
    num_patches: int = 0              # precomputed patch embeds prepended

    # misc
    mlp_act: str = "swiglu"           # swiglu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    max_seq_len: int = 524288

    # execution
    quant: Optional[QuantSpec] = None  # declarative quantization spec: the
                                      # single source of truth for format /
                                      # bits / group / backend preference
                                      # (repro.quant); None -> unquantized
    remat: bool = True
    scan_layers: bool = True
    kv_replication: int = 1           # replicate kv heads r-fold so the KV
                                      # cache shards over TP > n_kv_heads
                                      # (vLLM practice: 2x memory beats the
                                      # per-layer cache all-gather)
    kv_cache_bits: int = 16           # 8 -> int8 KV cache (per-slot-per-head
                                      # symmetric scales): halves the cache
                                      # bytes that dominate long-context
                                      # decode (beyond-paper extension of
                                      # the weight-quantization insight)
    paged_kernel: str = "auto"        # paged attention path (decode AND
                                      # chunked prefill, resolved per
                                      # variant): auto (fused Pallas
                                      # kernels where hardware-native,
                                      # else gathered view) | fused
                                      # (force; interpret off-TPU) |
                                      # gather

    # ---------------------------------------------------------------
    @property
    def backend_preference(self) -> str:
        """Execution-backend preference fed to the registry
        (:mod:`repro.quant.backends`): the ``quant`` spec's choice;
        "auto" lets capability negotiation pick per weight.  Unquantized
        models run dense linears, where the preference is inert."""
        if self.quant is not None:
            return self.quant.backend
        return "dense"

    def quant_spec(self) -> Optional[QuantSpec]:
        """The declarative QuantSpec (None when the model is
        unquantized).  The ``gemm_backend``/``quant_bits`` shims that
        used to synthesize a spec here were removed after their
        one-release deprecation window — set ``quant=QuantSpec(...)``."""
        return self.quant

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the vocab dim always shards over the
        model axis (un-shardable logits cost ~75 GiB/device at train_4k —
        standard MaxText-style embedding padding)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_hybrid(self) -> bool:
        return self.attn_layer_period > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.attention == "none" and self.ssm_state > 0

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    def layer_kind(self, i: int) -> str:
        """'attn' or 'mamba' for decoder layer i."""
        if self.is_ssm_only:
            return "mamba"
        if self.is_hybrid:
            return ("attn" if i % self.attn_layer_period == self.attn_layer_offset
                    else "mamba")
        return "attn"

    def mlp_kind(self, i: int) -> str:
        """'dense' or 'moe' for decoder layer i."""
        if self.n_experts and i >= self.first_dense_layers \
                and i % self.moe_layer_period == (self.moe_layer_period - 1 if self.moe_layer_period > 1 else 0):
            return "moe"
        return "dense"

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM, hybrid, or sliding-window attention."""
        return self.is_ssm_only or self.is_hybrid or self.sliding_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------------------
    def input_specs(self, shape: ShapeCfg, *, per_device: bool = False,
                    data_shards: int = 1) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell.

        ``train``  -> token batch (B, S) (+frontend stubs)
        ``prefill``-> token batch (B, S)
        ``decode`` -> (B, 1) new tokens; the KV/SSM cache is supplied
                      separately (see models.model.abstract_cache).
        """
        b = shape.global_batch // (data_shards if per_device else 1)
        s = shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(self.dtype)
        if shape.kind == "decode":
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
            return specs
        if self.is_encdec:
            enc = self.encoder_seq or 1500
            return {
                "frames": jax.ShapeDtypeStruct((b, enc, self.d_model), dt),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
            }
        if self.num_patches:
            p = min(self.num_patches, s // 2)
            return {
                "tokens": jax.ShapeDtypeStruct((b, s - p), i32),
                "patch_embeds": jax.ShapeDtypeStruct((b, p, self.d_model), dt),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "pixtral_12b", "deepseek_v2_236b", "mixtral_8x7b", "phi4_mini_3_8b",
    "stablelm_1_6b", "qwen1_5_32b", "minicpm3_4b", "mamba2_2_7b",
    "whisper_medium", "jamba_1_5_large_398b", "opt_6_7b",
]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.reduced()


def registry() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
