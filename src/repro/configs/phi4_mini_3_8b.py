"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA decoder.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064
[arXiv:2412.08905; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    attention="gqa",
    mlp_act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, scan_layers=False, max_seq_len=128,
    )
