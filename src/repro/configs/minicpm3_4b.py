"""minicpm3-4b [dense] — MLA attention, dense SwiGLU MLP.

62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
[hf:openbmb/MiniCPM3-4B; hf]

MLA dims from the HF config: q_lora 768, kv_lora 256, qk_nope 64,
qk_rope 32, v_head 64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    mlp_act="swiglu",
    norm="rmsnorm",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
        qk_rope_head_dim=8, v_head_dim=16, scan_layers=False, max_seq_len=128,
    )
