from repro.configs.base import (ModelConfig, ShapeCfg, SHAPES, ARCH_IDS,
                                get_config, get_reduced, registry)

__all__ = ["ModelConfig", "ShapeCfg", "SHAPES", "ARCH_IDS", "get_config",
           "get_reduced", "registry"]
