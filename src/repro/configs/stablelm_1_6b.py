"""stablelm-1.6b [dense] — MHA decoder (kv = heads), LayerNorm.

24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    attention="gqa",
    mlp_act="swiglu",
    norm="layernorm",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, scan_layers=False, max_seq_len=128,
    )
