from repro.train import checkpoint
