"""Distributed trainer: pjit train step, microbatch accumulation,
checkpoint/restart, failure recovery, straggler detection, elastic
re-mesh, optional int8 gradient compression.

Fault-tolerance model (1000+-node posture):
  * every state mutation flows through the TrainState pytree; the async
    checkpointer snapshots it atomically every ``ckpt_every`` steps;
  * the data pipeline is step-addressable (pure function of step), so
    restart = restore latest checkpoint + continue at step+1 — bitwise
    identical batches, no iterator state;
  * ``run`` catches per-step exceptions (the single-process stand-in for
    a node failure), restores the latest checkpoint and retries — the
    same path a real cluster takes after a coordinator-restart;
  * straggler mitigation: per-step wall times feed an EWMA watermark;
    steps slower than ``straggler_factor`` x the watermark are logged and
    counted (on a real fleet this feeds the scheduler's replace-node
    decision; here it is observable behaviour under test);
  * elastic re-mesh: ``reshard_to`` rebuilds shardings on a new mesh and
    device_puts the restored state — any divisor topology works.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.train import checkpoint as ckpt_mod


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1            # gradient accumulation factor
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0
    grad_compression: bool = False   # int8 + error feedback
    fsdp: bool = False
    seed: int = 0


class Trainer:
    def __init__(self, model: Model, opt_cfg: adamw.AdamWConfig,
                 train_cfg: TrainConfig, mesh=None, rules=None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.cfg = train_cfg
        self.mesh = mesh
        self.rules = rules or (shd.make_rules(fsdp=train_cfg.fsdp)
                               if mesh is not None else None)
        self._step_fn = None
        self.ckpt = ckpt_mod.AsyncCheckpointer(train_cfg.ckpt_dir)
        self.step_times: list[float] = []
        self.stragglers: list[int] = []

    # ------------------------------------------------------------------
    def init_state(self, rng):
        params = self.model.init(rng)
        opt = adamw.init_state(params)
        return {"params": params, "opt": opt,
                "step": jnp.zeros((), jnp.int32)}

    def state_shardings(self, state):
        if self.mesh is None:
            return None
        axes = self.model.axes()
        p_sh = shd.build_shardings(self.mesh, state["params"], axes, self.rules)
        opt_sh = adamw.AdamWState(
            count=shd.replicated(self.mesh),
            m=shd.build_shardings(self.mesh, state["opt"].m, axes, self.rules),
            v=shd.build_shardings(self.mesh, state["opt"].v, axes, self.rules),
        )
        return {"params": p_sh, "opt": opt_sh,
                "step": shd.replicated(self.mesh)}

    # ------------------------------------------------------------------
    def build_step(self, batch_example):
        """jit'd (state, batch) -> (state, metrics) with donation."""
        model, opt_cfg, n_micro = self.model, self.opt_cfg, self.cfg.microbatches
        compress = self.cfg.grad_compression

        def loss_fn(params, batch):
            return model.loss_fn(params, batch)

        def step(state, batch):
            params = state["params"]
            if n_micro > 1:
                # split the batch into microbatches and accumulate grads —
                # overlap-friendly: XLA schedules each microbatch's grads'
                # reduce while the next microbatch computes.
                def mb(i, carry):
                    gacc, lacc = carry
                    mb_batch = jax.tree_util.tree_map(
                        lambda x: jax.lax.dynamic_slice_in_dim(
                            x, i * (x.shape[0] // n_micro),
                            x.shape[0] // n_micro, axis=0), batch)
                    l, g = jax.value_and_grad(loss_fn)(params, mb_batch)
                    gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                    return gacc, lacc + l
                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, loss = jax.lax.fori_loop(
                    0, n_micro, mb, (zeros, jnp.zeros((), jnp.float32)))
                grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
                loss = loss / n_micro
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)

            if compress:
                # int8 on the wire: quantize -> (implicit all-reduce in
                # sharded grads) -> dequantize.  Error feedback residual is
                # recomputed per step (stateless form).
                q, s, _ = adamw.compress_grads(grads)
                grads = adamw.decompress_grads(q, s)

            new_params, new_opt, metrics = adamw.apply_updates(
                params, grads, state["opt"], opt_cfg)
            metrics["loss"] = loss
            return ({"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}, metrics)

        if self.mesh is not None:
            state_sh = None  # filled at call time

            def jit_with(state):
                sh = self.state_shardings(state)
                bsh = shd.batch_shardings(
                    self.mesh,
                    {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                     for k, v in batch_example.items()},
                    self.rules)
                return jax.jit(step, in_shardings=(sh, bsh),
                               out_shardings=(sh, None),
                               donate_argnums=(0,))
            self._jit_with = jit_with
        self._step_fn = jax.jit(step, donate_argnums=(0,)) \
            if self.mesh is None else None
        return step

    # ------------------------------------------------------------------
    def run(self, pipeline, rng=None, state=None, inject_failure_at=None):
        """Train with auto-resume; returns (state, history).

        inject_failure_at: step index at which a simulated node failure
        (RuntimeError) is raised once — exercises the recovery path.
        """
        rng = rng if rng is not None else jax.random.PRNGKey(self.cfg.seed)
        start_step = 0
        if state is None:
            latest = ckpt_mod.latest_step(self.cfg.ckpt_dir)
            if latest is not None:
                state, start_step = self._restore(latest)
                print(f"[trainer] resumed from step {start_step}")
            else:
                state = self.init_state(rng)
        batch0 = pipeline.batch_at(0)
        batch0 = {k: jnp.asarray(v) for k, v in batch0.items()}
        self.build_step(batch0)
        step_fn = (self._jit_with(state) if self.mesh is not None
                   else self._step_fn)

        history = []
        failed_once = False
        t_ewma = None
        step = start_step
        while step < self.cfg.steps:
            try:
                if inject_failure_at is not None and step == inject_failure_at \
                        and not failed_once:
                    failed_once = True
                    raise RuntimeError("simulated node failure")
                t0 = time.perf_counter()   # full step incl. data fetch —
                # input stalls are a straggler class too
                batch = {k: jnp.asarray(v)
                         for k, v in pipeline.batch_at(step).items()}
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                # straggler watermark — the first executed step carries jit
                # compile time and must not contaminate the EWMA
                if step > start_step:
                    if t_ewma is None:
                        t_ewma = dt
                    if dt > self.cfg.straggler_factor * t_ewma \
                            and step > start_step + 3:
                        self.stragglers.append(step)
                        print(f"[trainer] straggler at step {step}: "
                              f"{dt*1e3:.0f}ms vs watermark {t_ewma*1e3:.0f}ms")
                    t_ewma = 0.9 * t_ewma + 0.1 * dt
                self.step_times.append(dt)
                history.append({k: float(v) for k, v in metrics.items()})
                step += 1
                if step % self.cfg.ckpt_every == 0 or step == self.cfg.steps:
                    self.ckpt.save_async(step, state)
                if step % self.cfg.log_every == 0:
                    print(f"[trainer] step {step}: loss="
                          f"{history[-1]['loss']:.4f} ({dt*1e3:.0f}ms)")
            except RuntimeError as e:
                print(f"[trainer] failure at step {step}: {e}; recovering")
                self.ckpt.wait()
                latest = ckpt_mod.latest_step(self.cfg.ckpt_dir)
                if latest is None:
                    state = self.init_state(rng)
                    step = 0
                else:
                    state, step = self._restore(latest)
                step_fn = (self._jit_with(state) if self.mesh is not None
                           else self._step_fn)
        self.ckpt.wait()
        return state, history

    # ------------------------------------------------------------------
    def _restore(self, step: int):
        state, step, _ = ckpt_mod.restore(self.cfg.ckpt_dir, step)
        # opt state restores as a plain dict; rebuild the NamedTuple
        if isinstance(state.get("opt"), dict):
            state["opt"] = adamw.AdamWState(**state["opt"])
        state["step"] = jnp.asarray(state["step"], jnp.int32)
        if self.mesh is not None:
            sh = self.state_shardings(state)
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(jnp.asarray(x), s), state, sh)
        return state, int(step)

    def reshard_to(self, mesh, state):
        """Elastic re-mesh: place an (unsharded/restored) state on a new
        mesh.  Any topology whose axes divide the dims works."""
        self.mesh = mesh
        self.rules = shd.make_rules(fsdp=self.cfg.fsdp)
        sh = self.state_shardings(state)
        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), state, sh)
