"""Sharded, atomic, async checkpointing (numpy-backed; no orbax on box).

Layout:  <dir>/step_<N>/
            manifest.json        — tree structure, shapes, dtypes, step
            <leaf-path>.npy      — one file per leaf (global/logical array)

Guarantees used by the fault-tolerance story:
  * **atomic commit** — written to ``step_<N>.tmp`` then os.rename'd;
    a crash mid-write can never produce a "latest" that is half-written.
  * **topology-agnostic** — leaves are saved as full logical arrays
    (gathered from whatever sharding they had), so a restore may target a
    *different* mesh (elastic scaling: 512 -> 256 chips re-shards freely).
  * **async** — ``save_async`` snapshots to host then writes in a
    background thread; training continues during the disk write.
  * **auto-resume** — ``latest_step``/``restore`` pick the newest complete
    manifest.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _is_namedtuple(x) -> bool:
    return isinstance(x, tuple) and hasattr(x, "_fields")


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (str(k),))
    elif _is_namedtuple(tree):
        for k in tree._fields:
            yield from _flatten(getattr(tree, k), path + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (str(i),))
    elif tree is None:
        yield path, None
    else:
        yield path, tree


def _unflatten(skeleton, leaves: dict, path=()):
    if isinstance(skeleton, dict):
        if skeleton.get("__namedtuple__"):
            fields = skeleton["fields"]
            return {k: _unflatten(v, leaves, path + (str(k),))
                    for k, v in fields.items()}
        return {k: _unflatten(v, leaves, path + (str(k),))
                for k, v in skeleton.items()}
    if isinstance(skeleton, (list, tuple)):
        t = [(_unflatten(v, leaves, path + (str(i),)))
             for i, v in enumerate(skeleton)]
        return t
    if skeleton is None:
        return None
    return leaves["/".join(path)]


def _skeleton(tree):
    if isinstance(tree, dict):
        return {k: _skeleton(v) for k, v in tree.items()}
    if _is_namedtuple(tree):
        # namedtuples restore as plain dicts (callers rebuild the type)
        return {"__namedtuple__": True,
                "fields": {k: _skeleton(getattr(tree, k))
                           for k in tree._fields}}
    if isinstance(tree, (list, tuple)):
        return [_skeleton(v) for v in tree]
    return None if tree is None else "leaf"


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None):
    """Blocking atomic save of a pytree (params/opt state/counters)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": {}, "extra": extra or {},
                "skeleton": _skeleton(tree)}
    for path, leaf in _flatten(tree):
        if leaf is None:
            continue
        key = "/".join(path)
        arr = np.asarray(jax.device_get(leaf))
        fn = key.replace("/", "__") + ".npy"
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":        # numpy can't serialize ml_dtypes
            np.save(os.path.join(tmp, fn), arr.view(np.uint16))
        else:
            np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {"file": fn, "dtype": dtype_name,
                                   "shape": list(arr.shape)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    return final


class AsyncCheckpointer:
    """Snapshot-to-host immediately, write to disk in a daemon thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()                             # one in flight at a time
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save(self.ckpt_dir, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)


def list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            mani = os.path.join(ckpt_dir, name, "manifest.json")
            if os.path.exists(mani):            # complete checkpoints only
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None,
            shardings: Any = None, template: Any = None):
    """Restore a pytree; optionally place leaves with target shardings.

    shardings: matching pytree of jax.sharding.Sharding (or None leaves) —
    this is the elastic-rescale path: any mesh whose axes divide the leaf
    dims works regardless of the mesh at save time.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        leaves[key] = arr
    tree = _unflatten(manifest["skeleton"], leaves)
    if template is not None:
        # cast/convert leaves to the template's dtypes (e.g. np->jnp bf16)
        tree = jax.tree_util.tree_map(
            lambda t, l: jnp.asarray(l, getattr(t, "dtype", None)), template, tree)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda l, s: jax.device_put(l, s) if s is not None else jnp.asarray(l),
            tree, shardings)
    return tree, manifest["step"], manifest.get("extra", {})
