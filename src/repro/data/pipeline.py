"""Deterministic, shard-aware token data pipeline.

Two sources:
  * ``SyntheticLM``  — structured pseudo-text (Zipfian unigrams + Markov
    bigram structure) so a small LM actually has something to learn; fully
    deterministic in (seed, step) => exact replay after checkpoint restore.
  * ``MemmapTokens`` — np.memmap over a token file (the production path).

The pipeline is *stateless given the step index*: ``batch_at(step)`` is a
pure function, so fault-tolerant resume only needs the step counter from
the checkpoint — no iterator state to serialize — and elastic re-sharding
(different data_shards after a re-mesh) re-partitions deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Zipf + bigram-markov synthetic corpus, deterministic per (seed, step)."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    data_shard: int = 0
    data_shards: int = 1
    zipf_a: float = 1.3

    def __post_init__(self):
        if self.global_batch % self.data_shards:
            raise ValueError("global_batch must divide data_shards")
        self.local_batch = self.global_batch // self.data_shards
        rng = np.random.default_rng(self.seed)
        # fixed bigram transition structure: each token prefers a small set
        # of successors -> learnable low-entropy structure
        self._succ = rng.integers(0, self.vocab_size,
                                  size=(self.vocab_size, 4), dtype=np.int32)
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_a)
        self._unigram = p / p.sum()

    def batch_at(self, step: int) -> dict:
        """-> {'tokens': int32 [local_batch, seq_len]} for this shard."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.data_shard)
        b, s = self.local_batch, self.seq_len
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.choice(self.vocab_size, size=b, p=self._unigram)
        follow = rng.random((b, s)) < 0.8          # 80% bigram-structured
        nxt_choice = rng.integers(0, 4, size=(b, s))
        fresh = rng.choice(self.vocab_size, size=(b, s), p=self._unigram)
        for t in range(1, s):
            structured = self._succ[toks[:, t - 1], nxt_choice[:, t]]
            toks[:, t] = np.where(follow[:, t], structured, fresh[:, t])
        return {"tokens": toks}


@dataclasses.dataclass
class MemmapTokens:
    """Flat token-file source (np.memmap), shard-aware & step-addressable."""
    path: str
    seq_len: int
    global_batch: int
    data_shard: int = 0
    data_shards: int = 1
    dtype: str = "int32"

    def __post_init__(self):
        self.local_batch = self.global_batch // self.data_shards
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self.n_tokens = self._data.shape[0]
        self.seqs_total = self.n_tokens // self.seq_len

    def batch_at(self, step: int) -> dict:
        b, s = self.local_batch, self.seq_len
        base = (step * self.global_batch + self.data_shard * b) % max(
            self.seqs_total - b, 1)
        idx = (base + np.arange(b)) % self.seqs_total
        toks = np.stack([self._data[i * s:(i + 1) * s] for i in idx])
        return {"tokens": toks.astype(np.int32)}


def make_pipeline(kind: str, **kw):
    if kind == "synthetic":
        return SyntheticLM(**kw)
    if kind == "memmap":
        return MemmapTokens(**kw)
    raise ValueError(f"unknown pipeline {kind!r}")
