from repro.data.pipeline import SyntheticLM, MemmapTokens, make_pipeline
