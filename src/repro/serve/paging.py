"""Paged KV cache: fixed-size blocks in a shared pool + per-sequence
block tables.

The device side lives in ``models/attention.py`` (``paged_view`` /
``cache_insert``'s paged branch): every per-layer cache buffer is shaped
``[num_blocks, block_size, ...]`` and a ``block_tables`` leaf ``[B,
max_blocks_per_seq]`` maps each sequence's logical blocks to physical
pool blocks (-1 = unallocated).  This module is the *host* side: a free
list allocator with double-booking checks, plus helpers to push updated
block tables into a cache tree.

Physical block 0 is reserved as the trash block: writes whose target is
out of range or unallocated (right-padded prefill chunks, idle batch
rows) are routed there by the device-side insert, and the view masks any
slot reached through a -1 table entry — so the trash block's contents
are never observable.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.models.module import tree_map_with_path


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` KV entries."""
    return -(-max(n_tokens, 0) // block_size)


class BlockPool:
    """Free-list allocator over the shared block pool (host bookkeeping).

    Block 0 is reserved (trash); ``capacity`` counts usable blocks only.
    Every alloc/free is checked against an owner map so a block can never
    be double-booked or double-freed — the invariant the paged cache's
    correctness rests on.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque = deque(range(1, num_blocks))
        self._owner: Dict[int, object] = {}          # block -> owner tag

    # ------------------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Usable blocks (excludes the reserved trash block)."""
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.capacity - len(self._free)

    def occupancy(self) -> float:
        return self.used_blocks / self.capacity

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.block_size)

    # ------------------------------------------------------------------
    def alloc(self, owner, n: int = 1) -> Optional[List[int]]:
        """Allocate ``n`` blocks for ``owner``; None if insufficient
        (all-or-nothing, so a partial grab never strands blocks)."""
        if n > len(self._free):
            return None
        out = []
        for _ in range(n):
            b = self._free.popleft()
            assert b not in self._owner, f"double-booked block {b}"
            assert b != 0, "trash block leaked into the free list"
            self._owner[b] = owner
            out.append(b)
        return out

    def free(self, blocks: List[int], owner) -> None:
        for b in blocks:
            got = self._owner.pop(b, None)
            assert got is not None, f"double-free of block {b}"
            assert got == owner, f"block {b} owned by {got}, freed by {owner}"
            self._free.append(b)

    def owned_by(self, owner) -> List[int]:
        return [b for b, o in self._owner.items() if o == owner]

    def check(self) -> None:
        """Assert the pool's books balance (used in tests after every run)."""
        assert len(self._free) + len(self._owner) == self.capacity
        assert not (set(self._free) & set(self._owner))


# ---------------------------------------------------------------------------
# cache-tree helpers
# ---------------------------------------------------------------------------


def set_block_tables(cache, tables):
    """Return ``cache`` with every ``block_tables`` leaf set to ``tables``.

    ``tables``: int32 [B, max_blocks_per_seq] (np or jnp).  Scan-stacked
    layer caches carry a leading layers axis on every leaf; the tables
    are broadcast across it (all layers share one block table).
    """
    tables = jnp.asarray(tables, jnp.int32)

    def fix(path, leaf):
        if path and path[-1] == "block_tables":
            if leaf.ndim == tables.ndim + 1:          # scan-stacked layers
                # batch may differ from the leaf's (single-row prefill
                # slices), so rebuild the shape from the new tables
                return jnp.broadcast_to(tables[None],
                                        (leaf.shape[0], *tables.shape))
            return tables
        return leaf
    return tree_map_with_path(fix, cache)
