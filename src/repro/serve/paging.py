"""Paged KV cache: fixed-size blocks in a shared pool + per-sequence
block tables, with refcounted cross-sequence block sharing.

The device side lives in ``models/attention.py`` (``paged_view`` /
``cache_insert``'s paged branch): every per-layer cache buffer is shaped
``[num_blocks, block_size, ...]`` and a ``block_tables`` leaf ``[B,
max_blocks_per_seq]`` maps each sequence's logical blocks to physical
pool blocks (-1 = unallocated).  This module is the *host* side: a
refcounting allocator with double-booking checks, the prefix index that
lets many sequences share one physical block, and helpers to push
updated block tables into a cache tree.

Ownership / refcount / immutability invariants (enforced by the
asserts here and by ``tests/test_property_paging.py``):

  * every allocated block has >= 1 holders; a holder appears at most
    once per block (``free`` is a decref — the block is recycled only
    when the LAST holder releases it, so refcounts can never go
    negative and preempt-by-recompute can never yank a shared block out
    from under another sequence);
  * a block with more than one holder is IMMUTABLE: the scheduler only
    shares blocks that are completely filled with prompt/prefix KV, and
    every write (decode append, prefill chunk) lands at a position
    whose block is held by exactly one sequence.  Copy-on-write is
    "copy by recompute": a request whose prompt ends inside (or
    diverges inside) a cached block gets a fresh private block and
    prefills those tokens again — shared blocks are never written;
  * a shared block sits at the SAME logical index in every holder's
    table (the prefix key hashes the whole token chain from position
    0), so the device-side ``pos == logical index`` liveness rule holds
    for every sharer without per-sequence state.

Physical block 0 is reserved as the trash block: writes whose target is
out of range or unallocated (right-padded prefill chunks, idle batch
rows) are routed there by the device-side insert, and the view masks any
slot reached through a -1 table entry — so the trash block's contents
are never observable.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax.numpy as jnp

from repro.models.module import tree_map_with_path


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` KV entries."""
    return -(-max(n_tokens, 0) // block_size)


class BlockPool:
    """Refcounting free-list allocator over the shared block pool.

    Block 0 is reserved (trash); ``capacity`` counts usable blocks only.
    ``alloc`` hands out exclusive blocks (refcount 1); ``share`` adds a
    holder to an already-allocated block (prefix reuse); ``free``
    removes ONE holder and recycles the block only at refcount 0.
    Every transition is checked against the holder map so a block can
    never be double-booked, double-freed, or freed by a non-holder —
    the invariants the paged cache's correctness rests on.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque = deque(range(1, num_blocks))
        self._holders: Dict[int, List[object]] = {}   # block -> holder tags

    # ------------------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Usable blocks (excludes the reserved trash block)."""
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Distinct allocated blocks (a shared block counts once)."""
        return self.capacity - len(self._free)

    def occupancy(self) -> float:
        return self.used_blocks / self.capacity

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.block_size)

    # ------------------------------------------------------------------
    def alloc(self, owner, n: int = 1) -> Optional[List[int]]:
        """Allocate ``n`` exclusive blocks for ``owner``; None if
        insufficient (all-or-nothing, so a partial grab never strands
        blocks)."""
        if n > len(self._free):
            return None
        out = []
        for _ in range(n):
            b = self._free.popleft()
            assert b not in self._holders, f"double-booked block {b}"
            assert b != 0, "trash block leaked into the free list"
            self._holders[b] = [owner]
            out.append(b)
        return out

    def share(self, blocks: Sequence[int], owner) -> None:
        """Add ``owner`` as a holder of each already-allocated block
        (refcount + 1).  Shared blocks are immutable by contract — the
        scheduler only shares full, registered prefix blocks."""
        for b in blocks:
            hs = self._holders.get(b)
            assert hs, f"sharing unallocated block {b}"
            assert owner not in hs, f"owner {owner} already holds block {b}"
            hs.append(owner)

    def free(self, blocks: Sequence[int], owner) -> None:
        """Release ``owner``'s hold on each block (refcount - 1); a
        block returns to the free list only when its LAST holder frees
        it."""
        for b in blocks:
            hs = self._holders.get(b)
            assert hs is not None, f"double-free of block {b}"
            assert owner in hs, f"block {b} not held by {owner} " \
                                f"(holders: {hs})"
            hs.remove(owner)
            if not hs:
                del self._holders[b]
                self._free.append(b)

    # ------------------------------------------------------------------
    def refcount(self, block: int) -> int:
        return len(self._holders.get(block, ()))

    def writable(self, block: int, owner) -> bool:
        """The immutability predicate: only the sole holder may write."""
        return self._holders.get(block) == [owner]

    def owned_by(self, owner) -> List[int]:
        return [b for b, hs in self._holders.items() if owner in hs]

    def check(self) -> None:
        """Assert the pool's books balance (used in tests after every run)."""
        assert len(self._free) + len(self._holders) == self.capacity
        assert not (set(self._free) & set(self._holders))
        for b, hs in self._holders.items():
            assert len(hs) >= 1, f"allocated block {b} with no holders"
            assert len(hs) == len(set(map(id, hs))), \
                f"duplicate holder on block {b}"


# ---------------------------------------------------------------------------
# prefix cache: chain-hash index over block-aligned token chunks
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Entry:
    """One cached block: the KV of ``tokens`` at logical block depth
    ``depth`` under the chain identified by ``parent`` (None = block 0
    of a sequence)."""
    key: int
    parent: Optional[int]
    tokens: Tuple[int, ...]
    block: int
    depth: int
    children: Set[int] = dataclasses.field(default_factory=set)
    last_used: int = 0


class PrefixCache:
    """Prefix index: rolling hash of block-aligned token chunks -> live
    physical block, so admission can map a new request's prompt onto
    blocks that already hold its KV instead of scheduling prefill.

    Entries form a trie over token chunks: the key of block ``j`` is
    ``hash((key_of_block_{j-1}, tokens_of_block_j))`` — it therefore
    commits to EVERY token from position 0, which is what makes a hit
    safe: a cached block is only ever adopted at the same logical index
    it was written at, with the same full token history (each step also
    re-verifies the chunk's tokens, so a hash collision degrades to a
    miss, never a wrong adoption).

    The cache holds its own reference on every entry's block (it is a
    holder in the :class:`BlockPool` sense), which keeps prefixes WARM
    after the sequences that wrote them retire.  Eviction is
    LRU-leaf-first and only touches blocks whose sole holder is the
    cache (``refcount == 1``): blocks shared with live sequences are
    pinned.  ``evict`` runs on demand when the pool would otherwise be
    dry — the cache never starves real allocations.
    """

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self.entries: Dict[int, _Entry] = {}
        self._roots: Set[int] = set()
        self._tick = 0
        self.evictions = 0

    def __len__(self) -> int:
        """Number of cached blocks (each entry pins exactly one)."""
        return len(self.entries)

    @staticmethod
    def _key(parent: Optional[int], chunk: Tuple[int, ...]) -> int:
        return hash((parent, chunk))

    def _touch(self, e: _Entry) -> None:
        self._tick += 1
        e.last_used = self._tick

    # ------------------------------------------------------------------
    def lookup(self, tokens, max_blocks: int):
        """Longest cached chain covering ``tokens`` (at most
        ``max_blocks`` full blocks).  Returns ``(blocks, last_key)``:
        the physical blocks to adopt (logical indices ``0..len-1``) and
        the chain key of the last one (None on a cold miss) — the
        caller threads ``last_key`` back into registration so the chain
        continues where the hit ended.  Touches LRU; does NOT take a
        reference (the caller shares the blocks while holding the GIL,
        before anything can evict)."""
        bs = self.pool.block_size
        blocks: List[int] = []
        parent: Optional[int] = None
        for j in range(max_blocks):
            chunk = tuple(int(t) for t in tokens[j * bs:(j + 1) * bs])
            if len(chunk) < bs:
                break
            key = self._key(parent, chunk)
            e = self.entries.get(key)
            # verify the chunk's tokens AND the walked chain's parent key:
            # with both, induction over j proves the full token history
            # matches, so a hash collision (same key, different prefix)
            # degrades to a miss instead of adopting foreign KV
            if e is None or e.tokens != chunk or e.parent != parent:
                break
            self._touch(e)
            blocks.append(e.block)
            parent = key
        return blocks, parent

    def cached_overlap(self, parent_key: Optional[int], tail) -> int:
        """Longest common token prefix between ``tail`` (the request's
        remaining tokens inside the first un-adopted block) and any
        cached sibling chunk under ``parent_key``.  A positive overlap
        is a copy-on-write event: a memcpy-CoW design would copy those
        slots into a private block; this engine recomputes them (same
        outcome — the shared block is never written)."""
        tail = [int(t) for t in tail]
        if not tail:
            return 0
        kids = self._roots if parent_key is None \
            else self.entries[parent_key].children
        best = 0
        for k in kids:
            cached = self.entries[k].tokens
            n = 0
            for a, b in zip(tail, cached):
                if a != b:
                    break
                n += 1
            best = max(best, n)
        return best

    def register(self, parent_key: Optional[int], chunk: Tuple[int, ...],
                 block: int) -> Optional[int]:
        """Index ``block`` as holding the KV of ``chunk`` under
        ``parent_key``'s chain; the cache takes a reference (the block
        survives its writer).  A duplicate key with identical tokens is
        a no-op returning the existing key (the writer keeps its
        private copy; future admissions dedup against the first).
        Returns None — the caller must stop registering this chain —
        on a key collision with DIFFERENT tokens or parent (lookup's
        verification already makes the collision unadoptable), and
        when ``parent_key``'s entry has been evicted (a dedup'd chain
        whose backing entry retired): continuing would create a root
        entry that lookup can never reach, pinning a block for nothing
        and polluting the CoW metrics."""
        assert len(chunk) == self.pool.block_size, "only full blocks cache"
        key = self._key(parent_key, chunk)
        e = self.entries.get(key)
        if e is not None:
            if e.tokens != chunk or e.parent != parent_key:
                return None
            self._touch(e)
            return key
        parent = None
        if parent_key is not None:
            parent = self.entries.get(parent_key)
            if parent is None:
                return None
        e = _Entry(key=key, parent=parent_key, tokens=tuple(chunk),
                   block=block, depth=0 if parent is None else
                   parent.depth + 1)
        self.pool.share([block], self)
        self.entries[key] = e
        self._touch(e)
        if parent is None:
            self._roots.add(key)
        else:
            parent.children.add(key)
        return key

    # ------------------------------------------------------------------
    def evictable(self) -> int:
        """Blocks the cache could ACTUALLY free on demand via iterated
        leaf-first eviction: an entry is freeable iff its block has no
        holder but the cache AND every child entry is freeable (evict()
        only drops childless entries, so a pinned descendant blocks its
        whole ancestor chain).  Counting every refcount-1 entry would
        overcount — dedup can leave a cache-only parent above a pinned
        child (refcounts are not non-increasing with depth) — and an
        optimistic budget here makes the scheduler over-admit and then
        fail allocations evict() cannot actually cover."""
        freeable: Dict[int, bool] = {}
        # children always sit one level deeper than their parent, so a
        # deepest-first sweep sees every child before its parent
        for e in sorted(self.entries.values(), key=lambda e: -e.depth):
            freeable[e.key] = (self.pool.refcount(e.block) == 1 and
                               all(freeable[k] for k in e.children))
        return sum(freeable.values())

    def _drop(self, e: _Entry) -> None:
        del self.entries[e.key]
        if e.parent is None:
            self._roots.discard(e.key)
        else:
            parent = self.entries.get(e.parent)
            if parent is not None:
                parent.children.discard(e.key)
        self.pool.free([e.block], self)

    def evict(self, n: int) -> int:
        """Free up to ``n`` blocks, LRU-leaf-first, skipping blocks
        still shared with live sequences.  Returns blocks actually
        freed."""
        freed = 0
        while freed < n:
            best = None
            for e in self.entries.values():
                if e.children or self.pool.refcount(e.block) != 1:
                    continue
                if best is None or e.last_used < best.last_used:
                    best = e
            if best is None:
                break
            self._drop(best)
            freed += 1
        self.evictions += freed
        return freed

    def clear(self) -> None:
        """Release every cache reference (shared blocks stay allocated
        for their sequences).  After a drained engine clears its cache,
        the pool is fully free — the invariant the property tests close
        the loop on."""
        for e in list(self.entries.values()):
            self.pool.free([e.block], self)
        self.entries.clear()
        self._roots.clear()


# ---------------------------------------------------------------------------
# cache-tree helpers
# ---------------------------------------------------------------------------


def set_block_tables(cache, tables):
    """Return ``cache`` with every ``block_tables`` leaf set to ``tables``.

    ``tables``: int32 [B, max_blocks_per_seq] (np or jnp).  Scan-stacked
    layer caches carry a leading layers axis on every leaf; the tables
    are broadcast across it (all layers share one block table).
    """
    tables = jnp.asarray(tables, jnp.int32)

    def fix(path, leaf):
        if path and path[-1] == "block_tables":
            if leaf.ndim == tables.ndim + 1:          # scan-stacked layers
                # batch may differ from the leaf's (single-row prefill
                # slices), so rebuild the shape from the new tables
                return jnp.broadcast_to(tables[None],
                                        (leaf.shape[0], *tables.shape))
            return tables
        return leaf
    return tree_map_with_path(fix, cache)
