"""Batched serving engine: continuous batching over a fixed slot grid.

The unit of work is a *slot* (row of the KV cache).  Requests join free
slots; one jit'd ``decode_step`` advances every active slot each tick
(per-row positions — ``cache_insert`` takes a [B] position vector, so
slots at different depths coexist).  Prefill runs per-request through the
jit'd ``prefill`` on a dedicated length-bucketed batch to bound
recompilation.

Works with dense or BCQ-quantized params transparently (the model's
``gemm_backend`` decides the execution path) — this is the deployment
shape of the paper's engine: weight-only-quantized LLM decode.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # int32 [prompt_len]
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, *, slots: int = 8,
                 cache_len: int = 512, prefill_buckets=(32, 128, 512),
                 rng_seed: int = 0, pretune: bool = False):
        self.model = model
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.buckets = sorted(prefill_buckets)
        if pretune:
            self._pretune()
        self.cache = model.init_cache(slots, cache_len)
        self.slot_req: list = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.rng = np.random.default_rng(rng_seed)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self.ticks = 0

    # ------------------------------------------------------------------
    def _pretune(self):
        """Warm the repro.tune cache for every quantized GEMM this engine
        will launch — decode steps run b = active-slot rows, prefill runs
        b = prompt-bucket rows — in the model's activation dtype, so the
        first serving ticks hit tuned configs instead of the heuristic.
        No-op for dense params or non-Pallas backends."""
        from repro import tune as tune_mod
        from repro.core import lut_gemm as core_lg
        kernel = {"lut_pallas": "lut_gemm",
                  "mxu_pallas": "bcq_matmul"}.get(self.model.cfg.gemm_backend)
        if kernel is None or not tune_mod.collect_bcq_specs(self.params):
            return
        # interpret mode (CPU smoke): small reps + truncated space so
        # pretune stays a warm-up, not a benchmark run
        extra = dict(reps=2, warmup=1, max_candidates=8) if core_lg.INTERPRET else {}
        batches = sorted({1, self.slots, *self.buckets})
        tune_mod.pretune_params(self.params, kernels=(kernel,),
                                batch_sizes=batches,
                                dtype=jnp.dtype(self.model.cfg.dtype),
                                verbose=True, **extra)

    # ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def add_request(self, req: Request) -> bool:
        """Prefill into a free slot; False if engine is full."""
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        plen = len(req.prompt)
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, -plen:] = req.prompt          # left-pad into the bucket
        # run prefill on a single-row cache then splice into the big cache
        small = self.model.init_cache(1, self.cache_len)
        logits, small = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, small)
        self.cache = _splice_cache(self.cache, small, slot)
        # note: left-padding means positions 0..bucket-1 with pad tokens at
        # the start; harmless for causal decode (pads are attended but
        # carry learned-nothing embeddings on random prompts; production
        # would mask pads — documented simplification).
        first = _sample(np.asarray(logits)[0], req.temperature, self.rng)
        req.out_tokens.append(int(first))
        self.slot_req[slot] = req
        self.slot_pos[slot] = bucket
        return True

    # ------------------------------------------------------------------
    def tick(self):
        """One decode step for every active slot."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        tokens = np.zeros((self.slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slot_req[i].out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(self.slot_pos))
        logits = np.asarray(logits)
        for i in active:
            req = self.slot_req[i]
            tok = _sample(logits[i], req.temperature, self.rng)
            req.out_tokens.append(int(tok))
            self.slot_pos[i] += 1
            if len(req.out_tokens) >= req.max_new_tokens \
                    or self.slot_pos[i] >= self.cache_len - 1:
                req.done = True
                self.slot_req[i] = None
        self.ticks += 1

    def run(self, requests: list, max_ticks: int = 1000) -> list:
        """Continuous batching: admit when slots free, tick until done."""
        pending = list(requests)
        done = []
        while (pending or any(r is not None for r in self.slot_req)) \
                and self.ticks < max_ticks:
            while pending and self._free_slots():
                if not self.add_request(pending[0]):
                    break
                pending.pop(0)
            self.tick()
            done = [r for r in requests if r.done]
        return done


def _sample(logits: np.ndarray, temperature: float, rng) -> int:
    if temperature <= 0:
        return int(np.argmax(logits))
    p = np.exp((logits - logits.max()) / temperature)
    p /= p.sum()
    return int(rng.choice(len(p), p=p))


def _splice_cache(big, small, slot: int):
    """Copy a 1-row cache into row ``slot`` of the engine cache."""
    return jax.tree_util.tree_map(
        lambda b, s: b.at[slot:slot + 1].set(s.astype(b.dtype)), big, small)
