"""Serving engines: paged continuous batching (primary) and the
fixed-slot contiguous engine (reference / fallback).

``PagedServeEngine`` is the production shape: KV lives in a shared block
pool (``serve/paging.py``), a scheduler (``serve/scheduler.py``) admits
FCFS by free-block budget, prefill runs in bucket-sized chunks written
straight into the pool, decode and prefill interleave every tick, the
pool preempts-by-recompute when it runs dry, and per-token streaming
callbacks plus ``serve/metrics.py`` telemetry come for free.  Capacity
is bounded by *actual tokens held*, not worst-case reservations — the
whole point of paging.

The engine has two tick modes sharing one scheduler and one cache:

  * ``step()`` — synchronous: dispatch, block on the device, sample on
    the host.  The reference semantics.
  * ``step_async()`` — double-buffered: plan against *projected* state,
    dispatch step N with sampling fused on-device
    (:meth:`repro.models.Model.decode_and_sample`, so only token ids
    ever cross the host boundary), then sync and emit step N-1's
    tokens.  The host runs one step behind the device; the device queue
    never drains while there is decode work.  Token-for-token (and
    schedule-for-schedule) identical to ``step()`` under fixed seeds —
    see ``docs/serving.md`` ("Async host loop") for the invariant
    argument.

``ServeEngine`` keeps the contiguous fixed-slot design: every request
reserves a full ``cache_len`` row.  It is the equivalence oracle for the
paged engine (greedy outputs must match token-for-token) and still
serves models the paged cache doesn't cover (SSM/hybrid, enc-dec,
sliding-window).

Both work with dense or plane-bundle-quantized params transparently —
the config's :class:`~repro.quant.QuantSpec` sets the backend
*preference* and the registry's capability negotiation picks the
execution path per weight (kind-aware: ternary bundles route to the
dedicated kernel) — the deployment shape of the paper's engine:
weight-only-quantized LLM decode.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from contextlib import nullcontext as _null_scope
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.models.model import sample_tokens
from repro.obs import trace as obs_trace
from repro.obs.trace import req_track
from repro.serve.metrics import ServeMetrics
from repro.serve.paging import BlockPool, PrefixCache, set_block_tables
from repro.serve.scheduler import Scheduler


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # int32 [prompt_len]
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => no truncation (temperature > 0 only)
    seed: Optional[int] = None    # per-request sampling seed (None: engine
                                  # seed folded with uid — still deterministic)
    deadline_s: Optional[float] = None   # absolute, on the engine's clock
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    on_token: Optional[Callable] = None   # streaming: fn(token, request)
    error: Optional[str] = None           # "too_long" | "oom" | "callback"
                                          # | "deadline" | "cancelled" | None


def _emit(req: Request, tok: int) -> None:
    req.out_tokens.append(int(tok))
    cb = req.on_token
    if cb is None:
        return
    try:
        cb(int(tok), req)
    except Exception:
        # a broken streaming consumer must fail ITS request, not wedge
        # the tick (and every other in-flight stream) — the engine
        # retires the request with error="callback" when it sees this
        req.error = "callback"
        req.on_token = None


def request_key(req: Request, index: int, engine_seed: int):
    """The PRNG key for a request's ``index``-th sampled token.

    Derivation is a pure function of (seed-or-uid, index): explicit
    ``req.seed`` wins, otherwise the engine seed folded with the uid, so
    distinct requests never share a stream.  ``index`` counts tokens
    sampled so far — preempt-by-recompute replays the same indices, so
    a resumed request keeps drawing the same tokens, and the sync and
    async samplers (which both receive this key as data) agree bit for
    bit."""
    if req.seed is not None:
        base = jax.random.PRNGKey(req.seed)
    else:
        base = jax.random.fold_in(jax.random.PRNGKey(engine_seed), req.uid)
    return jax.random.fold_in(base, index)


def _sample_host(req: Request, logits_row: np.ndarray,
                 engine_seed: int) -> int:
    """Synchronous host-side sampler.  Greedy stays a plain ``np.argmax``
    (bit-identical to the device's ``jnp.argmax``, ties to the lowest
    index); temperature/top-k route through the SAME
    :func:`~repro.models.model.sample_tokens` the async fused path jits,
    under the same :func:`request_key` — that identity is what the
    sync==async equivalence tests lean on."""
    if req.temperature <= 0:
        return int(np.argmax(logits_row))
    key = request_key(req, len(req.out_tokens), engine_seed)
    tok = sample_tokens(jnp.asarray(logits_row)[None],
                        jnp.asarray(key, jnp.uint32)[None],
                        jnp.full((1,), req.temperature, jnp.float32),
                        jnp.full((1,), req.top_k, jnp.int32))
    return int(tok[0])


def _pretune(model: Model, params, batch_sizes, verbose: bool = True):
    """Warm the repro.tune cache for every quantized GEMM a serving
    engine will launch (decode = active-row batches, prefill = bucket
    rows) so the first ticks hit tuned configs instead of the heuristic.
    No-op for dense params or non-Pallas backends."""
    from repro import tune as tune_mod
    from repro.core import lut_gemm as core_lg
    from repro.quant.backends import kernel_for
    kernel = kernel_for(model.cfg.backend_preference)
    if kernel is None or not tune_mod.collect_bcq_specs(params):
        return
    # interpret mode (CPU smoke): small reps + truncated space so
    # pretune stays a warm-up, not a benchmark run
    extra = dict(reps=2, warmup=1, max_candidates=8) if core_lg.INTERPRET else {}
    tune_mod.pretune_params(params, kernels=(kernel,),
                            batch_sizes=sorted(set(batch_sizes)),
                            dtype=jnp.dtype(model.cfg.dtype),
                            verbose=verbose, **extra)


def supports_paging(cfg) -> bool:
    """Whether a config can serve through the paged engine: attention-only
    decoder, no sliding window (ring caches are already fixed-size), no
    encoder-decoder cross-KV (a fixed per-row reservation)."""
    return (not cfg.is_encdec and not cfg.sliding_window
            and all(cfg.layer_kind(i) == "attn"
                    for i in range(cfg.n_layers)))


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unsynced async step: the device-resident
    sampled token vector plus the host bookkeeping needed to emit it
    next tick."""
    tokens: object                 # device int32 [max_batch]
    emits: list                    # [(SeqState, row)] in sampling order
    row_of: dict                   # uid -> row, for next tick's decode input
    t_dispatch: float              # engine-clock time of dispatch
    tick: int


# ---------------------------------------------------------------------------
# paged engine
# ---------------------------------------------------------------------------


class PagedServeEngine:
    """Continuous batching over a paged KV cache.

    ``num_blocks`` x ``block_size`` KV slots are shared by up to
    ``max_batch`` concurrent sequences; each sequence holds only the
    blocks its tokens actually occupy, so total admitted context can
    exceed ``max_batch`` worst-case reservations by the pool ratio.

    ``paged_kernel`` ("auto" | "fused" | "gather", default: the model
    config's setting) picks the paged attention paths: the fused Pallas
    kernels read live pool blocks directly through the block table —
    decode for float, int8-KV (per-slot scale rows ride the same DMA)
    and MLA latent pools, chunked prefill for float and int8-KV pools —
    while "gather" materializes the contiguous ``paged_view`` per layer
    (the reference path).  The paths are resolved PER VARIANT:
    ``self.decode_path`` and ``self.prefill_path`` can differ (MLA
    decodes fused but prefills gathered, for the decompressing
    ``kv_map_fn``), and both paths' analytic KV traffic is tracked per
    step in ``metrics`` (``kv_bytes_per_token_{fused,gathered}``,
    ``kv_bytes_per_prefill_token_{fused,gathered}``).

    ``prefix_cache=True`` turns on prefix caching: fully-written prompt
    blocks are indexed by their token content and later requests with
    the same block-aligned prefix ADOPT those live blocks by reference
    instead of re-prefilling them (see ``docs/serving.md``).  Adopted
    blocks are shared and immutable — writes always land in privately
    owned blocks (copy-on-write by recompute) — so greedy outputs are
    token-for-token identical with the cache on or off.  Off by
    default: a warm cache deliberately keeps pool blocks occupied after
    their sequences retire, which changes drain-time occupancy.

    ``mesh`` (a ``("data", "model")`` jax Mesh, see
    ``launch.mesh.make_mesh_for``) serves the same engine TP/DP-sharded:
    params and KV-pool leaves are ``device_put`` through
    ``parallel.sharding.build_shardings`` (pool KV shards over
    ``kv_heads`` -> model, falling back to ``head_dim`` when the head
    count doesn't divide), block tables stay replicated host state,
    ``decode_step`` / ``prefill_chunk`` are jitted with explicit in/out
    shardings (batch rows over ``data`` when ``max_batch`` divides), and
    the fused kernel launches per model-shard through ``shard_map``.
    Scheduling, metrics and streaming are unchanged — the mesh is
    invisible above the decode step.

    ``tracer`` (an :class:`repro.obs.Tracer`, or ``attach_tracer`` after
    construction) records an event-level trace of every tick — spans for
    admission, prefix lookup, prefill chunks, decode dispatch, device
    sync and sampling on engine-phase tracks, plus a per-request track
    from submit to retire — exportable as Chrome trace-event JSON via
    ``repro.obs.save_chrome`` (see ``docs/observability.md``).  Off by
    default; the hooks run against a no-op ``NullTracer``.  Under
    ``step_async`` the overlap is directly visible: tick N's
    ``decode_dispatch`` span precedes tick N-1's ``device_sync`` span
    inside the same ``tick`` span.
    """

    def __init__(self, model: Model, params, *, num_blocks: int = 64,
                 block_size: int = 16, max_batch: int = 8,
                 max_seq_len: int = 0, prefill_buckets=(32, 128, 512),
                 rng_seed: int = 0, pretune: bool = False,
                 paged_kernel: Optional[str] = None,
                 prefix_cache: bool = False,
                 mesh=None, shard_rules: Optional[dict] = None,
                 clock=time.perf_counter, tracer=None):
        from repro.models.attention import (kv_entry_bytes,
                                            paged_kernel_mode,
                                            paged_prefill_mode)
        if paged_kernel is not None and paged_kernel != model.cfg.paged_kernel:
            # the mode is part of the (jitted) decode graph, so it lives
            # on the config; an engine-level override rebuilds the Model
            model = Model(model.cfg.replace(paged_kernel=paged_kernel))
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.block_size = block_size
        self.buckets = sorted(prefill_buckets)
        max_seq_len = max_seq_len or model.cfg.max_seq_len
        self.max_seq_len = max_seq_len
        self.max_blocks_per_seq = -(-max_seq_len // block_size)
        self.mesh = mesh
        self._tp = 1
        self._shard_batch = False
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            self._tp = sizes.get("model", 1)
            # batch rows ride the data axis only when they divide it —
            # otherwise they stay replicated (correct, just no DP win)
            self._shard_batch = max_batch % max(sizes.get("data", 1), 1) == 0
        self.decode_path = paged_kernel_mode(
            model.cfg, block_size=block_size, pages=self.max_blocks_per_seq,
            tp=self._tp)
        self.prefill_path = paged_prefill_mode(
            model.cfg, block_size=block_size, pages=self.max_blocks_per_seq,
            tp=self._tp)
        # per-entry bytes INCLUDING the int8 pools' per-slot scale rows:
        # fused int8 decode/prefill DMA the scales alongside each block,
        # and the gathered view materializes them too, so both traffic
        # estimates must count them (see attention.kv_entry_bytes)
        self._kv_entry_bytes = kv_entry_bytes(model.cfg)
        # tracing: hooks below run unconditionally against a NullTracer
        # when tracing is off (attach_tracer swaps in a live one).  The
        # tracer goes active BEFORE pretune/jit so kernel-config
        # resolutions inside tune.dispatch land in the trace too.
        self.trace = obs_trace.NULL
        if tracer is not None:
            self.attach_tracer(tracer)
        if pretune:
            _pretune(model, params, [1, max_batch, *self.buckets])
        self.cache = model.init_paged_cache(max_batch, num_blocks,
                                            block_size,
                                            self.max_blocks_per_seq)
        self.pool = BlockPool(num_blocks, block_size)
        self.prefix = PrefixCache(self.pool) if prefix_cache else None
        self.sched = Scheduler(self.pool, rows=max_batch,
                               buckets=self.buckets,
                               max_blocks_per_seq=self.max_blocks_per_seq,
                               max_seq_len=max_seq_len,
                               prefix_cache=self.prefix,
                               tracer=self.trace)
        self.clock = clock
        self.metrics = ServeMetrics(clock)
        self.tables = np.full((max_batch, self.max_blocks_per_seq), -1,
                              np.int32)
        self.rng_seed = rng_seed
        self.rng = np.random.default_rng(rng_seed)
        self._key_cache: dict = {}          # uid -> base PRNG key
        self._inflight: Optional[_InFlight] = None
        self._row_sh = None                 # token-row sharding when meshed
        if mesh is not None:
            self._build_sharded(num_blocks, shard_rules)
        else:
            self._attn_scope = _null_scope
            self._decode = jax.jit(model.decode_step)
            self._decode_sample = jax.jit(model.decode_and_sample)
            self._prefill_chunk = jax.jit(model.prefill_chunk)
        self._sample_only = jax.jit(sample_tokens)
        self.ticks = 0
        self.finished: list = []

    def _build_sharded(self, num_blocks: int, shard_rules) -> None:
        """Shard params + KV pool over the mesh and re-jit the device
        entry points with explicit in/out shardings."""
        import functools
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import attention as attn
        from repro.parallel import sharding as shd
        mesh, model = self.mesh, self.model
        rules = shard_rules or shd.make_rules()
        p_sh = shd.build_shardings(mesh, self.params, model.axes(), rules)
        self.params = jax.device_put(self.params, p_sh)
        cache_axes = model.paged_cache_axes(
            self.max_batch, num_blocks, self.block_size,
            self.max_blocks_per_seq)
        c_sh = shd.build_shardings(mesh, self.cache, cache_axes, rules)
        self.cache = jax.device_put(self.cache, c_sh)
        rep = NamedSharding(mesh, P())
        dax = "data" if self._shard_batch else None
        row_sh = NamedSharding(mesh, P(dax, None))
        vec_sh = NamedSharding(mesh, P(dax))
        self._row_sh = row_sh
        self._attn_scope = functools.partial(
            attn.paged_shard_scope, mesh, tp=self._tp,
            shard_batch=self._shard_batch)
        # logits come back replicated: the sync engine samples on the
        # host every tick, so any vocab sharding would be gathered anyway
        self._decode = jax.jit(
            model.decode_step,
            in_shardings=(p_sh, row_sh, c_sh, vec_sh),
            out_shardings=(rep, c_sh))
        # fused decode+sample: keys/temperature/top_k ride the batch rows
        # exactly like tokens/pos; the sampled id vector (a few bytes)
        # comes back replicated — it IS the host boundary now
        self._decode_sample = jax.jit(
            model.decode_and_sample,
            in_shardings=(p_sh, row_sh, c_sh, vec_sh, row_sh, vec_sh,
                          vec_sh),
            out_shardings=(rep, c_sh))
        self._prefill_chunk = jax.jit(
            model.prefill_chunk,
            in_shardings=(p_sh, {"tokens": rep}, c_sh, rep, rep),
            out_shardings=(rep, c_sh))

    # ------------------------------------------------------------------
    def attach_tracer(self, tracer) -> None:
        """Attach (or detach with ``None``) an ``obs.Tracer``.  Also
        makes it the module-level *active* tracer so kernel-config
        resolutions in ``tune.dispatch`` — which cannot be handed an
        instance — record into the same ring."""
        self.trace = tracer if tracer is not None else obs_trace.NULL
        obs_trace.set_active(tracer)
        if hasattr(self, "sched"):
            self.sched.trace = self.trace

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.metrics.on_submit(req.uid)
        self.trace.instant("submit", track=req_track(req.uid), cat="request",
                           uid=req.uid, prompt_len=len(req.prompt),
                           max_new=req.max_new_tokens)
        self.sched.submit(req)

    def _sync_tables(self) -> None:
        self.tables.fill(-1)
        for seq in self.sched.running:
            self.tables[seq.row, :len(seq.table)] = seq.table

    def _finalize_detached(self, req: Request) -> None:
        """Complete/fail a request whose blocks and row are already
        released (normal retire, async retire-at-dispatch, or a
        cancelled waiting request)."""
        req.done = True
        self.finished.append(req)
        self._key_cache.pop(req.uid, None)
        if req.error:                     # e.g. "oom": truncated output
            self.metrics.on_fail(req.uid, req.error)
            self.trace.instant("fail", track=req_track(req.uid),
                               cat="request", uid=req.uid,
                               error=req.error)
        else:
            self.metrics.on_complete(req.uid)
            self.trace.instant("complete", track=req_track(req.uid),
                               cat="request", uid=req.uid,
                               tokens=len(req.out_tokens))

    def _retire(self, seq) -> None:
        self.sched.finish(seq)
        self._finalize_detached(seq.req)

    def _fail_detached(self, req: Request, error: str) -> None:
        req.error = req.error or error
        self._finalize_detached(req)

    # ------------------------------------------------------------------
    def cancel(self, req: Request, error: str = "cancelled") -> bool:
        """Cancel a request wherever it currently lives — waiting queue,
        running (frees its pool blocks and batch row; prefix-cache
        references survive by design, the cache holds its own refs), or
        sampled-but-unsynced in the async in-flight step (its token is
        dropped at emission).  Returns False if it already finished."""
        if req.done:
            return False
        if req in self.sched.waiting:
            self.sched.waiting.remove(req)
            self._fail_detached(req, error)
            return True
        for seq in self.sched.running:
            if seq.req is req:
                req.error = error
                self._retire(seq)
                return True
        # neither waiting nor running nor done: an async retiring seq
        # whose final tokens are still in flight — blocks/row are
        # already free, so only the bookkeeping remains
        self._fail_detached(req, error)
        return True

    def _check_deadlines(self) -> None:
        """Expire requests whose deadline passed, waiting or running.
        Runs at the top of every tick (both modes) on the engine clock;
        an expired running request frees its blocks immediately."""
        now = self.clock()
        expired_w = [r for r in self.sched.waiting
                     if r.deadline_s is not None and now >= r.deadline_s]
        for req in expired_w:
            self.sched.waiting.remove(req)
            self.trace.instant("deadline", track=req_track(req.uid),
                               cat="request", uid=req.uid)
            self._fail_detached(req, "deadline")
        for seq in [s for s in self.sched.running
                    if s.req.deadline_s is not None
                    and now >= s.req.deadline_s]:
            seq.req.error = "deadline"
            self.trace.instant("deadline", track=req_track(seq.uid),
                               cat="request", uid=seq.uid)
            self._retire(seq)

    # ------------------------------------------------------------------
    def _decode_kv_bytes(self, decode) -> tuple:
        """Analytic per-step KV traffic of both decode paths (bytes).

        fused: every *live* pool block is read exactly once per layer
        (the kernel DMAs blocks through the block table).
        gathered: ``paged_view`` reads B x pages pool blocks (unallocated
        entries still fetch the trash block), writes the contiguous view,
        and ``decode_attend`` reads it back — 3 view-sized copies per
        layer regardless of how few blocks are actually live.  A traffic
        model, not a measurement; benchmarks report it per token."""
        per_layer = self.block_size * self._kv_entry_bytes
        live = sum(len(seq.table) for seq in decode)
        layers = self.model.cfg.n_layers
        fused = live * per_layer * layers
        gathered = 3 * self.max_batch * self.max_blocks_per_seq \
            * per_layer * layers
        return fused, gathered

    def _prefill_kv_bytes(self, seq) -> tuple:
        """Analytic per-chunk KV traffic of both prefill paths (bytes).

        fused: the chunked-prefill flash kernel streams the sequence's
        own table-mapped blocks once per layer (int8 scale rows ride the
        same DMA and are part of ``_kv_entry_bytes``).
        gathered: the 1-row ``paged_view`` reads the row's full
        ``max_blocks_per_seq`` capacity, writes the contiguous view and
        ``blockwise_attention`` reads it back — 3 view-sized copies per
        layer.  Same traffic model as ``_decode_kv_bytes``."""
        per_layer = self.block_size * self._kv_entry_bytes
        layers = self.model.cfg.n_layers
        fused = len(seq.table) * per_layer * layers
        gathered = 3 * self.max_blocks_per_seq * per_layer * layers
        return fused, gathered

    def _request_key(self, req: Request, index: int):
        """Memoized :func:`request_key` (the base key is two fold-ins
        that would otherwise re-run per token on the host hot path)."""
        base = self._key_cache.get(req.uid)
        if base is None:
            if req.seed is not None:
                base = jax.random.PRNGKey(req.seed)
            else:
                base = jax.random.fold_in(
                    jax.random.PRNGKey(self.rng_seed), req.uid)
            self._key_cache[req.uid] = base
        return jax.random.fold_in(base, index)

    def _emit_token(self, seq, tok: int) -> None:
        _emit(seq.req, tok)
        self.metrics.on_token(seq.req.uid)
        self.trace.instant(
            "first_token" if len(seq.req.out_tokens) == 1 else "token",
            track=req_track(seq.req.uid), cat="request", uid=seq.req.uid,
            pos=seq.kv_len)
        if seq.req.error == "callback":
            # the raising consumer poisoned only itself: retire this
            # request failed and keep every other stream ticking
            self._retire(seq)
            return
        # retire at the TOKEN bound, not the block-rounded capacity:
        # when max_seq_len is not a multiple of block_size the last
        # block has slack that must never be decoded into (positions
        # >= max_seq_len overrun learned-position tables)
        if len(seq.req.out_tokens) >= seq.req.max_new_tokens \
                or seq.kv_len + 1 >= self.max_seq_len:
            self._retire(seq)

    # ------------------------------------------------------------------
    def _plan_and_apply(self):
        """Shared tick head: deadline sweep, scheduler plan, plan-event
        metrics/tracing, table sync, prefix write-safety asserts."""
        self._check_deadlines()
        with self.trace.span("admission", track="engine/admission"):
            plan = self.sched.plan_tick()
        # metrics identity: a sequence preempted in the same tick it was
        # admitted must appear in NEITHER list (the scheduler drops such
        # net no-op victims from plan.admitted) — otherwise on_admit /
        # on_preempt would fire for a seq that never held KV
        assert {s.uid for s in plan.admitted}.isdisjoint(
            {s.uid for s in plan.preempted}), \
            "scheduler emitted admit+preempt for one seq in one tick"
        for req in plan.rejected:
            self.metrics.on_reject(req.uid)
            self.trace.instant("reject", track=req_track(req.uid),
                               cat="request", uid=req.uid, error=req.error)
            self.finished.append(req)
        for seq in plan.admitted:
            self.metrics.on_admit(seq.req.uid)
            self.trace.instant("admit", track=req_track(seq.req.uid),
                               cat="request", uid=seq.req.uid, row=seq.row,
                               prefill_target=seq.prefill_target,
                               prefix_hit_blocks=seq.prefix_hit,
                               free_blocks=self.pool.free_blocks)
            if self.prefix is not None:
                self.metrics.on_prefix_lookup(
                    seq.req.uid, seq.prefix_queried, seq.prefix_hit,
                    seq.shared_tokens, seq.cow_tokens)
        for seq in plan.preempted:
            self.metrics.on_preempt(seq.req.uid)
            self.trace.instant("preempted", track=req_track(seq.req.uid),
                               cat="request", uid=seq.req.uid)
        for seq in plan.failed:          # pool too dry even after preemption
            self._retire(seq)
        self._sync_tables()

        if self.prefix is not None:
            # immutability contract: every block this tick writes must be
            # privately owned by the writing sequence (shared prefix
            # blocks are read-only; CoW means they were never adopted)
            for seq in plan.decode:
                blk = seq.table[seq.kv_len // self.block_size]
                assert self.pool.writable(blk, seq.uid), \
                    f"decode would write shared block {blk}"
            if plan.prefill is not None:
                pf = plan.prefill
                lo = pf.start // self.block_size
                hi = (pf.start + pf.length - 1) // self.block_size
                for blk in pf.seq.table[lo:hi + 1]:
                    assert self.pool.writable(blk, pf.seq.uid), \
                        f"prefill would write shared block {blk}"
        return plan

    def _masked_tables(self, decode) -> np.ndarray:
        tables = self.tables.copy()
        rows = {seq.row for seq in decode}
        for r in range(self.max_batch):
            if r not in rows:
                tables[r] = -1       # idle rows write to the trash block
        return tables

    def _tick_metrics(self) -> None:
        self.ticks += 1
        if self.prefix is not None:
            self.metrics.on_tick(
                self.pool.occupancy(), self.sched.active,
                logical_blocks=sum(len(s.table)
                                   for s in self.sched.running),
                physical_blocks=self.pool.used_blocks,
                prefix_cached=len(self.prefix),
                prefix_evictions=self.prefix.evictions)
        else:
            self.metrics.on_tick(self.pool.occupancy(), self.sched.active)

    # ------------------------------------------------------------------
    # synchronous tick
    # ------------------------------------------------------------------
    def step(self) -> None:
        """One synchronous tick: plan (admit / top-up / preempt), then
        run one decode batch and at most one prefill chunk, blocking on
        the device and sampling on the host.  Any async in-flight step
        is flushed first, so the two modes can interleave safely."""
        self.flush()
        self.trace.tick = self.ticks
        with self.trace.span("tick", track="engine/tick",
                             free_blocks=self.pool.free_blocks,
                             running=len(self.sched.running),
                             waiting=len(self.sched.waiting)):
            self._step_traced()

    def _step_traced(self) -> None:
        plan = self._plan_and_apply()

        if plan.decode:
            tables = self._masked_tables(plan.decode)
            tokens = np.zeros((self.max_batch, 1), np.int32)
            posv = np.zeros(self.max_batch, np.int32)
            for seq in plan.decode:
                # during decode len(tokens) == kv_len + 1, so the pending
                # input is always the last sampled token (seq.tokens would
                # rebuild the whole prompt+output list every tick)
                tokens[seq.row, 0] = seq.req.out_tokens[-1]
                posv[seq.row] = seq.kv_len
            cache = set_block_tables(self.cache, tables)
            t_disp = self.clock()
            with self.trace.span("decode_dispatch", track="engine/decode",
                                 rows=len(plan.decode),
                                 path=self.decode_path,
                                 uids=[s.uid for s in plan.decode]):
                with self._attn_scope():
                    logits, self.cache = self._decode(
                        self.params, jnp.asarray(tokens), cache,
                        jnp.asarray(posv))
            # the host blocks HERE, not at dispatch: np.asarray forces
            # the device computation (step_async hides exactly this span
            # behind the next tick's planning and dispatch)
            with self.trace.span("device_sync", track="engine/sync",
                                 rows=len(plan.decode)):
                logits = np.asarray(logits)
            self.metrics.on_device_interval(t_disp, self.clock())
            fused_b, gathered_b = self._decode_kv_bytes(plan.decode)
            self.metrics.on_decode_step(len(plan.decode), fused_b,
                                        gathered_b, self.decode_path)
            with self.trace.span("sample", track="engine/sample",
                                 rows=len(plan.decode)):
                for seq in plan.decode:
                    seq.kv_len += 1
                    tok = _sample_host(seq.req, logits[seq.row],
                                       self.rng_seed)
                    self._emit_token(seq, tok)

        if plan.prefill is not None:
            logits, seq = self._dispatch_prefill(plan.prefill)
            if seq.kv_len >= seq.prefill_target:
                with self.trace.span("sample", track="engine/sample",
                                     rows=1):
                    tok = _sample_host(seq.req, np.asarray(logits)[0],
                                       self.rng_seed)
                    self._emit_token(seq, tok)

        self._tick_metrics()

    def _dispatch_prefill(self, pf):
        """Dispatch one prefill chunk (shared by both tick modes);
        advances ``kv_len`` and returns (device logits [1, V], seq)."""
        seq, start, clen = pf.seq, pf.start, pf.length
        bucket = self.sched.bucket(clen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :clen] = seq.tokens[start:start + clen]
        cache = set_block_tables(self.cache,
                                 self.tables[seq.row:seq.row + 1])
        with self.trace.span("prefill_chunk", track="engine/prefill",
                             uid=seq.uid, start=start, length=clen,
                             bucket=bucket):
            with self._attn_scope():
                logits, self.cache = self._prefill_chunk(
                    self.params, {"tokens": jnp.asarray(toks)}, cache,
                    jnp.int32(start), jnp.int32(clen - 1))
        self.trace.instant("prefill_chunk", track=req_track(seq.uid),
                           cat="request", uid=seq.uid, start=start,
                           length=clen)
        fused_b, gathered_b = self._prefill_kv_bytes(seq)
        self.metrics.on_prefill_chunk(clen, fused_b, gathered_b,
                                      self.prefill_path)
        seq.kv_len += clen
        return logits, seq

    # ------------------------------------------------------------------
    # double-buffered async tick
    # ------------------------------------------------------------------
    def step_async(self) -> None:
        """One double-buffered tick: plan against *projected* occupancy
        (``kv_len``/``inflight`` advance at dispatch), dispatch step N
        with sampling fused on-device, THEN sync and emit step N-1's
        token ids.  The host runs one step behind the device; dispatch
        order on the device is preserved by the cache data dependency
        (tick N's compute consumes tick N-1's cache output), which is
        what makes freeing blocks at dispatch safe — the device has, in
        program order, already read them."""
        self.trace.tick = self.ticks
        with self.trace.span("tick", track="engine/tick", mode="async",
                             free_blocks=self.pool.free_blocks,
                             running=len(self.sched.running),
                             waiting=len(self.sched.waiting),
                             inflight=self._inflight is not None):
            self._step_async_traced()

    def _step_async_traced(self) -> None:
        prev, self._inflight = self._inflight, None
        plan = self._plan_and_apply()
        cur_tokens = None            # device int32 [max_batch]
        emits: list = []

        if plan.decode:
            tables = self._masked_tables(plan.decode)
            tokens = np.zeros((self.max_batch, 1), np.int32)
            posv = np.zeros(self.max_batch, np.int32)
            keys = np.zeros((self.max_batch, 2), np.uint32)
            temps = np.zeros(self.max_batch, np.float32)
            topks = np.zeros(self.max_batch, np.int32)
            dev_rows = []
            for seq in plan.decode:
                posv[seq.row] = seq.kv_len
                if prev is not None and seq.uid in prev.row_of:
                    # input token is still on the device (sampled last
                    # tick, not yet emitted); rows are stable while a
                    # seq stays running, so gather from the same row
                    assert prev.row_of[seq.uid] == seq.row
                    dev_rows.append(seq.row)
                else:
                    tokens[seq.row, 0] = seq.req.out_tokens[-1]
                if seq.req.temperature > 0:
                    idx = len(seq.req.out_tokens) + seq.inflight
                    keys[seq.row] = np.asarray(
                        self._request_key(seq.req, idx))
                temps[seq.row] = seq.req.temperature
                topks[seq.row] = seq.req.top_k
            inp = jnp.asarray(tokens)
            if dev_rows:
                r = np.asarray(dev_rows)
                inp = inp.at[r, 0].set(prev.tokens[r])
                if self._row_sh is not None:
                    # gathering from the replicated in-flight vector
                    # commits inp replicated; re-place to the declared
                    # per-row sharding before the pjit call
                    inp = jax.device_put(inp, self._row_sh)
            cache = set_block_tables(self.cache, tables)
            t_disp = self.clock()
            with self.trace.span("decode_dispatch", track="engine/decode",
                                 rows=len(plan.decode), mode="async",
                                 path=self.decode_path,
                                 uids=[s.uid for s in plan.decode]):
                with self._attn_scope():
                    cur_tokens, self.cache = self._decode_sample(
                        self.params, inp, cache, jnp.asarray(posv),
                        jnp.asarray(keys), jnp.asarray(temps),
                        jnp.asarray(topks))
            fused_b, gathered_b = self._decode_kv_bytes(plan.decode)
            self.metrics.on_decode_step(len(plan.decode), fused_b,
                                        gathered_b, self.decode_path)
            for seq in plan.decode:
                seq.kv_len += 1
                seq.inflight += 1
                emits.append((seq, seq.row))
                self._maybe_finish_async(seq)

        if plan.prefill is not None:
            logits, seq = self._dispatch_prefill(plan.prefill)
            if seq.kv_len >= seq.prefill_target:
                with self.trace.span("sample", track="engine/sample",
                                     rows=1, mode="async"):
                    idx = len(seq.req.out_tokens) + seq.inflight
                    key = (np.asarray(self._request_key(seq.req, idx))
                           if seq.req.temperature > 0
                           else np.zeros(2, np.uint32))
                    tok = self._sample_only(
                        logits,
                        jnp.asarray(key, jnp.uint32)[None],
                        jnp.full((1,), seq.req.temperature, jnp.float32),
                        jnp.full((1,), seq.req.top_k, jnp.int32))[0]
                if cur_tokens is None:
                    cur_tokens = jnp.zeros(self.max_batch, jnp.int32)
                cur_tokens = cur_tokens.at[seq.row].set(tok)
                seq.inflight += 1
                emits.append((seq, seq.row))
                self._maybe_finish_async(seq)

        # sync (and emit) the PREVIOUS tick only after this tick's work
        # is in the device queue — that ordering is the whole overlap
        self._sync_prev(prev)
        if emits:
            t_disp = t_disp if plan.decode else self.clock()
            self._inflight = _InFlight(
                tokens=cur_tokens, emits=emits,
                row_of={s.uid: row for s, row in emits},
                t_dispatch=t_disp, tick=self.ticks)
        self._tick_metrics()

    def _maybe_finish_async(self, seq) -> None:
        """Retire-at-dispatch: when the just-dispatched token is the
        request's last (by count — the retire decision never needs the
        token's value), release the row and blocks NOW so next tick's
        admission sees them; ``done``/completion metrics wait for the
        final emission (streaming order is preserved)."""
        if len(seq.req.out_tokens) + seq.inflight \
                >= seq.req.max_new_tokens \
                or seq.kv_len + 1 >= self.max_seq_len:
            seq.retiring = True
            self.sched.finish(seq)

    def _sync_prev(self, prev: Optional[_InFlight]) -> None:
        """Block on the previous async step and emit its tokens."""
        if prev is None:
            return
        with self.trace.span("device_sync", track="engine/sync",
                             rows=len(prev.emits), sync_tick=prev.tick):
            toks = np.asarray(prev.tokens)
        self.metrics.on_device_interval(prev.t_dispatch, self.clock())
        with self.trace.span("emit", track="engine/sample",
                             rows=len(prev.emits)):
            for seq, row in prev.emits:
                self._emit_async(seq, int(toks[row]))

    def _emit_async(self, seq, tok: int) -> None:
        """Emit one step-N-1 token for ``seq``, which by now may be
        running, retiring (finished at dispatch), or preempted (its
        request re-queued; the token still belongs to the stream and
        re-admission folds it into the recompute prefix).  A request
        cancelled/expired while its token was in flight drops it."""
        seq.inflight -= 1
        req = seq.req
        if req.done:
            return
        _emit(req, tok)
        self.metrics.on_token(req.uid)
        self.trace.instant(
            "first_token" if len(req.out_tokens) == 1 else "token",
            track=req_track(req.uid), cat="request", uid=req.uid,
            pos=seq.kv_len)
        if req.error == "callback":
            if seq in self.sched.running:
                self._retire(seq)
            elif req in self.sched.waiting:      # preempted victim
                self.sched.waiting.remove(req)
                self._fail_detached(req, "callback")
            else:                                # retiring: already freed
                self._fail_detached(req, "callback")
            return
        if seq.retiring and seq.inflight == 0:
            # the count-based retire decision was taken at dispatch;
            # a preempted seq can never complete here (its final token
            # would have flipped it to retiring instead)
            self._finalize_detached(req)

    def flush(self) -> None:
        """Sync and emit any in-flight async step without dispatching
        new work (drain point for the frontend and for mode mixing)."""
        prev, self._inflight = self._inflight, None
        self._sync_prev(prev)

    @property
    def has_inflight(self) -> bool:
        return self._inflight is not None

    # ------------------------------------------------------------------
    def _drain_tick_budget(self) -> None:
        """Tick budget exhausted: drain waiting/running requests as
        errored so callers polling ``req.done`` never hang, and so the
        pool's books balance (running seqs free their blocks)."""
        for seq in list(self.sched.running):
            seq.req.error = "tick_budget"
            self._retire(seq)
        while self.sched.waiting:
            req = self.sched.waiting.popleft()
            self._fail_detached(req, "tick_budget")

    def run(self, requests: list, max_ticks: int = 1000) -> list:
        for req in requests:
            self.submit(req)
        while self.sched.has_work() and self.ticks < max_ticks:
            self.step()
        if self.sched.has_work():
            self._drain_tick_budget()
        return self.finished

    def run_async(self, requests: list, max_ticks: int = 1000) -> list:
        """Drain a batch through the double-buffered tick (the asyncio
        frontend drives ``step_async`` itself; this mirrors :meth:`run`
        for benches and equivalence tests)."""
        for req in requests:
            self.submit(req)
        while (self.sched.has_work() or self._inflight is not None) \
                and self.ticks < max_ticks:
            self.step_async()
        self.flush()
        if self.sched.has_work():
            self._drain_tick_budget()
        return self.finished


# ---------------------------------------------------------------------------
# contiguous fixed-slot engine (reference / fallback)
# ---------------------------------------------------------------------------


class ServeEngine:
    """Continuous batching over a fixed slot grid (one full ``cache_len``
    row per request).  The unit of work is a *slot*; one jit'd
    ``decode_step`` advances every active slot each tick.  Prefill runs
    per-request through a throwaway 1-row cache spliced into the grid;
    left-pads get negative positions, so the attention pos-mask makes
    padded prompts score exactly like unpadded ones in attention layers.
    (SSM layers have no position mask — pad embeddings still enter the
    conv/SSD state there, a documented residual simplification for the
    SSM/hybrid models this engine remains the fallback for.)"""

    def __init__(self, model: Model, params, *, slots: int = 8,
                 cache_len: int = 512, prefill_buckets=(32, 128, 512),
                 rng_seed: int = 0, pretune: bool = False):
        self.model = model
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.buckets = sorted(prefill_buckets)
        if pretune:
            _pretune(model, params, [1, slots, *self.buckets])
        self.cache = model.init_cache(slots, cache_len)
        self.slot_req: list = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)
        self.rng_seed = rng_seed
        self.rng = np.random.default_rng(rng_seed)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self.ticks = 0

    # ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        top = self.buckets[-1]          # longer prompts: round up to the
        return -(-n // top) * top       # top bucket (bounded trace count)

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def add_request(self, req: Request) -> bool:
        """Prefill into a free slot; False if engine is full."""
        free = self._free_slots()
        if not free:
            return False
        plen = len(req.prompt)
        if plen == 0:
            req.error = "empty_prompt"
            req.done = True
            return True
        if plen >= self.cache_len - 1:       # can't hold prompt + 1 decode
            req.error = "too_long"           # reject, don't silently truncate
            req.done = True
            return True
        slot = free[0]
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, -plen:] = req.prompt          # left-pad into the bucket
        # run prefill on a single-row cache then splice into the big cache;
        # start_pos < 0 gives the pads negative positions -> masked out of
        # attention and dead on insert (real tokens sit at 0..plen-1)
        small = self.model.init_cache(1, self.cache_len)
        logits, small = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, small,
            jnp.int32(plen - bucket))
        self.cache = _splice_cache(self.cache, small, slot)
        first = _sample_host(req, np.asarray(logits)[0], self.rng_seed)
        _emit(req, first)
        if req.error == "callback" \
                or len(req.out_tokens) >= req.max_new_tokens:
            req.done = True                   # done (or its consumer broke):
            return True                       # slot stays free
        self.slot_req[slot] = req
        self.slot_pos[slot] = plen
        return True

    # ------------------------------------------------------------------
    def tick(self) -> list:
        """One decode step for every active slot; returns requests that
        retired this tick."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        tokens = np.zeros((self.slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slot_req[i].out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(self.slot_pos))
        logits = np.asarray(logits)
        retired = []
        for i in active:
            req = self.slot_req[i]
            tok = _sample_host(req, logits[i], self.rng_seed)
            _emit(req, tok)
            self.slot_pos[i] += 1
            if req.error == "callback" \
                    or len(req.out_tokens) >= req.max_new_tokens \
                    or self.slot_pos[i] >= self.cache_len - 1:
                req.done = True
                retired.append(req)
                self.slot_req[i] = None
        self.ticks += 1
        return retired

    def run(self, requests: list, max_ticks: int = 1000) -> list:
        """Continuous batching: admit when slots free, tick until done."""
        pending = deque(requests)
        done = []
        while (pending or any(r is not None for r in self.slot_req)) \
                and self.ticks < max_ticks:
            while pending and self._free_slots():
                req = pending[0]
                if not self.add_request(req):
                    break
                pending.popleft()
                if req.done:
                    done.append(req)
            done.extend(self.tick())
        return done


def _splice_cache(big, small, slot: int):
    """Copy a 1-row cache into row ``slot`` of the engine cache.

    Leaves under a "scan" group are stacked with a leading layers axis,
    so their batch dim is axis 1, not axis 0."""
    def fix(path, b, s):
        stacked = any(isinstance(k, jax.tree_util.DictKey) and k.key == "scan"
                      for k in path)
        if stacked:
            return b.at[:, slot:slot + 1].set(s.astype(b.dtype))
        return b.at[slot:slot + 1].set(s.astype(b.dtype))
    return jax.tree_util.tree_map_with_path(fix, big, small)
