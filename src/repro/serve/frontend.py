"""Asyncio request frontend over :class:`~repro.serve.engine.PagedServeEngine`.

The engine is a tick machine; this module gives it a serving surface:

  * :meth:`AsyncServeFrontend.submit` -> a :class:`StreamHandle` whose
    tokens arrive as an async iterator and whose completion is
    awaitable (``await handle.wait()``);
  * a **bounded admission queue** — when ``max_queue`` requests are
    already waiting, ``submit`` raises the typed :class:`QueueFullError`
    instead of queueing unboundedly (open-loop load must shed, not
    buffer);
  * **per-request deadlines** (``deadline_ms``) stamped as absolute
    times on the engine clock and enforced by the engine's tick-top
    deadline sweep, so an expired request frees its pool blocks whether
    it is still queued or mid-decode;
  * **cancellation** (``handle.cancel()``) with the same block-release
    guarantee; a token already sampled on-device for a cancelled
    request is dropped at emission.

One event loop, one thread: the frontend never races the engine — ticks
run inline in :meth:`serve_forever` (or :meth:`drain`), and control
returns to the loop between ticks (``await asyncio.sleep(0)``) so
submitters, cancellers and stream consumers interleave with the engine
at tick granularity.  The engine itself stays asyncio-free: everything
awaitable lives here, everything tick-shaped lives in the engine, and
the double-buffered ``step_async`` hides the device sync behind the
next tick's planning either way.

No new dependencies: pure stdlib ``asyncio`` + the existing engine.
"""
from __future__ import annotations

import asyncio
from typing import Optional

import numpy as np

from repro.serve.engine import PagedServeEngine, Request


class QueueFullError(RuntimeError):
    """Admission queue at capacity: the submit was rejected, nothing was
    enqueued.  Carries ``limit`` so callers can report the bound."""

    def __init__(self, limit: int):
        super().__init__(f"admission queue full ({limit} waiting)")
        self.limit = limit


class FrontendClosedError(RuntimeError):
    """submit() after close()."""


_DONE = object()          # token-stream sentinel


class StreamHandle:
    """One submitted request: async-iterate it for tokens, ``await
    handle.wait()`` for the finished :class:`Request`.  The handle never
    raises on engine-side failure — inspect ``handle.error`` (e.g.
    ``"deadline"``, ``"cancelled"``, ``"oom"``) after completion."""

    def __init__(self, frontend: "AsyncServeFrontend", req: Request):
        self.request = req
        self._frontend = frontend
        self._queue: asyncio.Queue = asyncio.Queue()
        self._done = asyncio.Event()

    # -- engine-facing ---------------------------------------------------
    def _on_token(self, tok: int, req: Request) -> None:
        self._queue.put_nowait(int(tok))

    def _finish(self) -> None:
        if not self._done.is_set():
            self._done.set()
            self._queue.put_nowait(_DONE)

    # -- client-facing ---------------------------------------------------
    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def error(self) -> Optional[str]:
        return self.request.error

    @property
    def out_tokens(self) -> list:
        return self.request.out_tokens

    def __aiter__(self) -> "StreamHandle":
        return self

    async def __anext__(self) -> int:
        tok = await self._queue.get()
        if tok is _DONE:
            raise StopAsyncIteration
        return tok

    async def wait(self) -> Request:
        """Await completion (normal or errored); returns the request."""
        await self._done.wait()
        return self.request

    def cancel(self) -> bool:
        """Cancel this request (releases its pool blocks immediately).
        Returns False if it had already finished."""
        return self._frontend.cancel(self)


class AsyncServeFrontend:
    """The asyncio serving surface for one :class:`PagedServeEngine`.

    ``max_queue`` bounds the engine's waiting queue (admitted-and-running
    requests don't count — the pool already bounds those); ``idle_sleep``
    is how long :meth:`serve_forever` naps when there is no work.  All
    timing (deadlines, metrics) uses the ENGINE's injectable clock, so
    tests drive expiry with a fake clock and zero real sleeping."""

    def __init__(self, engine: PagedServeEngine, *, max_queue: int = 64,
                 idle_sleep: float = 0.001):
        self.engine = engine
        self.max_queue = max_queue
        self.idle_sleep = idle_sleep
        self._handles: dict = {}            # uid -> live StreamHandle
        self._next_uid = 0
        self._reaped = 0                    # engine.finished cursor
        self._closed = False

    # ------------------------------------------------------------------
    def submit_nowait(self, prompt, *, max_new_tokens: int = 32,
                      temperature: float = 0.0, top_k: int = 0,
                      seed: Optional[int] = None,
                      deadline_ms: Optional[float] = None,
                      uid: Optional[int] = None) -> StreamHandle:
        """Enqueue a request; raises :class:`QueueFullError` when the
        bounded admission queue is at capacity and
        :class:`FrontendClosedError` after :meth:`close`."""
        if self._closed:
            raise FrontendClosedError("frontend is closed")
        if len(self.engine.sched.waiting) >= self.max_queue:
            raise QueueFullError(self.max_queue)
        if uid is None:
            uid = self._next_uid
        self._next_uid = max(self._next_uid, uid) + 1
        req = Request(uid=uid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens,
                      temperature=temperature, top_k=top_k, seed=seed)
        handle = StreamHandle(self, req)
        req.on_token = handle._on_token
        if deadline_ms is not None:
            req.deadline_s = self.engine.clock() + deadline_ms / 1e3
        self.engine.submit(req)
        self._handles[uid] = handle
        return handle

    async def submit(self, prompt, **kw) -> StreamHandle:
        """Async-flavored :meth:`submit_nowait` (same typed errors); the
        awaitable shape lets callers treat admission as a suspension
        point even though enqueueing itself never blocks."""
        handle = self.submit_nowait(prompt, **kw)
        await asyncio.sleep(0)
        return handle

    def cancel(self, handle: StreamHandle) -> bool:
        ok = self.engine.cancel(handle.request, "cancelled")
        self._reap()
        return ok

    # ------------------------------------------------------------------
    def _reap(self) -> None:
        """Finalize handles for everything the engine retired since the
        last sweep (``engine.finished`` is append-only)."""
        fin = self.engine.finished
        while self._reaped < len(fin):
            req = fin[self._reaped]
            self._reaped += 1
            h = self._handles.pop(req.uid, None)
            if h is not None:
                h._finish()

    def _has_work(self) -> bool:
        return self.engine.sched.has_work() or self.engine.has_inflight

    def step(self) -> None:
        """One engine tick + handle reaping (exposed for tests that want
        tick-exact control; the async entry points call this)."""
        if self._has_work():
            self.engine.step_async()
        self._reap()

    async def drain(self, max_ticks: int = 100000) -> None:
        """Tick until every submitted request has finished, yielding to
        the event loop between ticks."""
        ticks = 0
        while self._has_work() and ticks < max_ticks:
            self.step()
            ticks += 1
            await asyncio.sleep(0)
        self._reap()

    async def serve_forever(self) -> None:
        """Engine loop: tick while there is work, nap when idle, exit on
        :meth:`close`.  Run as a task next to the submitting coroutines:

            loop = asyncio.create_task(frontend.serve_forever())
            h = await frontend.submit(prompt)
            async for tok in h: ...
            frontend.close(); await loop
        """
        while not self._closed:
            if self._has_work():
                self.step()
                await asyncio.sleep(0)
            else:
                await asyncio.sleep(self.idle_sleep)

    def close(self) -> None:
        """Stop :meth:`serve_forever` and fail any still-live request
        with ``error="shutdown"`` so no awaiter hangs."""
        if self._closed:
            return
        self._closed = True
        self.engine.flush()
        for h in list(self._handles.values()):
            if not h.request.done:
                self.engine.cancel(h.request, "shutdown")
        self._reap()
        # anything the engine never saw finish (defensive): unblock it
        for h in list(self._handles.values()):
            h._finish()
        self._handles.clear()
