"""Serving scheduler: FCFS admission by free-block budget, prefix-cache
hits mapped onto live blocks at admission, chunked prefill over the
length buckets, decode/prefill interleaving, and preempt-by-recompute
when the block pool runs dry.

Pure host-side bookkeeping over a :class:`~repro.serve.paging.BlockPool`
(plus an optional :class:`~repro.serve.paging.PrefixCache`) — no JAX, no
model — so every policy is unit-testable without running a model.  The
engine executes one :class:`TickPlan` per tick:

  1. register newly completed full prompt blocks in the prefix index
     (their KV is final and immutable from here on);
  2. admit waiting requests FCFS while a batch row is free and the pool
     can cover the prompt plus a decode-headroom reserve.  With a
     prefix cache, the request's prompt is first probed against the
     index: hit blocks are adopted by reference (``BlockPool.share``)
     and their prefill is SKIPPED — the admission budget counts only
     the NEW blocks the request needs, so a mostly-cache-resident
     request is never deferred for blocks it will not allocate.
     Requests that could never fit are rejected outright, not queued
     forever;
  3. top up decode blocks for every fully-prefilled sequence (one new
     block each time its length crosses a block boundary), evicting
     cache-only blocks and then preempting the youngest running
     sequence when the pool is dry;
  4. pick one prefill chunk (bucket-sized, FCFS) and allocate its blocks.

Ownership / refcount / immutability invariants the policies maintain
(see also ``serve/paging.py`` and ``tests/test_property_paging.py``):

  * a sequence's writes — decode appends at ``kv_len``, prefill chunks
    over ``[kv_len, kv_len + length)`` — always land in blocks whose
    SOLE holder is that sequence.  Shared (refcount > 1) blocks are
    immutable: only fully-written prompt blocks are ever registered or
    adopted, and adoption stops at least one token short of the prompt
    end so the partially-filled tail block is always private
    (copy-on-write by recompute);
  * ``finish`` and preemption release by decref: a shared block
    survives until its last holder (sequence or cache) lets go, so
    refcounts never go negative and no sequence ever loses a block it
    still references;
  * preempt-by-recompute victims re-enter the waiting queue and
    RE-PROBE the index on re-admission, so their own registered blocks
    (kept alive by the cache's reference) make the recompute cheap.

Preemption is by *recompute*: the victim's holds are released and the
request re-enters the waiting queue with its generated tokens folded
into the prompt, so re-admission prefills the whole (uncached) prefix
and greedy decoding continues token-for-token where it left off.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

from repro.obs import trace as obs_trace
from repro.serve.paging import BlockPool, PrefixCache


@dataclasses.dataclass
class SeqState:
    """A request occupying a batch row, with its block table.

    ``kv_len`` counts tokens whose KV is cached.  During prefill
    ``kv_len < prefill_target``; during decode ``len(tokens) ==
    kv_len + 1`` (the last sampled token is the pending model input).
    A prefix-cache hit starts the sequence at ``kv_len ==
    shared_tokens`` with the adopted blocks already in ``table`` —
    those leading blocks are shared and must never be written.

    Under the async engine ``kv_len`` is *projected*: it advances at
    dispatch, one tick before the host sees the sampled token, and
    ``inflight`` counts tokens sampled on-device but not yet emitted.
    The scheduler itself needs no async awareness — planning against
    projected state is exactly planning one tick ahead.  ``retiring``
    marks a sequence whose blocks and row were already released at
    dispatch (count-based retire) while its last tokens are still in
    flight; completion bookkeeping happens at emission.
    """
    req: object                        # serve.engine.Request
    row: int
    admit_seq: int
    prefill_target: int
    kv_len: int = 0
    table: List[int] = dataclasses.field(default_factory=list)
    inflight: int = 0                  # sampled on device, not yet emitted
    retiring: bool = False             # freed at dispatch, awaiting emission
    # --- prefix-cache bookkeeping (all zero when the cache is off) ----
    shared_tokens: int = 0             # tokens adopted from the index
    prefix_queried: int = 0            # full prompt blocks probed
    prefix_hit: int = 0                # blocks adopted (== blocks saved)
    cow_tokens: int = 0                # cached tokens recomputed (CoW)
    reg_key: Optional[int] = None      # chain key of last registered block
    reg_blocks: int = 0                # full blocks registered/adopted
    reg_stopped: bool = False          # hash-collision guard tripped

    @property
    def uid(self):
        return self.req.uid

    @property
    def tokens(self) -> list:
        return list(self.req.prompt) + self.req.out_tokens


@dataclasses.dataclass
class PrefillChunk:
    seq: SeqState
    start: int                         # absolute position of first token
    length: int                        # real tokens in the chunk


@dataclasses.dataclass
class TickPlan:
    admitted: List[SeqState] = dataclasses.field(default_factory=list)
    decode: List[SeqState] = dataclasses.field(default_factory=list)
    prefill: Optional[PrefillChunk] = None
    preempted: List[SeqState] = dataclasses.field(default_factory=list)
    rejected: List[object] = dataclasses.field(default_factory=list)
    failed: List[SeqState] = dataclasses.field(default_factory=list)


class Scheduler:
    def __init__(self, pool: BlockPool, rows: int, buckets,
                 max_blocks_per_seq: int, decode_reserve: int = 1,
                 max_seq_len: int = 0,
                 prefix_cache: Optional[PrefixCache] = None,
                 tracer=None):
        self.pool = pool
        self.prefix = prefix_cache
        # scheduling-decision trace hooks (prefix probes, evictions,
        # preemptions); a NullTracer when observability is off
        self.trace = tracer if tracer is not None else obs_trace.NULL
        self.buckets = sorted(buckets)
        self.max_blocks_per_seq = max_blocks_per_seq
        # the TOKEN bound, which is tighter than the block bound whenever
        # max_seq_len is not a multiple of block_size: admission must
        # compare against it or a sequence legally decodes up to
        # block_size-1 tokens past max_seq_len inside its last block
        # (overrunning learned-position tables)
        self.max_seq_len = max_seq_len or max_blocks_per_seq * pool.block_size
        self.decode_reserve = decode_reserve
        self.waiting: deque = deque()
        self.running: List[SeqState] = []
        self._free_rows = list(range(rows - 1, -1, -1))   # pop() -> row 0 first
        self._admit_counter = 0

    # ------------------------------------------------------------------
    def submit(self, req) -> None:
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def active(self) -> int:
        return len(self.running)

    def bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    # ------------------------------------------------------------------
    def _available(self) -> int:
        """Blocks an allocation could obtain right now: the free list
        plus cache-only blocks the prefix index would evict on demand.
        Budget checks must use this, or a warm cache (which deliberately
        keeps the pool occupied) would starve admission."""
        extra = self.prefix.evictable() if self.prefix is not None else 0
        return self.pool.free_blocks + extra

    def _alloc(self, owner, n: int) -> Optional[List[int]]:
        """Allocate ``n`` blocks, evicting cache-only prefix blocks
        first when the free list alone cannot cover the request."""
        if self.prefix is not None and n > self.pool.free_blocks:
            want = n - self.pool.free_blocks
            before = self.pool.free_blocks
            self.prefix.evict(want)
            self.trace.instant("prefix_evict", track="engine/evict",
                               cat="scheduler", owner=owner, want=want,
                               freed=self.pool.free_blocks - before)
        return self.pool.alloc(owner, n)

    # ------------------------------------------------------------------
    def finish(self, seq: SeqState) -> None:
        """Retire a sequence: release its block holds (shared blocks
        survive in the prefix cache) and free its batch row."""
        self.pool.free(seq.table, seq.uid)
        seq.table = []
        self.running.remove(seq)
        self._free_rows.append(seq.row)

    def _preempt(self, seq: SeqState) -> None:
        """Preempt-by-recompute: decref every held block (NOT a hard
        free — blocks shared with the cache or other sequences live
        on), requeue at the front (victims are popped youngest-first,
        so repeated appendleft keeps the waiting queue in original
        arrival order).  Re-admission re-probes the prefix index, so
        the victim's own registered blocks make the recompute cheap."""
        self.pool.free(seq.table, seq.uid)
        seq.table = []
        seq.kv_len = 0
        self.running.remove(seq)
        self._free_rows.append(seq.row)
        self.waiting.appendleft(seq.req)

    def _youngest(self, than: Optional[SeqState] = None) -> Optional[SeqState]:
        """Latest-admitted running sequence (optionally strictly younger
        than ``than``) — the preemption victim, vLLM-style."""
        cands = self.running
        if than is not None:
            cands = [s for s in cands if s.admit_seq > than.admit_seq]
        return max(cands, key=lambda s: s.admit_seq) if cands else None

    def _record_preempt(self, plan: TickPlan, victim: SeqState) -> None:
        """Preempt ``victim`` and keep the plan's event lists consistent.

        A victim admitted THIS tick is a net no-op (it never held KV or
        ran a step): it is dropped from ``plan.admitted`` instead of
        appearing in both lists, so the engine's admit/preempt metrics
        see it exactly zero times — the invariant the engine asserts.
        """
        self.trace.instant("preempt", track="engine/preempt",
                           cat="scheduler", uid=victim.uid,
                           kv_len=victim.kv_len,
                           blocks_held=len(victim.table),
                           same_tick=victim in plan.admitted)
        self._preempt(victim)
        if victim in plan.admitted:
            plan.admitted.remove(victim)
        else:
            plan.preempted.append(victim)
        if victim in plan.decode:
            plan.decode.remove(victim)

    # ------------------------------------------------------------------
    def plan_tick(self) -> TickPlan:
        plan = TickPlan()
        self._register_prefixes()
        self._admit(plan)
        self._plan_decode(plan)
        self._plan_prefill(plan)
        return plan

    def _register_prefixes(self) -> None:
        """Index every newly completed full prompt block.  A block is
        registered only once ``(j + 1) * block_size <= min(kv_len,
        prefill_target)`` — its contents are final (prefill only moves
        forward, decode writes land past ``prefill_target``), so the
        immutability contract holds the moment it becomes adoptable."""
        if self.prefix is None:
            return
        bs = self.pool.block_size
        for seq in self.running:
            full = min(seq.kv_len, seq.prefill_target) // bs
            if seq.reg_stopped or seq.reg_blocks >= full:
                continue
            toks = seq.tokens
            while seq.reg_blocks < full:
                j = seq.reg_blocks
                chunk = tuple(int(t) for t in toks[j * bs:(j + 1) * bs])
                key = self.prefix.register(seq.reg_key, chunk, seq.table[j])
                if key is None:          # hash collision: stop this chain
                    seq.reg_stopped = True
                    break
                seq.reg_key = key
                seq.reg_blocks += 1

    def _admit(self, plan: TickPlan) -> None:
        """FCFS: stop at the first request the budget can't cover (no
        skip-ahead — later, shorter requests must not starve the head)."""
        reserved = 0     # blocks promised to seqs admitted THIS tick
                         # (allocation happens later, at prefill/decode)
        bs = self.pool.block_size
        while self.waiting and self._free_rows:
            req = self.waiting[0]
            if len(req.prompt) == 0:
                self.waiting.popleft()
                req.error = "empty_prompt"
                req.done = True
                plan.rejected.append(req)
                continue
            # final KV footprint: generation stops at max_new_tokens, so
            # tokens already generated (preempt-recompute) don't add to it
            total = len(req.prompt) + req.max_new_tokens
            need_total = self.pool.blocks_for(total)
            if total > self.max_seq_len or \
                    need_total > min(self.pool.capacity,
                                     self.max_blocks_per_seq):
                self.waiting.popleft()
                req.error = "too_long"
                req.done = True
                plan.rejected.append(req)
                continue
            target = len(req.prompt) + len(req.out_tokens)
            # prefix probe: adopt the longest cached chain, capped one
            # token short of the prefill target — the model must still
            # compute the last prompt token's logits, and that keeps
            # the partially-filled tail block private (CoW-by-recompute:
            # shared blocks are never written)
            hits, last_key, cow = [], None, 0
            cap = (target - 1) // bs
            if self.prefix is not None and cap > 0:
                t0 = self.trace.now_us()
                toks = list(req.prompt) + req.out_tokens
                hits, last_key = self.prefix.lookup(toks, cap)
                tail = toks[len(hits) * bs:
                            min((len(hits) + 1) * bs, target)]
                cow = self.prefix.cached_overlap(last_key, tail)
                # emitted as a closed span so the probe's cost AND its
                # outcome (hit/cow counts) land in one trace event
                self.trace.emit("prefix_lookup", "X", t0, "engine/prefix",
                                "scheduler", dur=self.trace.now_us() - t0,
                                args=dict(uid=req.uid, queried_blocks=cap,
                                          hit_blocks=len(hits),
                                          cow_tokens=cow))
            # decode headroom, capped by the sequence's FINAL footprint:
            # a prompt that fills its last block only partially decodes
            # into that block, so demanding an extra reserve block it
            # will never use can wedge admission forever when the final
            # footprint equals pool capacity (found by the fuzz suite).
            # Hit blocks are adopted by reference, never allocated, so
            # the budget counts only the NEW blocks this request needs
            # — a mostly-cache-resident request must not be deferred
            # for blocks it already has.
            need_now = min(self.pool.blocks_for(target) + self.decode_reserve,
                           need_total) - len(hits)
            if self._available() - reserved < max(need_now, 0):
                break
            reserved += max(need_now, 0)
            self.waiting.popleft()
            seq = SeqState(req=req, row=self._free_rows.pop(),
                           admit_seq=self._admit_counter,
                           prefill_target=target,
                           kv_len=len(hits) * bs, table=list(hits),
                           shared_tokens=len(hits) * bs,
                           prefix_queried=cap, prefix_hit=len(hits),
                           cow_tokens=cow,
                           reg_key=last_key, reg_blocks=len(hits))
            if hits:
                self.pool.share(hits, req.uid)
            self._admit_counter += 1
            self.running.append(seq)
            plan.admitted.append(seq)

    def _plan_decode(self, plan: TickPlan) -> None:
        for seq in list(self.running):
            if seq not in self.running:        # preempted by an older seq
                continue
            if seq.kv_len < seq.prefill_target:
                continue
            # next write position is kv_len; top up its block if needed
            needed = self.pool.blocks_for(seq.kv_len + 1)
            skip = False
            while len(seq.table) < needed:
                blks = self._alloc(seq.uid, 1)
                if blks is not None:
                    seq.table.extend(blks)
                    continue
                # pool dry even after cache eviction: preempt the
                # youngest running sequence — which may be this one (an
                # older request's blocks are never stolen for a younger
                # decode)
                victim = self._youngest()
                if victim is seq and len(self.running) == 1:
                    # alone yet out of blocks: the request can never fit
                    # (admission bounds should prevent this)
                    seq.req.error = "oom"
                    plan.failed.append(seq)
                    skip = True
                    break
                self._record_preempt(plan, victim)
                if victim is seq:
                    skip = True
                    break
            if not skip:
                plan.decode.append(seq)

    def _plan_prefill(self, plan: TickPlan) -> None:
        """One bucket-sized chunk per tick, FCFS over running sequences.
        Only strictly-younger sequences may be preempted for a prefill
        (never steal blocks from an older request's decode)."""
        for seq in self.running:
            if seq.kv_len >= seq.prefill_target:
                continue
            length = min(seq.prefill_target - seq.kv_len, self.buckets[-1])
            need = self.pool.blocks_for(seq.kv_len + length) - len(seq.table)
            while need > 0:
                if need <= self._available():
                    blks = self._alloc(seq.uid, need)
                    if blks is not None:
                        seq.table.extend(blks)
                        break
                    # _available() promised blocks eviction could not
                    # actually deliver (e.g. a cache-only parent pinned
                    # under a live child) — fall through and preempt
                victim = self._youngest(than=seq)
                if victim is None:
                    return                     # defer the chunk to a later tick
                self._record_preempt(plan, victim)
            plan.prefill = PrefillChunk(seq=seq, start=seq.kv_len,
                                        length=length)
            return
