from repro.serve.engine import (PagedServeEngine, Request, ServeEngine,
                                request_key, supports_paging)
from repro.serve.frontend import (AsyncServeFrontend, FrontendClosedError,
                                  QueueFullError, StreamHandle)
from repro.serve.metrics import Histogram, ServeMetrics
from repro.serve.paging import (BlockPool, PrefixCache, blocks_for,
                                set_block_tables)
from repro.serve.scheduler import Scheduler, SeqState, TickPlan
