"""Serving telemetry: latency histograms + engine counters.

No dependencies beyond numpy.  The engine feeds events through the
``on_*`` hooks with timestamps from an injectable clock (tests pass a
fake clock for determinism); ``summary()`` renders the numbers the
acceptance criteria ask for — TTFT, per-token latency, throughput, pool
occupancy and prefix-cache effectiveness — and ``to_json`` persists
them (uploaded as a CI artifact by ``benchmarks/bench_serve.py``).

Every key ``summary()`` emits is documented in the README metrics
glossary ("Serving metrics glossary"); keep the two in sync.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

import numpy as np


class Histogram:
    """Log-bucketed latency histogram (seconds) that also keeps a capped
    sample reservoir so percentiles stay exact for short runs and
    unbiased (uniform reservoir sampling) for long ones."""

    def __init__(self, max_samples: int = 4096):
        # 100ns .. 100s in half-decade buckets
        self.bounds = np.logspace(-7, 2, 19)
        self.counts = np.zeros(len(self.bounds) + 1, np.int64)
        self.total = 0.0
        self.n = 0
        # exact running extrema: the reservoir can evict the true max on
        # long runs, so percentile(100) under-reports it — min/max must
        # never come from the sample set
        self._min = float("inf")
        self._max = float("-inf")
        self._samples: List[float] = []
        self._max_samples = max_samples
        self._rng = np.random.default_rng(0)

    def observe(self, v: float) -> None:
        self.counts[np.searchsorted(self.bounds, v)] += 1
        self.total += v
        self.n += 1
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        if len(self._samples) < self._max_samples:
            self._samples.append(v)
        else:                    # classic reservoir: keep each of the n
            j = int(self._rng.integers(0, self.n))   # seen w.p. k/n
            if j < self._max_samples:
                self._samples[j] = v

    def percentile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        return float(np.percentile(np.asarray(self._samples), q))

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    @property
    def min(self) -> float:
        return self._min if self.n else 0.0

    @property
    def max(self) -> float:
        return self._max if self.n else 0.0

    def summary(self) -> Dict[str, float]:
        return {"n": self.n, "mean": self.mean,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "min": self.min, "max": self.max}


class ServeMetrics:
    """Per-engine counters + TTFT / inter-token latency / occupancy."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.ttft = Histogram()
        self.per_token = Histogram()
        self.queue_delay = Histogram()
        self.counters = {"submitted": 0, "admitted": 0, "completed": 0,
                         "failed": 0, "preempted": 0, "rejected": 0,
                         "cancelled": 0, "deadline_expired": 0,
                         "tokens_out": 0, "prefill_chunks": 0,
                         "prefill_tokens": 0, "ticks": 0,
                         "decode_steps": 0, "decode_tokens": 0,
                         "kv_bytes_fused_est": 0, "kv_bytes_gathered_est": 0,
                         "prefill_kv_bytes_fused_est": 0,
                         "prefill_kv_bytes_gathered_est": 0,
                         "prefix_lookups": 0, "prefix_hit_requests": 0,
                         "prefix_queried_blocks": 0, "prefix_hit_blocks": 0,
                         "prefix_tokens_saved": 0, "prefix_cow_events": 0,
                         "prefix_cow_tokens": 0, "prefix_evictions": 0}
        # device-busy accounting: dispatch->sync windows, union-merged so
        # overlapping double-buffered steps never double-count
        self._busy_time = 0.0
        self._busy_until = float("-inf")
        self._admitted_once: set = set()
        # decode steps per attention path: a single last-write string
        # would hide mixed fused/gather runs (e.g. a capability
        # negotiation change mid-run), so count per path and report both
        self.decode_path_steps: Dict[str, int] = {}
        self.prefill_path_chunks: Dict[str, int] = {}
        self.occupancy: List[float] = []       # one sample per tick
        self.active: List[int] = []            # concurrent running seqs
        self.sharing: List[float] = []         # logical/physical blocks
        self.prefix_cached: List[int] = []     # cache-held blocks per tick
        self._t_submit: Dict[int, float] = {}
        self._t_last_tok: Dict[int, float] = {}
        self._t0 = clock()
        # throughput clock starts at FIRST ADMISSION, not construction:
        # engine construction / compile warmup would deflate tokens/s
        self._t_first_admit: Optional[float] = None

    # ------------------------------------------------------------------
    def on_submit(self, uid: int) -> None:
        self.counters["submitted"] += 1
        self._t_submit[uid] = self.clock()

    def on_admit(self, uid: int) -> None:
        self.counters["admitted"] += 1
        now = self.clock()
        if self._t_first_admit is None:
            self._t_first_admit = now
        # queue delay is submit -> FIRST admission (scheduling delay);
        # preempt-recompute re-admissions would re-observe cumulative
        # lifetimes and drown the signal
        if uid not in self._admitted_once:
            self._admitted_once.add(uid)
            self.queue_delay.observe(now - self._t_submit.get(uid, now))

    def on_reject(self, uid: int) -> None:
        self.counters["rejected"] += 1

    def on_preempt(self, uid: int) -> None:
        self.counters["preempted"] += 1

    def on_token(self, uid: int) -> None:
        now = self.clock()
        if uid not in self._t_last_tok:           # first token: TTFT
            self.ttft.observe(now - self._t_submit.get(uid, self._t0))
        else:
            self.per_token.observe(now - self._t_last_tok[uid])
        self._t_last_tok[uid] = now
        self.counters["tokens_out"] += 1

    def on_complete(self, uid: int) -> None:
        self.counters["completed"] += 1

    def on_fail(self, uid: int, error: Optional[str] = None) -> None:
        """Retired with an error (e.g. pool OOM truncation).  Client
        cancellations and deadline expiries additionally bump their own
        counters so load-shedding is visible separately from engine
        faults."""
        self.counters["failed"] += 1
        if error == "cancelled":
            self.counters["cancelled"] += 1
        elif error == "deadline":
            self.counters["deadline_expired"] += 1

    def on_device_interval(self, start: float, end: float) -> None:
        """One dispatch->sync device window (engine clock).  Windows are
        union-merged: under the double-buffered tick, step N's window
        overlaps the host work of step N+1, and summing raw durations
        would count busy time twice."""
        if end <= start:
            return
        s = max(start, self._busy_until)
        if end > s:
            self._busy_time += end - s
        self._busy_until = max(self._busy_until, end)

    def on_prefix_lookup(self, uid: int, queried_blocks: int,
                         hit_blocks: int, tokens_saved: int,
                         cow_tokens: int) -> None:
        """One admission-time prefix-index probe.  ``queried_blocks`` is
        how many full prompt blocks were eligible for adoption,
        ``hit_blocks`` how many were found live (== pool blocks saved),
        ``tokens_saved`` the prefill tokens skipped, and ``cow_tokens``
        the cached tokens that had to be RECOMPUTED into a private block
        because they sat in a partially-matching tail block
        (copy-on-write by recompute)."""
        self.counters["prefix_lookups"] += 1
        self.counters["prefix_queried_blocks"] += int(queried_blocks)
        self.counters["prefix_hit_blocks"] += int(hit_blocks)
        self.counters["prefix_tokens_saved"] += int(tokens_saved)
        if hit_blocks > 0:
            self.counters["prefix_hit_requests"] += 1
        if cow_tokens > 0:
            self.counters["prefix_cow_events"] += 1
            self.counters["prefix_cow_tokens"] += int(cow_tokens)

    def on_tick(self, occupancy: float, active: int,
                logical_blocks: Optional[int] = None,
                physical_blocks: Optional[int] = None,
                prefix_cached: Optional[int] = None,
                prefix_evictions: Optional[int] = None) -> None:
        self.counters["ticks"] += 1
        self.occupancy.append(float(occupancy))
        self.active.append(int(active))
        if logical_blocks is not None and physical_blocks:
            # effective-capacity gauge: block-table entries across running
            # sequences over distinct pool blocks in use.  > 1.0 means
            # sharing is letting logical context exceed physical KV.
            self.sharing.append(logical_blocks / physical_blocks)
        if prefix_cached is not None:
            self.prefix_cached.append(int(prefix_cached))
        if prefix_evictions is not None:
            self.counters["prefix_evictions"] = int(prefix_evictions)

    def on_prefill_chunk(self, tokens: int = 0, fused_bytes: int = 0,
                         gathered_bytes: int = 0,
                         path: Optional[str] = None) -> None:
        """One chunked-prefill dispatch: ``tokens`` is the chunk length,
        plus the analytic KV traffic of BOTH prefill attention paths for
        this chunk — the fused flash kernel streams only the sequence's
        own table-mapped blocks (scale rows included on int8 pools),
        while the gathered path materializes k/v/pos views over the full
        per-sequence capacity.  ``path`` is the one actually taken; the
        legacy zero-argument form just counts the chunk."""
        self.counters["prefill_chunks"] += 1
        self.counters["prefill_tokens"] += int(tokens)
        self.counters["prefill_kv_bytes_fused_est"] += int(fused_bytes)
        self.counters["prefill_kv_bytes_gathered_est"] += int(gathered_bytes)
        if path is not None:
            self.prefill_path_chunks[path] = \
                self.prefill_path_chunks.get(path, 0) + 1

    def on_decode_step(self, tokens: int, fused_bytes: int,
                       gathered_bytes: int, path: str) -> None:
        """One decode batch: ``tokens`` rows advanced, plus the analytic
        KV traffic of BOTH paged decode paths for this step (the engine
        computes them from live block counts; see
        ``PagedServeEngine._decode_kv_bytes``).  ``path`` is the one
        actually taken."""
        self.counters["decode_steps"] += 1
        self.counters["decode_tokens"] += int(tokens)
        self.counters["kv_bytes_fused_est"] += int(fused_bytes)
        self.counters["kv_bytes_gathered_est"] += int(gathered_bytes)
        self.decode_path_steps[path] = self.decode_path_steps.get(path, 0) + 1

    # ------------------------------------------------------------------
    @property
    def decode_path(self) -> Optional[str]:
        """The single decode path taken, or ``"mixed"`` when a run used
        more than one (``decode_path_steps`` has the per-path counts)."""
        if not self.decode_path_steps:
            return None
        if len(self.decode_path_steps) == 1:
            return next(iter(self.decode_path_steps))
        return "mixed"

    @property
    def prefill_path(self) -> Optional[str]:
        """The single prefill-attention path taken, or ``"mixed"``
        (``prefill_path_chunks`` has the per-path chunk counts)."""
        if not self.prefill_path_chunks:
            return None
        if len(self.prefill_path_chunks) == 1:
            return next(iter(self.prefill_path_chunks))
        return "mixed"

    def throughput(self) -> float:
        """Emitted tokens over wall time since the first admission (the
        construction timestamp is only the fallback when nothing was
        ever admitted, where the numerator is zero anyway)."""
        t0 = self._t_first_admit if self._t_first_admit is not None \
            else self._t0
        dt = self.clock() - t0
        return self.counters["tokens_out"] / dt if dt > 0 else 0.0

    def device_busy_fraction(self) -> float:
        """Fraction of serving wall time (since first admission) covered
        by a dispatched-but-unsynced decode step.  An *estimate of host-
        side overlap*, not a device counter: prefill-only phases count
        as idle on both tick modes, so the sync and async engines are
        directly comparable — the async engine's whole point is pushing
        this toward 1.0."""
        if self._t_first_admit is None:
            return 0.0
        dt = self.clock() - self._t_first_admit
        return min(1.0, self._busy_time / dt) if dt > 0 else 0.0

    def summary(self) -> Dict:
        occ = np.asarray(self.occupancy) if self.occupancy else np.zeros(1)
        act = np.asarray(self.active) if self.active else np.zeros(1)
        shr = np.asarray(self.sharing) if self.sharing else np.ones(1)
        ndec = max(self.counters["decode_tokens"], 1)
        npre = max(self.counters["prefill_tokens"], 1)
        nq = max(self.counters["prefix_queried_blocks"], 1)
        return {
            "counters": dict(self.counters),
            "ttft_s": self.ttft.summary(),
            "per_token_s": self.per_token.summary(),
            "queue_delay_s": self.queue_delay.summary(),
            "throughput_tok_s": self.throughput(),
            "device_busy_fraction": self.device_busy_fraction(),
            "occupancy": {"mean": float(occ.mean()),
                          "peak": float(occ.max())},
            "peak_active": int(act.max()),
            "paged_kernel": {
                "path": self.decode_path,
                "steps_by_path": dict(self.decode_path_steps),
                "kv_bytes_per_token_fused":
                    self.counters["kv_bytes_fused_est"] / ndec,
                "kv_bytes_per_token_gathered":
                    self.counters["kv_bytes_gathered_est"] / ndec,
                "prefill_path": self.prefill_path,
                "prefill_chunks_by_path": dict(self.prefill_path_chunks),
                "kv_bytes_per_prefill_token_fused":
                    self.counters["prefill_kv_bytes_fused_est"] / npre,
                "kv_bytes_per_prefill_token_gathered":
                    self.counters["prefill_kv_bytes_gathered_est"] / npre,
            },
            "prefix_cache": {
                "hit_rate": self.counters["prefix_hit_blocks"] / nq,
                "blocks_saved": self.counters["prefix_hit_blocks"],
                "tokens_saved": self.counters["prefix_tokens_saved"],
                "cow_events": self.counters["prefix_cow_events"],
                "evictions": self.counters["prefix_evictions"],
                "cached_blocks_peak":
                    max(self.prefix_cached) if self.prefix_cached else 0,
            },
            "effective_capacity": {     # 1.0 == no sharing (cache off)
                "mean": float(shr.mean()),
                "peak": float(shr.max()),
            },
        }

    def to_json(self, path: Optional[str] = None) -> str:
        s = json.dumps(self.summary(), indent=2, sort_keys=True)
        if path:
            with open(path, "w") as f:
                f.write(s + "\n")
        return s
