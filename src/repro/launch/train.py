"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch phi4_mini_3_8b \
        --steps 1000 --global-batch 256 --seq-len 4096 [--devices N]

On this CPU container the default runs a reduced config on 1 device; on a
real TPU fleet the same entry point builds the production mesh and
shards via the same rules the dry-run validates.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt_6_7b")
    ap.add_argument("--reduced", type=int, default=1,
                    help="1 = reduced config (CPU), 0 = full config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--mesh", default="",
                    help="e.g. '4x2' to build a data x model mesh")
    args = ap.parse_args()

    from repro.configs import get_config, get_reduced
    from repro.data.pipeline import SyntheticLM
    from repro.models import Model
    from repro.optim import adamw
    from repro.parallel import sharding as shd
    from repro.train.trainer import Trainer, TrainConfig

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg)
    print(f"[launch.train] {cfg.name}: {model.n_params():,} params")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh
        try:
            mesh = parse_mesh(args.mesh)
        except ValueError as e:
            raise SystemExit(str(e))
        rules = shd.make_rules(fsdp=bool(args.fsdp), act_shard=True)
        shd.set_activation_rules(mesh, rules)

    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                       global_batch=args.global_batch, seed=0)
    tcfg = TrainConfig(steps=args.steps, ckpt_every=50,
                       ckpt_dir=args.ckpt_dir,
                       microbatches=args.microbatches,
                       grad_compression=args.grad_compression,
                       fsdp=bool(args.fsdp))
    ocfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=min(100, args.steps // 10 + 1),
                             total_steps=args.steps)
    trainer = Trainer(model, ocfg, tcfg, mesh=mesh)
    state, hist = trainer.run(pipe)
    print(f"[launch.train] finished at step {int(state['step'])}, "
          f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
