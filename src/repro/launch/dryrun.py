import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production mesh, print memory/cost analysis, and extract roofline terms.

MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun
--arch mixtral_8x7b --shape decode_32k [--multi-pod] [--quant 4] ...``
The XLA_FLAGS line above runs before ANY jax import (jax locks the device
count at first init); nothing in this module imports jax at module scope
before it executes.

Per shape cell:
  train_4k    -> train_step  (loss+grad+AdamW update, dense bf16, FSDP)
  prefill_32k -> prefill     (quantized BCQ weights, fills the cache)
  decode_32k  -> decode_step (quantized, 1 token vs 32k cache)
  long_500k   -> decode_step (sub-quadratic archs only)

Roofline extraction lowers two UNROLLED reduced-depth variants and
extrapolates per-period costs (exact for homogeneous stacks) because
cost_analysis counts while-loop bodies once; the full scanned model is
compiled for the memory-fit proof (see roofline/analysis.py).
"""
import argparse
import dataclasses
import json
import sys
import time


def _parse():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True,
                   help="train_4k|prefill_32k|decode_32k|long_500k")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--quant", type=int, default=4,
                   help="BCQ bits for serving shapes (0 = dense)")
    p.add_argument("--backend", default="bcq_xla",
                   help="gemm backend for quantized serving")
    p.add_argument("--fsdp", type=int, default=1)
    p.add_argument("--no-roofline", action="store_true",
                   help="memory-fit compile only")
    p.add_argument("--json-out", default="")
    p.add_argument("--remat", type=int, default=1)
    p.add_argument("--seq-shard", type=int, default=0,
                   help="shard train sequence dim over the model axis (SP)")
    p.add_argument("--kv-bits", type=int, default=16,
                   help="8 -> int8 KV cache (serve shapes)")
    return p.parse_args()


def active_params(cfg, model) -> tuple:
    """(n_active, n_total) excluding token/pos embeddings; inactive routed
    experts removed (MODEL_FLOPS convention: 6*N_active*D)."""
    total = model.n_params()
    embed = cfg.vocab_size * cfg.d_model
    if cfg.pos == "learned":
        embed += cfg.max_seq_len * cfg.d_model
    if not cfg.tie_embeddings:
        embed += 0  # unembed participates in compute; keep it
    n_eff = total - embed
    if cfg.tie_embeddings:
        n_eff += cfg.vocab_size * cfg.d_model      # head matmul still runs
    inactive = 0
    if cfg.n_experts:
        f = cfg.moe_d_ff or cfg.d_ff
        per_expert = 3 * f * cfg.d_model
        n_moe_layers = sum(1 for i in range(cfg.n_layers)
                           if cfg.mlp_kind(i) == "moe")
        inactive = n_moe_layers * per_expert * \
            (cfg.n_experts - cfg.experts_per_token)
    return n_eff - inactive, total


def build_cell(arch: str, shape_name: str, *, quant=4, backend="bcq_xla",
               fsdp=True, multi_pod=False, remat=True, scan=True,
               n_layers=None, seq_shard=False, kv_bits=16):
    """Everything needed to lower one cell: (fn, example_args, shardings,
    mesh, cfg).  n_layers overrides depth (roofline extrapolation)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, SHAPES
    from repro.models import Model
    from repro.models.module import abstract_params
    from repro.optim import adamw
    from repro.parallel import sharding as shd
    from repro.quant.ptq import abstract_quantized_params
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context():
        raise SystemExit(f"SKIP: {arch} has no sub-quadratic path for "
                         f"long_500k (full attention) — see DESIGN.md")
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shd.make_rules(fsdp=fsdp and shape.kind == "train",
                           multi_pod=multi_pod,
                           act_shard=shape.kind == "train")
    if seq_shard:
        rules["seq"] = "model"

    overrides = dict(remat=remat, scan_layers=scan)
    if n_layers is not None:
        # keep prefix pattern intact; n_layers counts total layers
        overrides["n_layers"] = n_layers
        overrides["scan_layers"] = False
    if shape.kind != "train" and quant:
        from repro.quant.spec import QuantSpec
        overrides["quant"] = QuantSpec(bits=quant, backend=backend)
    if shape.kind != "train" and kv_bits != 16:
        overrides["kv_cache_bits"] = kv_bits
    model_par = 16
    if shape.kind != "train" and cfg.attention == "gqa" and cfg.n_kv_heads \
            and cfg.n_kv_heads < model_par and model_par % cfg.n_kv_heads == 0:
        # kv-head replication: 2x cache memory beats per-layer cache
        # all-gathers when TP > n_kv_heads (serve shapes only).  Requires
        # the q-head grouping to stay integral (phi4's 24 heads fall back
        # to head_dim sharding).
        r = model_par // cfg.n_kv_heads
        if cfg.n_heads % (cfg.n_kv_heads * r) == 0:
            overrides["kv_replication"] = r
    cfg = cfg.replace(**overrides)
    model = Model(cfg)
    shd.set_activation_rules(mesh, rules)

    aparams = model.abstract()
    axes = model.axes()
    if shape.kind != "train" and quant:
        aparams = abstract_quantized_params(aparams, axes, bits=quant)
    p_sh = shd.build_shardings(mesh, aparams, axes, rules)

    specs = cfg.input_specs(shape)
    b_sh = shd.batch_shardings(mesh, specs, rules)

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
            # pin gradient shardings to the param shardings — the grad
            # accumulators inside the layer-scan backward otherwise come out
            # replicated (same GSPMD loop-carry failure as the attention
            # residuals; ~58 GiB/device on mamba2 train without this)
            grads = jax.tree_util.tree_map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, p_sh)
            new_p, new_o, metrics = adamw.apply_updates(params, grads, opt,
                                                        opt_cfg)
            return new_p, new_o, metrics

        a_opt = jax.eval_shape(adamw.init_state, aparams)
        o_sh = adamw.AdamWState(
            count=shd.replicated(mesh),
            m=shd.build_shardings(mesh, a_opt.m, axes, rules),
            v=shd.build_shardings(mesh, a_opt.v, axes, rules))
        fn = train_step
        args = (aparams, a_opt, specs)
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh, None)
        donate = (0, 1)          # params + opt state update in place
    else:
        cache_len = shape.seq_len
        batch = shape.global_batch
        acache = model.abstract_cache(batch, cache_len)
        c_sh = shd.build_shardings(mesh, acache, model.axes() and
                                   _cache_axes(model, batch, cache_len), rules)
        if shape.kind == "prefill":
            def prefill(params, batch_in, cache):
                return model.prefill(params, batch_in, cache)
            fn = prefill
            args = (aparams, specs, acache)
            in_sh = (p_sh, b_sh, c_sh)
            out_sh = (None, c_sh)
            donate = (2,)        # cache filled in place
        else:
            tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
            specs = {"tokens": tok}

            def decode(params, tokens, cache, positions):
                return model.decode_step(params, tokens, cache, positions)
            fn = decode
            tok_sh = shd.batch_shardings(mesh, {"tokens": tok}, rules)["tokens"]
            pos_sh = shd.batch_shardings(mesh, {"p": pos}, rules)["p"]
            args = (aparams, tok, acache, pos)
            in_sh = (p_sh, tok_sh, c_sh, pos_sh)
            out_sh = (None, c_sh)
            donate = (2,)        # cache updated in place
    return fn, args, in_sh, out_sh, donate, mesh, cfg, shape, model


def _cache_axes(model, batch, length):
    from repro.models.module import logical_axes
    return logical_axes(model.cache_desc(batch, length))


def lower_and_compile(fn, args, in_sh, out_sh, mesh, label="", donate=()):
    import jax
    t0 = time.time()
    with mesh:
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    print(f"[dryrun] {label}: lower {t_lower:.1f}s compile {t_compile:.1f}s")
    return compiled


def run_cell(arch, shape_name, *, quant=4, backend="bcq_xla", fsdp=True,
             multi_pod=False, remat=True, roofline=True, seq_shard=False,
             kv_bits=16):
    """Compile the full scanned model (memory proof) and, if requested,
    two unrolled reduced-depth variants for extrapolated roofline terms.
    Returns a result dict."""
    from repro.configs import get_config
    from repro.roofline import analysis as ra

    cfg0 = get_config(arch)
    kw = dict(quant=quant, backend=backend, fsdp=fsdp, multi_pod=multi_pod,
              remat=remat, seq_shard=seq_shard, kv_bits=kv_bits)

    # ---- full model, scanned: the memory-fit / shardability proof -------
    fn, args, in_sh, out_sh, donate, mesh, cfg, shape, model = build_cell(
        arch, shape_name, scan=True, **kw)
    compiled = lower_and_compile(fn, args, in_sh, out_sh, mesh,
                                 f"{arch}/{shape_name}/full", donate)
    full = ra.from_compiled(compiled)
    ma = compiled.memory_analysis()
    print(f"[dryrun] memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
          f"out={ma.output_size_in_bytes/2**30:.2f}GiB per device")
    print(f"[dryrun] cost_analysis (scanned, loop bodies counted once): "
          f"flops={full.flops:.3e} bytes={full.bytes_accessed:.3e}")

    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "quant": quant if shape.kind != "train" else 0,
        "mesh": list(mesh.devices.shape),
        "device_mem_gb": full.device_memory_gb(),
        "compile_ok": True,
    }

    if roofline:
        # ---- layer-extrapolated costs (unrolled L1 / L2 periods) --------
        from repro.models.transformer import scan_grouping
        pre, period, reps = scan_grouping(cfg0)
        l1 = pre + period
        l2 = pre + 2 * period
        rls = []
        for ln in (l1, l2):
            fn, args, in_sh, out_sh, dn, mesh2, _, _, _ = build_cell(
                arch, shape_name, scan=True, n_layers=ln, **kw)
            c = lower_and_compile(fn, args, in_sh, out_sh, mesh2,
                                  f"{arch}/{shape_name}/L{ln}", dn)
            rls.append(ra.from_compiled(c))
        roof = ra.extrapolate(rls[0], rls[1], 1, 2, (cfg0.n_layers - pre) / period,
                              mem=full)
        n_act, n_tot = active_params(cfg0, model)
        mf = ra.model_flops(cfg0, shape, n_act, n_tot)
        n_chips = int(mesh.devices.size)
        useful = mf / max(roof.flops * n_chips, 1e-9)
        row = roof.row()
        row.update({"model_flops_global": mf,
                    "useful_flops_ratio": useful,
                    "n_active_params": n_act, "n_total_params": n_tot})
        if shape.kind == "decode":
            row["analytic"] = ra.serve_analytic_bytes(
                cfg0, shape, n_act, quant or 4)
        result["roofline"] = row
        print(f"[dryrun] roofline (extrapolated to {cfg0.n_layers}L): "
              f"t_comp={roof.t_compute*1e3:.2f}ms t_mem={roof.t_memory*1e3:.2f}ms "
              f"t_coll={roof.t_collective*1e3:.2f}ms -> {roof.bottleneck}-bound, "
              f"roofline_frac={roof.fraction_of_roofline():.3f}, "
              f"useful_flops={useful:.3f}")
    return result


def main():
    args = _parse()
    res = run_cell(args.arch, args.shape, quant=args.quant,
                   backend=args.backend, fsdp=bool(args.fsdp),
                   multi_pod=args.multi_pod, remat=bool(args.remat),
                   roofline=not args.no_roofline,
                   seq_shard=bool(args.seq_shard), kv_bits=args.kv_bits)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(res, f, indent=2, default=str)
    print(json.dumps(res, indent=2, default=str))


if __name__ == "__main__":
    main()
