"""Production serving launcher: quantize (or load) and serve.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b \
        --bits 3 --requests 16
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt_6_7b")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--method", default="bcq", choices=["bcq", "rtn"])
    ap.add_argument("--backend", default="bcq_xla")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--pretune", action="store_true",
                    help="autotune kernel configs for this model's layer "
                         "shapes before serving (persists to the JSON "
                         "cache; see python -m repro.tune)")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config, get_reduced
    from repro.models import Model
    from repro.quantize import quantize_model
    from repro.serve.engine import ServeEngine, Request

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.replace(max_seq_len=max(cfg.max_seq_len, args.cache_len))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[launch.serve] {cfg.name}: {model.n_params():,} params")

    if args.bits:
        t0 = time.time()
        params = quantize_model(params, model.axes(), bits=args.bits,
                                method=args.method, group_size=64, iters=3)
        print(f"[launch.serve] {args.method}-{args.bits}bit in "
              f"{time.time()-t0:.1f}s")
        model = Model(cfg.replace(gemm_backend=args.backend))

    eng = ServeEngine(model, params, slots=args.slots,
                      cache_len=args.cache_len, prefill_buckets=(16, 32, 64),
                      pretune=args.pretune)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               (int(rng.integers(4, 24)),)),
                    max_new_tokens=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[launch.serve] {len(done)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
