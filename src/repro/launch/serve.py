"""Production serving launcher: quantize (or load) and serve.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b \
        --bits 3 --requests 16

Default engine is the paged-KV engine (block pool + chunked-prefill
scheduler + streaming + metrics); ``--engine slots`` falls back to the
contiguous fixed-slot engine (required for SSM/hybrid, enc-dec and
sliding-window models, which the paged cache does not cover).
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt_6_7b")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--method", default="bcq", choices=["bcq", "rtn"])
    ap.add_argument("--backend", default="bcq_xla")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "paged", "slots"],
                    help="auto picks paged where the model supports it "
                         "(attention-only, no SWA/enc-dec), else slots")
    ap.add_argument("--slots", type=int, default=4,
                    help="[slots engine] fixed cache rows")
    ap.add_argument("--cache-len", type=int, default=256,
                    help="[slots engine] per-row KV reservation (also the "
                         "paged engine's default --max-seq-len)")
    ap.add_argument("--max-seq-len", type=int, default=0,
                    help="[paged engine] per-sequence context cap "
                         "(default: --cache-len)")
    ap.add_argument("--num-blocks", type=int, default=64,
                    help="[paged engine] shared KV pool size")
    ap.add_argument("--block-size", type=int, default=16,
                    help="[paged engine] tokens per block")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="[paged engine] concurrent sequences")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are generated")
    ap.add_argument("--metrics-json", default="",
                    help="write the metrics summary to this path")
    ap.add_argument("--pretune", action="store_true",
                    help="autotune kernel configs for this model's layer "
                         "shapes before serving (persists to the JSON "
                         "cache; see python -m repro.tune)")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config, get_reduced
    from repro.models import Model
    from repro.quantize import quantize_model
    from repro.serve import PagedServeEngine, Request, ServeEngine
    from repro.serve.engine import supports_paging

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.replace(max_seq_len=max(cfg.max_seq_len, args.cache_len))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[launch.serve] {cfg.name}: {model.n_params():,} params")

    if args.bits:
        t0 = time.time()
        params = quantize_model(params, model.axes(), bits=args.bits,
                                method=args.method, group_size=64, iters=3)
        print(f"[launch.serve] {args.method}-{args.bits}bit in "
              f"{time.time()-t0:.1f}s")
        model = Model(cfg.replace(gemm_backend=args.backend))

    on_token = None
    if args.stream:
        on_token = lambda tok, req: print(f"  [stream] req {req.uid} "
                                          f"+tok {tok}")
    engine = args.engine
    if engine == "auto":
        engine = "paged" if supports_paging(cfg) else "slots"
        print(f"[launch.serve] engine=auto -> {engine}")
    if engine == "paged":
        eng = PagedServeEngine(model, params, num_blocks=args.num_blocks,
                               block_size=args.block_size,
                               max_batch=args.max_batch,
                               max_seq_len=args.max_seq_len or args.cache_len,
                               prefill_buckets=(16, 32, 64),
                               pretune=args.pretune)
    else:
        eng = ServeEngine(model, params, slots=args.slots,
                          cache_len=args.cache_len,
                          prefill_buckets=(16, 32, 64),
                          pretune=args.pretune)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               (int(rng.integers(4, 24)),)),
                    max_new_tokens=args.max_new, on_token=on_token)
            for i in range(args.requests)]
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[launch.serve] {len(done)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s")
    if engine == "paged":
        s = eng.metrics.summary()
        print(f"[launch.serve] ttft p50={s['ttft_s']['p50']*1e3:.1f}ms "
              f"p95={s['ttft_s']['p95']*1e3:.1f}ms  "
              f"per-token p50={s['per_token_s']['p50']*1e3:.1f}ms  "
              f"occupancy mean={s['occupancy']['mean']:.2f} "
              f"peak={s['occupancy']['peak']:.2f}  "
              f"preempted={s['counters']['preempted']}")
        if args.metrics_json:
            eng.metrics.to_json(args.metrics_json)
            print(f"[launch.serve] metrics -> {args.metrics_json}")


if __name__ == "__main__":
    main()
