"""Production serving launcher: quantize (or load pre-quantized) and serve.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b \
        --bits 3 --requests 16

Quantization is driven by a :class:`repro.quant.QuantSpec` — built from
the CLI flags, or loaded whole from ``--spec spec.json`` (flags override
file fields).  Highlights:

  * ``--bits 2.4`` (fractional) runs sensitivity-driven mixed precision
    via ``core.mixed_precision.allocate_bits`` (paper Fig. 17); the
    printed manifest reports the achieved average.
  * ``--method ternary`` serves {-a, 0, +a} weights as a plane-native
    sign+mask bundle (one alpha row, no offset) routed to the dedicated
    ``ternary_matmul`` kernel where native; ``--bits 1.58`` instead
    mixes ternary/2/3-bit layers under a log2(3) average-bit budget.
  * ``--bits 0`` explicitly serves the dense FP model (no silent skip).
  * ``--save-quantized DIR`` / ``--load-quantized DIR`` persist / reuse
    the quantized tree, so relaunches skip minutes of PTQ solver time;
    a loaded checkpoint serves token-for-token identically to
    quantize-at-launch.
  * ``--manifest-json PATH`` dumps the per-layer manifest (CI artifact).

Default engine is the paged-KV engine (block pool + chunked-prefill
scheduler + streaming + metrics); ``--engine slots`` falls back to the
contiguous fixed-slot engine (required for SSM/hybrid, enc-dec and
sliding-window models, which the paged cache does not cover).
``--paged-kernel`` picks the paged attention paths: ``auto`` (fused
Pallas kernels where hardware-native), ``fused`` (force the kernels,
interpret mode off-TPU) or ``gather`` (the paged_view fallback).  The
fused coverage spans float, int8-KV (per-slot scales folded in-kernel)
and MLA-latent decode plus float/int8-KV chunked prefill; the paths
resolve per variant (MLA prefill still gathers for its decompressing
``kv_map_fn``) and are printed as ``decode path`` / ``prefill path``.

``--prefix-cache on|off`` (default: on for the paged engine) shares KV
blocks across requests with a common block-aligned prompt prefix —
refcounted adoption at admission, copy-on-write by recompute on the
first divergent or partially-filled block (see ``docs/serving.md``).

``--trace-out trace.json`` records an event-level serving trace (spans
for admission, prefix lookups, prefill chunks, decode dispatch, device
sync, sampling, preemptions and evictions, plus one track per request)
and writes Chrome trace-event JSON — open it in Perfetto or
``chrome://tracing``; ``--trace-timeline N`` also prints the host-side
per-request timeline table.  See ``docs/observability.md``.

``--async`` serves through the asyncio frontend
(``repro.serve.frontend``) and the double-buffered engine tick: every
request is submitted from its own coroutine, token streams are consumed
concurrently, sampling runs on-device, and the device sync for step N
hides behind the planning and dispatch of step N+1.  ``--deadline-ms``
gives every third request a deadline so the demo exercises expiry and
block release under load; outputs remain token-for-token identical to
the synchronous engine.

``--mesh auto`` (or an explicit ``DxM`` shape like ``2x4``) serves the
paged engine sharded over a ``("data", "model")`` mesh: KV pool leaves
shard over kv_heads (head_dim fallback for narrow-GQA), params ride
``parallel.sharding.build_shardings`` (BCQ bundles included), and the
fused kernel launches per model-shard via ``shard_map``.  ``--tp N``
pins the model axis under ``--mesh auto``.  Smoke it on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import argparse
import time


def build_spec(args):
    """Resolve the QuantSpec from --spec JSON + CLI overrides.

    Returns None for an explicitly dense serve (--bits 0 with no spec
    file, or a spec whose bits resolve to 0).
    """
    from repro.quant import QuantSpec, canonical_format

    if args.bits is not None and args.bits == 0:
        # explicit dense request wins before any spec normalization
        # (ternary would otherwise coerce bits back to its 2 planes)
        return None
    base = QuantSpec.load(args.spec) if args.spec else QuantSpec()
    kw = {}
    if args.bits is not None:
        kw["bits"] = args.bits
    elif args.format is not None and \
            canonical_format(args.format) != base.format:
        # switching format without --bits: reset to the new format's
        # default instead of carrying the old format's bit-width over
        # (ternary rejects any bits != 2)
        kw["bits"] = None
    if args.format is not None:
        kw["format"] = args.format
    if args.backend is not None:
        kw["backend"] = args.backend
    if args.group_size is not None:
        kw["group_size"] = args.group_size
    if args.iters is not None:
        kw["iters"] = args.iters
    try:
        spec = base.replace(**kw) if kw else base
    except ValueError as e:                  # e.g. --method ternary --bits 4
        raise SystemExit(f"invalid quant flags: {e}")
    return None if spec.bits == 0 else spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt_6_7b")
    ap.add_argument("--reduced", type=int, default=1)
    # --- quantization spec (repro.quant) -------------------------------
    ap.add_argument("--bits", type=float, default=None,
                    help="weight bits; fractional (e.g. 2.4) -> mixed "
                         "precision; sub-2 budgets (e.g. 1.58) mix "
                         "ternary/2/3-bit layers; 0 -> serve dense FP "
                         "(default: 4)")
    ap.add_argument("--method", "--format", dest="format", default=None,
                    choices=["bcq", "rtn", "uniform", "ternary"],
                    help="quant format (registry: repro.quant.formats)")
    ap.add_argument("--backend", default=None,
                    help="execution preference (auto | dense | bcq_xla | "
                         "lut_pallas | mxu_pallas | ternary_pallas); "
                         "capability negotiation falls back down the "
                         "chain per weight")
    ap.add_argument("--group-size", type=int, default=None,
                    help="scale group size along the input dim (default 128)")
    ap.add_argument("--iters", type=int, default=None,
                    help="BCQ alternating-refinement rounds (default 5)")
    ap.add_argument("--spec", default="",
                    help="QuantSpec JSON file; explicit flags override")
    ap.add_argument("--save-quantized", default="",
                    help="write the quantized params + spec/manifest to "
                         "this checkpoint dir after PTQ")
    ap.add_argument("--load-quantized", default="",
                    help="serve pre-quantized params from this checkpoint "
                         "dir (skips PTQ; spec comes from the checkpoint)")
    ap.add_argument("--manifest-json", default="",
                    help="write the quantization manifest to this path")
    # --- engine --------------------------------------------------------
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "paged", "slots"],
                    help="auto picks paged where the model supports it "
                         "(attention-only, no SWA/enc-dec), else slots")
    ap.add_argument("--slots", type=int, default=4,
                    help="[slots engine] fixed cache rows")
    ap.add_argument("--cache-len", type=int, default=256,
                    help="[slots engine] per-row KV reservation (also the "
                         "paged engine's default --max-seq-len)")
    ap.add_argument("--max-seq-len", type=int, default=0,
                    help="[paged engine] per-sequence context cap "
                         "(default: --cache-len)")
    ap.add_argument("--num-blocks", type=int, default=64,
                    help="[paged engine] shared KV pool size")
    ap.add_argument("--block-size", type=int, default=16,
                    help="[paged engine] tokens per block")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="[paged engine] concurrent sequences")
    ap.add_argument("--paged-kernel", default="auto",
                    choices=["auto", "fused", "gather"],
                    help="[paged engine] paged attention path: fused "
                         "Pallas kernels (auto: only where hardware-"
                         "native; fused: force, interpret mode off-TPU) "
                         "vs the gathered paged_view fallback.  Fused "
                         "covers float/int8-KV/MLA decode and float/"
                         "int8-KV chunked prefill; the remaining gaps "
                         "(MLA prefill) negotiate down per variant")
    ap.add_argument("--prefix-cache", default=None,
                    choices=["on", "off"],
                    help="[paged engine] share KV blocks across requests "
                         "with a common block-aligned prompt prefix "
                         "(refcounted, copy-on-write by recompute; see "
                         "docs/serving.md).  Default: on for the paged "
                         "engine")
    ap.add_argument("--mesh", default="",
                    help="[paged engine] serve sharded over a (data, "
                         "model) mesh: 'auto' (largest divisor mesh over "
                         "the visible devices; --tp pins the model axis) "
                         "or an explicit DxM shape like 2x4")
    ap.add_argument("--tp", type=int, default=0,
                    help="model-parallel extent for --mesh auto")
    ap.add_argument("--async", dest="async_engine", action="store_true",
                    help="[paged engine] serve through the asyncio "
                         "frontend with the double-buffered engine tick: "
                         "concurrent per-request coroutines, on-device "
                         "sampling, and step N's device sync hidden "
                         "behind step N+1's dispatch (token-identical "
                         "to the synchronous engine)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="with --async: give every third request this "
                         "deadline so the demo exercises expiry and "
                         "block release under load (0: no deadlines)")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are generated")
    ap.add_argument("--metrics-json", default="",
                    help="write the metrics summary to this path")
    ap.add_argument("--trace-out", default="",
                    help="[paged engine] record an event-level serving "
                         "trace and write it as Chrome trace-event JSON "
                         "(open in https://ui.perfetto.dev or "
                         "chrome://tracing; see docs/observability.md)")
    ap.add_argument("--trace-timeline", type=int, default=0, metavar="N",
                    help="with --trace-out: also print the first N rows "
                         "of the host-side per-request timeline table")
    ap.add_argument("--trace-profiler-bridge", action="store_true",
                    help="with --trace-out: wrap host spans in "
                         "jax.profiler annotations so device profiles "
                         "line up with the serving trace")
    ap.add_argument("--pretune", action="store_true",
                    help="autotune kernel configs for this model's layer "
                         "shapes before serving (persists to the JSON "
                         "cache; see python -m repro.tune)")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro import quant as quant_api
    from repro.configs import get_config, get_reduced
    from repro.models import Model
    from repro.serve import PagedServeEngine, Request, ServeEngine
    from repro.serve.engine import supports_paging

    if args.backend is not None:
        try:    # fail fast on both paths: before PTQ and before ckpt load
            quant_api.fallback_chain(args.backend)
        except KeyError as e:
            raise SystemExit(f"--backend: {e.args[0]}")
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.replace(max_seq_len=max(cfg.max_seq_len, args.cache_len))
    model = Model(cfg)

    manifest = None
    if args.load_quantized:
        # weight-shape flags describe the *stored* weights and cannot be
        # changed after the fact; --backend is a runtime execution
        # preference, so it still applies to a loaded checkpoint
        fixed = {"--bits": args.bits, "--method": args.format,
                 "--group-size": args.group_size, "--iters": args.iters,
                 "--spec": args.spec or None,
                 "--save-quantized": args.save_quantized or None}
        bad = [k for k, v in fixed.items() if v is not None]
        if bad:
            raise SystemExit(f"{', '.join(bad)} cannot be combined with "
                             "--load-quantized: the checkpoint's weights "
                             "are already quantized (re-quantize without "
                             "--load-quantized instead)")
        params, spec, manifest, extra = quant_api.load_quantized(
            args.load_quantized)
        if extra.get("arch") and extra["arch"] != cfg.name:
            raise SystemExit(f"checkpoint arch {extra['arch']!r} does not "
                             f"match --arch {cfg.name!r}")
        # cfg.name is identical for reduced and full configs — compare
        # dimensions too, or a reduced checkpoint dies in the first
        # forward with an opaque shape error
        dims = {"d_model": cfg.d_model, "n_layers": cfg.n_layers,
                "vocab_size": cfg.vocab_size}
        stored = {k: extra[k] for k in dims if k in extra}
        bad = {k: (v, dims[k]) for k, v in stored.items() if v != dims[k]}
        if bad:
            raise SystemExit(
                f"checkpoint model dims do not match --arch/--reduced: "
                + ", ".join(f"{k}: ckpt {a} vs cfg {b}"
                            for k, (a, b) in bad.items()))
        if args.backend is not None:
            spec = spec.replace(backend=args.backend)
        print(f"[launch.serve] loaded quantized checkpoint "
              f"{args.load_quantized} ({spec.describe()})")
    else:
        params = model.init(jax.random.PRNGKey(0))
        spec = build_spec(args)
        if spec is None:
            if args.save_quantized:
                raise SystemExit("--save-quantized requires quantization "
                                 "(remove --bits 0)")
            print("[launch.serve] serving dense FP (no quantization)")
        else:
            t0 = time.time()
            try:
                params, manifest = quant_api.quantize_model(params, spec,
                                                            model.axes())
            except ValueError as e:   # spec errors surfaced at plan time
                raise SystemExit(f"invalid quant spec: {e}")
            print(f"[launch.serve] {spec.describe()} in "
                  f"{time.time()-t0:.1f}s")
            print(f"[launch.serve] {manifest.summary()}")
            if args.save_quantized:
                path = quant_api.save_quantized(
                    args.save_quantized, params, spec, manifest,
                    arch=cfg.name,
                    extra_meta={"d_model": cfg.d_model,
                                "n_layers": cfg.n_layers,
                                "vocab_size": cfg.vocab_size})
                print(f"[launch.serve] quantized checkpoint -> {path}")
    if args.manifest_json:
        if manifest is not None:
            manifest.save(args.manifest_json)
            print(f"[launch.serve] manifest -> {args.manifest_json}")
        else:
            print(f"[launch.serve] warning: --manifest-json ignored "
                  f"(no manifest: dense serve, or checkpoint saved "
                  f"without one)")

    if spec is not None:
        cfg = cfg.replace(quant=spec)
        model = Model(cfg)
    print(f"[launch.serve] {cfg.name}: {model.n_params():,} params, "
          f"backend preference {cfg.backend_preference}")

    on_token = None
    if args.stream:
        on_token = lambda tok, req: print(f"  [stream] req {req.uid} "
                                          f"+tok {tok}")
    engine = args.engine
    if engine == "auto":
        engine = "paged" if supports_paging(cfg) else "slots"
        print(f"[launch.serve] engine=auto -> {engine}")
    mesh = None
    if args.mesh:
        if engine != "paged":
            raise SystemExit("--mesh requires the paged engine "
                             "(SSM/hybrid, enc-dec and sliding-window "
                             "models serve single-device for now)")
        from repro.launch.mesh import parse_mesh
        from repro.parallel import sharding as shd
        try:
            mesh = parse_mesh(args.mesh, tp=args.tp)
        except ValueError as e:
            raise SystemExit(str(e))
        shd.set_activation_rules(mesh, shd.make_rules())
        print(f"[launch.serve] mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"over {mesh.devices.size} devices")
    elif args.tp:
        raise SystemExit("--tp only applies with --mesh auto")
    if args.prefix_cache is not None and engine != "paged":
        raise SystemExit("--prefix-cache requires the paged engine "
                         "(the slots engine has no shared KV pool)")
    if args.async_engine and engine != "paged":
        raise SystemExit("--async requires the paged engine (the slots "
                         "engine has no double-buffered tick)")
    if args.deadline_ms and not args.async_engine:
        raise SystemExit("--deadline-ms requires --async")
    tracer = None
    if args.trace_out:
        if engine != "paged":
            raise SystemExit("--trace-out requires the paged engine "
                             "(the slots engine has no trace hooks)")
        from repro import obs
        tracer = obs.Tracer(profiler_bridge=args.trace_profiler_bridge)
    elif args.trace_timeline or args.trace_profiler_bridge:
        raise SystemExit("--trace-timeline/--trace-profiler-bridge "
                         "require --trace-out")
    if engine == "paged":
        eng = PagedServeEngine(model, params, num_blocks=args.num_blocks,
                               block_size=args.block_size,
                               max_batch=args.max_batch,
                               max_seq_len=args.max_seq_len or args.cache_len,
                               prefill_buckets=(16, 32, 64),
                               pretune=args.pretune,
                               paged_kernel=args.paged_kernel,
                               prefix_cache=args.prefix_cache != "off",
                               mesh=mesh, tracer=tracer)
        print(f"[launch.serve] paged-kernel={args.paged_kernel} -> "
              f"decode path: {eng.decode_path}  "
              f"prefill path: {eng.prefill_path}")
    else:
        eng = ServeEngine(model, params, slots=args.slots,
                          cache_len=args.cache_len,
                          prefill_buckets=(16, 32, 64),
                          pretune=args.pretune)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (int(rng.integers(4, 24)),))
               for _ in range(args.requests)]
    t0 = time.time()
    if args.async_engine:
        done = _run_async_demo(eng, prompts, args)
    else:
        reqs = [Request(uid=i, prompt=p, max_new_tokens=args.max_new,
                        on_token=on_token)
                for i, p in enumerate(prompts)]
        done = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[launch.serve] {len(done)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s")
    if engine == "paged":
        s = eng.metrics.summary()
        print(f"[launch.serve] ttft p50={s['ttft_s']['p50']*1e3:.1f}ms "
              f"p95={s['ttft_s']['p95']*1e3:.1f}ms  "
              f"per-token p50={s['per_token_s']['p50']*1e3:.1f}ms  "
              f"occupancy mean={s['occupancy']['mean']:.2f} "
              f"peak={s['occupancy']['peak']:.2f}  "
              f"preempted={s['counters']['preempted']}")
        print(f"[launch.serve] queue delay "
              f"p50={s['queue_delay_s']['p50']*1e3:.1f}ms  "
              f"device busy fraction={s['device_busy_fraction']:.2f}  "
              f"cancelled={s['counters']['cancelled']} "
              f"deadline-expired={s['counters']['deadline_expired']}")
        pk = s["paged_kernel"]
        print(f"[launch.serve] decode path={pk['path']}  KV bytes/token: "
              f"fused={pk['kv_bytes_per_token_fused']:.0f} "
              f"gathered={pk['kv_bytes_per_token_gathered']:.0f}")
        print(f"[launch.serve] prefill path={pk['prefill_path']}  "
              f"KV bytes/prefill token: "
              f"fused={pk['kv_bytes_per_prefill_token_fused']:.0f} "
              f"gathered={pk['kv_bytes_per_prefill_token_gathered']:.0f}")
        if eng.prefix is not None:
            pc = s["prefix_cache"]
            print(f"[launch.serve] prefix cache: hit-rate "
                  f"{pc['hit_rate']:.2f}  blocks saved "
                  f"{pc['blocks_saved']}  tokens saved "
                  f"{pc['tokens_saved']}  effective capacity "
                  f"peak {s['effective_capacity']['peak']:.2f}x")
        if args.metrics_json:
            eng.metrics.to_json(args.metrics_json)
            print(f"[launch.serve] metrics -> {args.metrics_json}")
        if tracer is not None:
            from repro import obs
            obs.save_chrome(tracer, args.trace_out)
            print(f"[launch.serve] trace -> {args.trace_out} "
                  f"({len(tracer.events)} events, {tracer.dropped} "
                  f"dropped; open in https://ui.perfetto.dev)")
            if args.trace_timeline:
                print(obs.format_timeline(tracer,
                                          max_rows=args.trace_timeline))


def _run_async_demo(eng, prompts, args):
    """Serve ``prompts`` through :class:`AsyncServeFrontend`: one
    submitting coroutine per request next to the engine loop, every
    token consumed from its handle's async stream, and (with
    ``--deadline-ms``) a deadline on every third request so expiry and
    block release are exercised under real concurrency."""
    import asyncio

    from repro.serve import AsyncServeFrontend

    fe = AsyncServeFrontend(eng, max_queue=max(8, 2 * len(prompts)))

    async def client(i, prompt):
        dl = args.deadline_ms if args.deadline_ms and i % 3 == 2 else None
        h = await fe.submit(prompt, max_new_tokens=args.max_new,
                            deadline_ms=dl)
        async for tok in h:
            if args.stream:
                print(f"  [stream] req {h.uid} +tok {tok}")
        return await h.wait()

    async def run():
        loop = asyncio.ensure_future(fe.serve_forever())
        try:
            done = await asyncio.gather(
                *(client(i, p) for i, p in enumerate(prompts)))
        finally:
            fe.close()
            await loop
        return done

    done = asyncio.run(run())
    expired = [r.uid for r in done if r.error == "deadline"]
    if expired:
        print(f"[launch.serve] deadline expired: "
              f"{len(expired)} requests {expired}")
    return done


if __name__ == "__main__":
    main()
