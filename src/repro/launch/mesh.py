"""Production mesh construction.

FUNCTIONS (never module-level constants) so importing this module never
touches jax device state — required because the dry-run pins the device
count via XLA_FLAGS before any jax initialization.

``make_mesh`` is the single construction point: it papers over the
``axis_types`` API (``jax.sharding.AxisType`` only exists on newer jax
releases; on older ones every axis is implicitly Auto, which is the
type we request anyway), so meshes build identically across the jax
versions this repo runs on.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Mesh with Auto axis types on every jax version.

    Newer jax wants ``axis_types`` spelled explicitly (and sharding-in-
    types meshes default differently); jax <= 0.4.x has no ``AxisType``
    at all and every axis is Auto.  Request Auto where the API exists,
    fall back silently where it doesn't.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (single pod, 256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 0):
    """Elastic helper: best (data, model) mesh for whatever devices exist.

    Used after a failure/re-scale event: the checkpoint is topology-
    agnostic, so training resumes on the largest divisor mesh.
    """
    if model_parallel <= 0:
        model_parallel = min(16, n_devices)
    while n_devices % model_parallel:
        model_parallel //= 2
    data = n_devices // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"))


def parse_mesh(spec: str, tp: int = 0):
    """Build a serving mesh from a CLI flag.

    ``spec`` is either ``"auto"`` (largest ``(data, model)`` divisor mesh
    over whatever devices exist, with ``tp`` pinning the model axis) or
    an explicit ``"DxM"`` shape like ``"2x4"`` (data x model; must
    multiply to the visible device count).
    """
    if spec == "auto":
        n = len(jax.devices())
        if tp and n % tp:
            # make_mesh_for would silently halve tp down to a divisor —
            # an explicit request for a model-parallel extent must not
            # degrade to less (or no) TP without the operator noticing
            raise ValueError(f"--tp {tp} does not divide the {n} visible "
                             f"devices")
        return make_mesh_for(n, tp)
    try:
        data, model = (int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"--mesh expects 'auto' or 'DxM' (e.g. 2x4), "
                         f"got {spec!r}")
    if tp and tp != model:
        raise ValueError(f"--tp {tp} contradicts --mesh {spec} "
                         f"(model axis {model})")
    n = len(jax.devices())
    if data * model != n:
        raise ValueError(f"--mesh {spec} needs {data * model} devices, "
                         f"found {n} (hint: "
                         f"--xla_force_host_platform_device_count)")
    return make_mesh((data, model), ("data", "model"))
