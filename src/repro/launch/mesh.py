"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state — required because the dry-run pins the device
count via XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (single pod, 256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for(n_devices: int, model_parallel: int = 0):
    """Elastic helper: best (data, model) mesh for whatever devices exist.

    Used after a failure/re-scale event: the checkpoint is topology-
    agnostic, so training resumes on the largest divisor mesh.
    """
    if model_parallel <= 0:
        model_parallel = min(16, n_devices)
    while n_devices % model_parallel:
        model_parallel //= 2
    data = n_devices // model_parallel
    return jax.make_mesh(
        (data, model_parallel), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
