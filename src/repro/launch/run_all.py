"""Run the full dry-run matrix: every (arch x shape) on single-pod
(+roofline) and multi-pod (compile proof).  Each cell runs in a fresh
subprocess (jax locks the fake-device count at first init) with a
timeout; results land in ``results_dir`` as one JSON per cell.

  PYTHONPATH=src python -m repro.launch.run_all [--out benchmarks/results/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def cells():
    from repro.configs import ARCH_IDS, SHAPES, get_config
    for arch in ARCH_IDS:
        if arch == "opt_6_7b":
            continue                      # paper arch: bench suite covers it
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape == "long_500k" and not cfg.supports_long_context():
                continue
            yield arch, shape


def run_cell(arch, shape, multi_pod, out_dir, timeout=1500, extra=()):
    tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
    out_json = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_json):
        print(f"[run_all] skip {tag} (exists)")
        return True
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--json-out", out_json]
    if multi_pod:
        cmd += ["--multi-pod", "--no-roofline"]
    cmd += list(extra)
    t0 = time.time()
    try:
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout,
                           env={**os.environ, "PYTHONPATH": "src"})
        ok = r.returncode == 0
        if not ok:
            skip = "SKIP:" in (r.stdout + r.stderr)
            with open(out_json.replace(".json", ".log"), "w") as f:
                f.write(r.stdout + "\n---STDERR---\n" + r.stderr)
            if skip:
                with open(out_json, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "multi_pod": multi_pod, "skipped": True}, f)
                print(f"[run_all] {tag}: SKIP (documented)")
                return True
    except subprocess.TimeoutExpired:
        ok = False
        with open(out_json.replace(".json", ".log"), "w") as f:
            f.write(f"TIMEOUT after {timeout}s")
    print(f"[run_all] {tag}: {'OK' if ok else 'FAIL'} "
          f"({time.time()-t0:.0f}s)")
    return ok


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="benchmarks/results/dryrun")
    p.add_argument("--timeout", type=int, default=1500)
    p.add_argument("--only", default="", help="substring filter on arch")
    p.add_argument("--multi-only", action="store_true")
    p.add_argument("--single-only", action="store_true")
    args = p.parse_args()
    os.makedirs(args.out, exist_ok=True)
    results = {}
    for arch, shape in cells():
        if args.only and args.only not in arch:
            continue
        if not args.multi_only:
            results[(arch, shape, "single")] = run_cell(
                arch, shape, False, args.out, args.timeout)
        if not args.single_only:
            results[(arch, shape, "multi")] = run_cell(
                arch, shape, True, args.out, args.timeout)
    n_ok = sum(results.values())
    print(f"[run_all] {n_ok}/{len(results)} cells OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
