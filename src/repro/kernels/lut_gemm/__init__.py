from .ops import lut_gemm
from .lut_gemm import lut_gemm_tiled
from . import ref

__all__ = ["lut_gemm", "lut_gemm_tiled", "ref"]
