"""Pure-jnp oracle for LUT-based FP-INT GEMM (paper §III-A).

Computes  y = x @ dequant(W).T  two ways:

  * ``dense_ref``   — dequantize to dense FP and matmul (the "GPU engine"
                      column of Table IV; ground truth).
  * ``lut_ref``     — literally builds the LUTs and performs keyed
                      read-accumulate per bit-plane (what the Pallas kernel
                      must match bit-for-bit up to FP reassociation).

Math:  with BCQ  W[m,n] = sum_i alpha[i,m,G(n)] B_i[m,n] + z[m,G(n)],

  y[b,m] = sum_i sum_G alpha[i,m,G] * ( sum_{g in G} LUT_b[g, key_i[m,g]] )
         + sum_G z[m,G] * S_b[G]

where LUT_b[g,p] = sum_j sign_j(p) x[b, g*mu+j]  and  S_b[G] = sum_{n in G} x[b,n]
(the offset term folds into a per-group activation sum — "accumulated sums
summed with the offset value", §III-B).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bcq as bcq_mod
from repro.core import lut as lut_mod


def dense_ref(x: jax.Array, w: bcq_mod.BCQWeight, out_dtype=None) -> jax.Array:
    """Ground truth: dequantize then dense matmul (FP32 accumulate)."""
    dense = bcq_mod.dequantize(w, dtype=jnp.float32)         # [out, in]
    y = jnp.einsum("...n,mn->...m", x.astype(jnp.float32), dense,
                   preferred_element_type=jnp.float32)
    return y.astype(out_dtype or x.dtype)


def lut_ref(x: jax.Array, w: bcq_mod.BCQWeight, mu: int = 4,
            half_lut: bool = True, out_dtype=None) -> jax.Array:
    """LUT-based evaluation — table build + read-accumulate, FP32 acc.

    x: [..., in_features]. Returns [..., out_features].
    """
    if w.group_size % mu:
        raise ValueError(f"group_size {w.group_size} must be divisible by mu={mu}")
    xf = x.astype(jnp.float32)
    n_pad = w.packed.shape[-1] * 8
    if xf.shape[-1] != n_pad:                                 # zero-pad to match
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, n_pad - xf.shape[-1])])

    lead = xf.shape[:-1]
    xf2 = xf.reshape(-1, n_pad)                               # [B, N]
    q = w.bits
    keys = lut_mod.keys_from_packed(w.packed, mu)             # [q, M, N/mu]

    if half_lut:
        table = lut_mod.build_half_lut(xf2, mu)               # [B, G, 2^(mu-1)]
        def read(keys_i):                                     # [M, G] -> [B, M, G]
            return jax.vmap(
                lambda t: lut_mod.decode_half_lut(t[None].repeat(keys_i.shape[0], 0), keys_i, mu)
            )(table)
    else:
        table = lut_mod.build_lut(xf2, mu)                    # [B, G, 2^mu]
        def read(keys_i):
            def one_batch(t):                                 # t: [G, 2^mu]
                return jnp.take_along_axis(t, keys_i.T, axis=-1).T  # [M, G]
            return jax.vmap(one_batch)(table)

    n_groups_mu = n_pad // mu
    per_ag = w.group_size // mu                               # mu-groups per alpha-group
    n_ag = w.n_groups

    y = jnp.zeros((xf2.shape[0], w.out_features), jnp.float32)
    for i in range(q):
        vals = read(keys[i])                                  # [B, M, G_mu]
        vals_ag = vals.reshape(*vals.shape[:-1], n_ag, per_ag).sum(-1)  # [B,M,AG]
        y = y + jnp.einsum("bma,ma->bm", vals_ag, w.alpha[i])
    # offset term: z[m,AG] * sum of x over the alpha-group
    xsum_ag = xf2.reshape(xf2.shape[0], n_ag, w.group_size).sum(-1)     # [B, AG]
    y = y + jnp.einsum("ba,ma->bm", xsum_ag, w.z)
    return y.reshape(*lead, w.out_features).astype(out_dtype or x.dtype)
