"""Public jit'd wrapper for the FIGLUT Pallas kernel.

Handles arbitrary leading batch dims, pads (B, M, N) up to block multiples,
and dispatches to :func:`lut_gemm_tiled`.  The oracle for every path is
``ref.lut_ref`` / ``ref.dense_ref``.

Launch geometry (block sizes, read mode, hFFLUT) is no longer hard-coded:
any parameter left as ``None`` is resolved through
:func:`repro.tune.dispatch.kernel_config` — tuned JSON-cache entry if one
exists for this (batch-bucket, M, N, dtype, mu, group, device) point,
deterministic heuristic otherwise.  Explicit arguments always win, so
tests and the tuner itself can pin exact launches.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bcq import BCQWeight
from repro.core.plane import tile_operands
from repro.tune import dispatch as _dispatch
from . import lut_gemm as _k


def lut_gemm(x: jax.Array, w: BCQWeight, *, mu: int = 4,
             half_lut: Optional[bool] = None, read_mode: Optional[str] = None,
             block_b: Optional[int] = None, block_m: Optional[int] = None,
             block_n: Optional[int] = None, interpret: bool = False,
             out_dtype=None) -> jax.Array:
    """y = x @ dequant(w).T via the FIGLUT Pallas kernel.

    x: [..., in_features] -> [..., out_features].  FP32 accumulation.
    """
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    n_logical = x.shape[-1]
    if n_logical != w.in_features:
        raise ValueError(f"x last dim {n_logical} != in_features {w.in_features}")

    x2 = x.reshape(-1, n_logical)
    b = x2.shape[0]

    if None in (half_lut, read_mode, block_b, block_m, block_n):
        cfg = _dispatch.kernel_config(
            "lut_gemm", b=b, m=w.out_features, n=w.in_features,
            dtype=x2.dtype, mu=mu, group_size=w.group_size,
            interpret=interpret, operands=(x2, w))
        half_lut = cfg.half_lut if half_lut is None else half_lut
        read_mode = cfg.read_mode if read_mode is None else read_mode
        block_b = cfg.block_b if block_b is None else block_b
        block_m = cfg.block_m if block_m is None else block_m
        block_n = cfg.block_n if block_n is None else block_n

    xp, packed, alpha, z, b, m, block_m, block_n = tile_operands(
        x2, w, block_b=block_b, block_m=block_m, block_n=block_n)

    y = _k.lut_gemm_tiled(
        xp, packed, alpha, z, mu=mu, half_lut=half_lut,
        group_size=w.group_size, read_mode=read_mode, block_b=block_b,
        block_m=block_m, block_n=block_n, interpret=interpret,
        out_dtype=jnp.float32,
    )
    return y[:b, :m].reshape(*lead, m).astype(out_dtype)
