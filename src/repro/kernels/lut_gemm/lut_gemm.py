"""Pallas TPU kernel for FIGLUT's LUT-based FP-INT GEMM (paper §III).

Per (batch-tile, out-tile, in-tile) grid cell:

  1. **LUT generation** (§III-E): the activation tile x[TB, TN] is reshaped
     into mu-groups and multiplied by the +-1 sign matrix — a (G, mu)x(mu, P)
     matmul that runs on the MXU, the systolic analogue of the paper's
     two-step adder tree.  With ``half_lut=True`` only the MSB=1 half of the
     table is built (hFFLUT, §III-D).
  2. **RAC** (§III-C): every output row's mu-bit weight pattern keys a read
     from the VMEM-resident LUT.  VMEM has no banking at the Pallas
     programming-model level, so k = TM concurrent readers are conflict-free
     by construction — the software realization of the FFLUT+mux design.
     Reads are implemented either as a 2^mu-way select sweep (``select``,
     VPU, mirrors the paper's mux) or as a one-hot contraction (``onehot``,
     MXU-friendly).
  3. **bit-serial accumulate** (§III-B): plane value sums are grouped per
     alpha-group, scaled by alpha_i, and accumulated in FP32; the offset term
     z * sum(x_group) (Eq. (3)) is folded in once per tile.

Storage streamed from HBM is the *packed* uint8 bit-planes — q/16 of the
bf16 dense bytes — which is the memory-roofline win on TPU (DESIGN.md §2).

Weight-stationary note: the grid iterates n (reduction) innermost and m
before b, so a weight tile's packed planes stay resident while batch tiles
stream — matching the paper's weight-stationary dataflow (§III-B) at the
granularity Pallas exposes (block revisiting, not per-PE registers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# LUT build / key extraction / half-table sign-decode read are shared
# with the dedicated ternary kernel — one home for the hFFLUT math.
from repro.kernels.lut_common import (ReadMode, build_lut, extract_keys,
                                      read_lut)


def _lut_gemm_kernel(x_ref, packed_ref, alpha_ref, z_ref, o_ref, *,
                     mu: int, half_lut: bool, group_size: int,
                     read_mode: ReadMode, n_grid: int):
    q = packed_ref.shape[0]
    tb, tn = x_ref.shape
    tm = packed_ref.shape[1]
    tag = alpha_ref.shape[-1]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                    # [TB, TN]

    # -- 1. LUT generation (MXU): groups @ S^T ----------------------------
    lut = build_lut(x, mu, half_lut)                      # [TB, G, P]

    # -- 2/3. per-plane RAC + alpha accumulate ----------------------------
    per_ag = group_size // mu
    acc = jnp.zeros((tb, tm), jnp.float32)
    for i in range(q):
        keys = extract_keys(packed_ref[i], mu)            # [TM, G]
        vals = read_lut(lut, keys, mu, half_lut, read_mode)    # [TB, TM, G]
        vals_ag = vals.reshape(tb, tm, tag, per_ag).sum(-1)    # [TB, TM, AG]
        alpha_i = alpha_ref[i].astype(jnp.float32)        # [TM, AG]
        acc = acc + jnp.einsum("bma,ma->bm", vals_ag, alpha_i,
                               preferred_element_type=jnp.float32)
    # offset term  z[m,AG] * sum_G x   (Eq. (3))
    xsum = x.reshape(tb, tag, group_size).sum(-1)         # [TB, AG]
    acc = acc + jnp.einsum("ba,ma->bm", xsum, z_ref[...].astype(jnp.float32),
                           preferred_element_type=jnp.float32)

    o_ref[...] += acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mu", "half_lut", "group_size", "read_mode",
                     "block_b", "block_m", "block_n", "interpret", "out_dtype"),
)
def lut_gemm_tiled(x, packed, alpha, z, *, mu: int = 4, half_lut: bool = True,
                   group_size: int = 128, read_mode: ReadMode = "onehot",
                   block_b: int = 8, block_m: int = 128, block_n: int = 512,
                   interpret: bool = False, out_dtype=jnp.float32):
    """Raw tiled kernel call. All dims must already divide the block sizes.

    x: [B, N] fp; packed: uint8[q, M, N//8]; alpha: f32[q, M, N//group_size];
    z: f32[M, N//group_size].  Returns [B, M] out_dtype (FP32 accumulation).
    """
    b, n = x.shape
    q, m, _ = packed.shape
    assert n % block_n == 0 and m % block_m == 0 and b % block_b == 0, (
        f"shapes ({b},{m},{n}) must divide blocks ({block_b},{block_m},{block_n})")
    assert block_n % group_size == 0 and group_size % mu == 0
    tag = block_n // group_size
    grid = (b // block_b, m // block_m, n // block_n)

    kernel = functools.partial(
        _lut_gemm_kernel, mu=mu, half_lut=half_lut, group_size=group_size,
        read_mode=read_mode, n_grid=grid[2])

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda bi, mi, ni: (bi, ni)),
            pl.BlockSpec((q, block_m, block_n // 8),
                         lambda bi, mi, ni: (0, mi, ni)),
            pl.BlockSpec((q, block_m, tag), lambda bi, mi, ni: (0, mi, ni)),
            pl.BlockSpec((block_m, tag), lambda bi, mi, ni: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda bi, mi, ni: (bi, mi)),
        out_shape=jax.ShapeDtypeStruct((b, m), out_dtype),
        interpret=interpret,
    )(x, packed, alpha, z)
