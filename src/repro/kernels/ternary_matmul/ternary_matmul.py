"""Pallas TPU kernel for the dedicated ternary (1.58-bit) fast path.

The ternary bundle stores ONE sign plane + ONE nonzero-mask plane and a
single shared-magnitude alpha row (``core.plane.PlaneBundle`` with
``kind="ternary"``) — strictly fewer HBM bytes than the generic 2-plane
BCQ encoding it replaces.  This kernel exploits that ±α structure
in-kernel instead of riding the generic bit-serial path:

  1. **half-LUT build** (§III-D/E): one half-size activation table per
     mu-group, shared by both derived planes — the hFFLUT symmetry
     LUT[p] = -LUT[2^mu-1-p] means ternary pays ONE table for what the
     generic 2-bit path reads as two.
  2. **in-kernel sign decode** (the paper's sign-decoding unit): the
     BCQ planes b1 = sign | ~mask, b2 = sign & mask are derived with two
     bitwise byte ops from the stored (sign, mask) bytes — no second
     stored plane, no second alpha row (``lut_common.ternary_plane_bytes``).
  3. **single-alpha accumulate**: y += (a/2) * (V1 + V2) per alpha
     group; there is no offset term (ternary has none), so the z row,
     its DMA and its epilogue einsum all disappear.

Per-tile arithmetic vs the generic lut_gemm at q=2: one LUT build
instead of one, two keyed reads (same), ONE alpha einsum instead of
two, no offset einsum — plus 2/3 of the scale-row traffic and no z row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lut_common import (ReadMode, build_lut, extract_keys,
                                      read_lut, ternary_plane_bytes)


def _ternary_matmul_kernel(x_ref, packed_ref, alpha_ref, o_ref, *,
                           mu: int, group_size: int, read_mode: ReadMode):
    tb, tn = x_ref.shape
    tm = packed_ref.shape[1]
    tag = alpha_ref.shape[-1]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                    # [TB, TN]

    # -- 1. one half-size LUT for both derived planes ---------------------
    lut = build_lut(x, mu, half=True)                     # [TB, G, P/2]

    # -- 2. sign decode: (sign, mask) bytes -> b1/b2 plane bytes ----------
    b1, b2 = ternary_plane_bytes(packed_ref[0], packed_ref[1])
    vals = (read_lut(lut, extract_keys(b1, mu), mu, True, read_mode)
            + read_lut(lut, extract_keys(b2, mu), mu, True, read_mode))

    # -- 3. single-alpha accumulate:  y += (a/2) (V1 + V2) ----------------
    per_ag = group_size // mu
    vals_ag = vals.reshape(tb, tm, tag, per_ag).sum(-1)   # [TB, TM, AG]
    half_alpha = alpha_ref[0].astype(jnp.float32) * 0.5   # [TM, AG]
    acc = jnp.einsum("bma,ma->bm", vals_ag, half_alpha,
                     preferred_element_type=jnp.float32)
    o_ref[...] += acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mu", "group_size", "read_mode", "block_b", "block_m",
                     "block_n", "interpret", "out_dtype"),
)
def ternary_matmul_tiled(x, packed, alpha, *, mu: int = 4,
                         group_size: int = 128, read_mode: ReadMode = "onehot",
                         block_b: int = 8, block_m: int = 128,
                         block_n: int = 512, interpret: bool = False,
                         out_dtype=jnp.float32):
    """Raw tiled kernel call. All dims must already divide the block sizes.

    x: [B, N] fp; packed: uint8[2, M, N//8] (plane 0 = sign, plane 1 =
    mask); alpha: f32[1, M, N//group_size].  Returns [B, M] out_dtype
    (FP32 accumulation).
    """
    b, n = x.shape
    q, m, _ = packed.shape
    assert q == 2, f"ternary bundle stores sign+mask planes, got {q}"
    assert alpha.shape[0] == 1, "ternary carries a single alpha row"
    assert n % block_n == 0 and m % block_m == 0 and b % block_b == 0, (
        f"shapes ({b},{m},{n}) must divide blocks "
        f"({block_b},{block_m},{block_n})")
    assert block_n % group_size == 0 and group_size % mu == 0
    tag = block_n // group_size
    grid = (b // block_b, m // block_m, n // block_n)

    kernel = functools.partial(
        _ternary_matmul_kernel, mu=mu, group_size=group_size,
        read_mode=read_mode)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda bi, mi, ni: (bi, ni)),
            pl.BlockSpec((2, block_m, block_n // 8),
                         lambda bi, mi, ni: (0, mi, ni)),
            pl.BlockSpec((1, block_m, tag), lambda bi, mi, ni: (0, mi, ni)),
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda bi, mi, ni: (bi, mi)),
        out_shape=jax.ShapeDtypeStruct((b, m), out_dtype),
        interpret=interpret,
    )(x, packed, alpha)
