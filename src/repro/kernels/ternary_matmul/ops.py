"""Public jit'd wrapper for the ternary fast-path kernel.

Accepts only ``kind="ternary"`` plane bundles (sign + mask planes, one
alpha row, no offset).  Launch geometry left as ``None`` resolves
through :func:`repro.tune.dispatch.kernel_config` under the
``"ternary_matmul"`` kernel name (tuned cache entry or the heuristic);
explicit arguments always win.  Tile padding is the shared
:func:`repro.core.plane.tile_operands` admission step — no layout math
lives here.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.plane import PlaneBundle, tile_operands
from repro.tune import dispatch as _dispatch
from . import ternary_matmul as _k


def ternary_matmul(x: jax.Array, w: PlaneBundle, *, mu: int = 4,
                   read_mode: Optional[str] = None,
                   block_b: Optional[int] = None,
                   block_m: Optional[int] = None,
                   block_n: Optional[int] = None, interpret: bool = False,
                   out_dtype=None) -> jax.Array:
    """y = x @ dequant(w).T via the dedicated ternary Pallas kernel.

    x: [..., in_features] -> [..., out_features].  FP32 accumulation.
    """
    if w.kind != "ternary":
        raise ValueError(
            f"ternary_matmul needs a kind='ternary' bundle, got {w.kind!r}; "
            "generic BCQ weights take the lut_gemm/bcq_matmul kernels")
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    n_logical = x.shape[-1]
    if n_logical != w.in_features:
        raise ValueError(f"x last dim {n_logical} != in_features {w.in_features}")

    x2 = x.reshape(-1, n_logical)
    b = x2.shape[0]

    if None in (read_mode, block_b, block_m, block_n):
        cfg = _dispatch.kernel_config(
            "ternary_matmul", b=b, m=w.out_features, n=w.in_features,
            dtype=x2.dtype, mu=mu, group_size=w.group_size,
            interpret=interpret, operands=(x2, w))
        read_mode = cfg.read_mode if read_mode is None else read_mode
        block_b = cfg.block_b if block_b is None else block_b
        block_m = cfg.block_m if block_m is None else block_m
        block_n = cfg.block_n if block_n is None else block_n

    xp, packed, alpha, _, b, m, block_m, block_n = tile_operands(
        x2, w, block_b=block_b, block_m=block_m, block_n=block_n)

    y = _k.ternary_matmul_tiled(
        xp, packed, alpha, mu=mu, group_size=w.group_size,
        read_mode=read_mode, block_b=block_b, block_m=block_m,
        block_n=block_n, interpret=interpret, out_dtype=jax.numpy.float32)
    return y[:b, :m].reshape(*lead, m).astype(out_dtype)
