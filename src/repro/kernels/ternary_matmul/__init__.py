from .ops import ternary_matmul
from .ternary_matmul import ternary_matmul_tiled
from .ref import dense_ref, ternary_ref

__all__ = ["ternary_matmul", "ternary_matmul_tiled", "dense_ref",
           "ternary_ref"]
