"""Oracles for the ternary kernel: dense dequant + gathered LUT walk.

``dense_ref`` is ground truth (dequantize -> FP32 matmul).
``ternary_ref`` performs the exact evaluation the Pallas kernel claims:
half-LUT build, in-kernel-style sign decode of the (sign, mask) planes
into b1/b2 keys, *gathered* table reads, single-alpha accumulate.  The
kernel must match it bit-for-bit when the arithmetic is exact (integer
activations, power-of-two alphas) — the exactness matrix in
tests/test_plane.py pins that.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lut as lut_mod
from repro.core import plane as plane_mod


def dense_ref(x: jax.Array, w: plane_mod.PlaneBundle, out_dtype=None) -> jax.Array:
    """Ground truth: dequantize then dense matmul (FP32 accumulate)."""
    dense = plane_mod.dequantize(w, dtype=jnp.float32)       # [out, in]
    y = jnp.einsum("...n,mn->...m", x.astype(jnp.float32), dense,
                   preferred_element_type=jnp.float32)
    return y.astype(out_dtype or x.dtype)


def _derived_plane_bytes(packed: np.ndarray):
    """(sign, mask) uint8 planes -> (b1, b2) BCQ plane bytes (host-side)."""
    s = packed[0].astype(np.int32)
    m = packed[1].astype(np.int32)
    b1 = (s | (~m & 0xFF)) & 0xFF
    b2 = s & m
    return b1.astype(np.uint8), b2.astype(np.uint8)


def ternary_ref(x: jax.Array, w: plane_mod.PlaneBundle, mu: int = 4,
                out_dtype=None) -> jax.Array:
    """Gathered-oracle evaluation of the ternary LUT datapath.

    x: [..., in_features]. Returns [..., out_features].
    """
    if w.kind != "ternary":
        raise ValueError(f"ternary_ref needs a ternary bundle, got {w.kind!r}")
    if w.group_size % mu:
        raise ValueError(f"group_size {w.group_size} must divide mu={mu}")
    xf = x.astype(jnp.float32)
    n_pad = w.packed.shape[-1] * 8
    if xf.shape[-1] != n_pad:                                # zero-pad to match
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, n_pad - xf.shape[-1])])
    lead = xf.shape[:-1]
    xf2 = xf.reshape(-1, n_pad)                              # [B, N]

    b1, b2 = _derived_plane_bytes(np.asarray(w.packed))
    keys = lut_mod.keys_from_packed(
        jnp.stack([jnp.asarray(b1), jnp.asarray(b2)]), mu)   # [2, M, G_mu]

    table = lut_mod.build_half_lut(xf2, mu)                  # [B, G, 2^(mu-1)]

    def read(keys_i):                                        # [M, G] -> [B, M, G]
        return jax.vmap(
            lambda t: lut_mod.decode_half_lut(
                t[None].repeat(keys_i.shape[0], 0), keys_i, mu)
        )(table)

    per_ag = w.group_size // mu
    n_ag = w.n_groups
    vals = read(keys[0]) + read(keys[1])                     # [B, M, G_mu]
    vals_ag = vals.reshape(*vals.shape[:-1], n_ag, per_ag).sum(-1)
    y = jnp.einsum("bma,ma->bm", vals_ag, w.alpha[0] * 0.5)
    return y.reshape(*lead, w.out_features).astype(out_dtype or x.dtype)
