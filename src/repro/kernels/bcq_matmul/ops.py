"""Public jit'd wrapper for the BCQ dequant-in-VMEM matmul kernel.

Block sizes left as ``None`` resolve through
:func:`repro.tune.dispatch.kernel_config` (tuned cache entry or the
deterministic heuristic); explicit arguments always win.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bcq import BCQWeight
from repro.tune import dispatch as _dispatch
from . import bcq_matmul as _k


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def bcq_matmul(x: jax.Array, w: BCQWeight, *, block_b: Optional[int] = None,
               block_m: Optional[int] = None, block_n: Optional[int] = None,
               interpret: bool = False, out_dtype=None) -> jax.Array:
    """y = x @ dequant(w).T via the TPU-native packed-weight kernel."""
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    n_logical = x.shape[-1]
    if n_logical != w.in_features:
        raise ValueError(f"x last dim {n_logical} != in_features {w.in_features}")

    x2 = x.reshape(-1, n_logical)
    b = x2.shape[0]

    if None in (block_b, block_m, block_n):
        cfg = _dispatch.kernel_config(
            "bcq_matmul", b=b, m=w.out_features, n=w.in_features,
            dtype=x2.dtype, mu=0, group_size=w.group_size,
            interpret=interpret, operands=(x2, w))
        block_b = cfg.block_b if block_b is None else block_b
        block_m = cfg.block_m if block_m is None else block_m
        block_n = cfg.block_n if block_n is None else block_n

    q, m, _ = w.packed.shape
    n_pad_w = w.packed.shape[-1] * 8
    ag = w.alpha.shape[-1]

    bp = _round_up(b, block_b)
    block_n = min(block_n, _round_up(n_pad_w, w.group_size))
    npad = _round_up(n_pad_w, block_n)
    block_m = min(block_m, _round_up(m, 8))
    mp = _round_up(m, block_m)
    agp = npad // w.group_size

    xp = jnp.zeros((bp, npad), x2.dtype).at[:b, :n_logical].set(x2)
    packed, alpha, z = w.packed, w.alpha, w.z
    if npad != n_pad_w or mp != m or agp != ag:
        packed = jnp.zeros((q, mp, npad // 8), jnp.uint8).at[:, :m, : n_pad_w // 8].set(packed)
        alpha = jnp.zeros((q, mp, agp), alpha.dtype).at[:, :m, :ag].set(alpha)
        z = jnp.zeros((mp, agp), z.dtype).at[:m, :ag].set(z)

    y = _k.bcq_matmul_tiled(
        xp, packed, alpha, z, group_size=w.group_size, block_b=block_b,
        block_m=block_m, block_n=block_n, interpret=interpret,
        out_dtype=jnp.float32)
    return y[:b, :m].reshape(*lead, m).astype(out_dtype)
