"""Public jit'd wrapper for the BCQ dequant-in-VMEM matmul kernel.

Block sizes left as ``None`` resolve through
:func:`repro.tune.dispatch.kernel_config` (tuned cache entry or the
deterministic heuristic); explicit arguments always win.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bcq import BCQWeight
from repro.core.plane import tile_operands
from repro.tune import dispatch as _dispatch
from . import bcq_matmul as _k


def bcq_matmul(x: jax.Array, w: BCQWeight, *, block_b: Optional[int] = None,
               block_m: Optional[int] = None, block_n: Optional[int] = None,
               interpret: bool = False, out_dtype=None) -> jax.Array:
    """y = x @ dequant(w).T via the TPU-native packed-weight kernel."""
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    n_logical = x.shape[-1]
    if n_logical != w.in_features:
        raise ValueError(f"x last dim {n_logical} != in_features {w.in_features}")

    x2 = x.reshape(-1, n_logical)
    b = x2.shape[0]

    if None in (block_b, block_m, block_n):
        cfg = _dispatch.kernel_config(
            "bcq_matmul", b=b, m=w.out_features, n=w.in_features,
            dtype=x2.dtype, mu=0, group_size=w.group_size,
            interpret=interpret, operands=(x2, w))
        block_b = cfg.block_b if block_b is None else block_b
        block_m = cfg.block_m if block_m is None else block_m
        block_n = cfg.block_n if block_n is None else block_n

    xp, packed, alpha, z, b, m, block_m, block_n = tile_operands(
        x2, w, block_b=block_b, block_m=block_m, block_n=block_n)

    y = _k.bcq_matmul_tiled(
        xp, packed, alpha, z, group_size=w.group_size, block_b=block_b,
        block_m=block_m, block_n=block_n, interpret=interpret,
        out_dtype=jnp.float32)
    return y[:b, :m].reshape(*lead, m).astype(out_dtype)
