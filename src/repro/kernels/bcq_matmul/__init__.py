from .ops import bcq_matmul
from .bcq_matmul import bcq_matmul_tiled
from . import ref

__all__ = ["bcq_matmul", "bcq_matmul_tiled", "ref"]
