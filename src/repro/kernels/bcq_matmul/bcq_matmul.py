"""Beyond-paper TPU-native execution of BCQ weights: dequant-in-VMEM matmul.

FIGLUT's LUT read replaces an FP adder — a win for CMOS energy, but a TPU's
MXU performs a 128x128 systolic matmul at fixed cost whether operands are
+-1 or arbitrary bf16.  The *transferable* win of the BCQ format on TPU is
that weights live in HBM as packed uint8 bit-planes (q/16 of bf16 bytes):
LLM decode is memory-bound, so cutting weight bytes moves the memory-
roofline term directly (DESIGN.md §2).

This kernel streams packed planes HBM->VMEM, reconstructs the dense weight
tile in VMEM (q shift/mask unpacks + alpha-scaled accumulate + offset), and
issues a single MXU matmul per tile.  Same math as the LUT kernel, same
compressed storage, MXU-optimal compute — it is the "optimized version"
reported next to the paper-faithful kernel in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _unpack_pm1(packed_tile: jax.Array) -> jax.Array:
    """uint8[TM, TN//8] -> f32 {-1,+1} [TM, TN] (LSB-first)."""
    tm, nb = packed_tile.shape
    p32 = packed_tile.astype(jnp.int32)
    cols = [((p32 >> s) & 1) for s in range(8)]
    bits = jnp.stack(cols, axis=-1).reshape(tm, nb * 8)
    return bits.astype(jnp.float32) * 2.0 - 1.0


def _bcq_matmul_kernel(x_ref, packed_ref, alpha_ref, z_ref, o_ref, *,
                       group_size: int):
    q = packed_ref.shape[0]
    tb, tn = x_ref.shape
    tm = packed_ref.shape[1]
    tag = alpha_ref.shape[-1]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # dequantize the weight tile in VMEM:  W = sum_i alpha_i * B_i + z
    w = jnp.zeros((tm, tn), jnp.float32)
    for i in range(q):
        pm1 = _unpack_pm1(packed_ref[i])                     # [TM, TN]
        alpha_cols = jnp.broadcast_to(
            alpha_ref[i][:, :, None].astype(jnp.float32),
            (tm, tag, group_size)).reshape(tm, tn)
        w = w + alpha_cols * pm1
    z_cols = jnp.broadcast_to(
        z_ref[...][:, :, None].astype(jnp.float32),
        (tm, tag, group_size)).reshape(tm, tn)
    w = w + z_cols

    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += lax.dot_general(
        x, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "block_b", "block_m", "block_n",
                     "interpret", "out_dtype"),
)
def bcq_matmul_tiled(x, packed, alpha, z, *, group_size: int = 128,
                     block_b: int = 8, block_m: int = 128, block_n: int = 512,
                     interpret: bool = False, out_dtype=jnp.float32):
    """Raw tiled call; dims must divide blocks. x:[B,N] -> [B,M]."""
    b, n = x.shape
    q, m, _ = packed.shape
    assert n % block_n == 0 and m % block_m == 0 and b % block_b == 0
    assert block_n % group_size == 0
    tag = block_n // group_size
    grid = (b // block_b, m // block_m, n // block_n)
    kernel = functools.partial(_bcq_matmul_kernel, group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda bi, mi, ni: (bi, ni)),
            pl.BlockSpec((q, block_m, block_n // 8),
                         lambda bi, mi, ni: (0, mi, ni)),
            pl.BlockSpec((q, block_m, tag), lambda bi, mi, ni: (0, mi, ni)),
            pl.BlockSpec((block_m, tag), lambda bi, mi, ni: (mi, ni)),
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda bi, mi, ni: (bi, mi)),
        out_shape=jax.ShapeDtypeStruct((b, m), out_dtype),
        interpret=interpret,
    )(x, packed, alpha, z)
