"""Oracle for bcq_matmul: dense dequantized matmul (FP32 accumulate)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bcq as bcq_mod


def bcq_matmul_ref(x: jax.Array, w: bcq_mod.BCQWeight, out_dtype=None) -> jax.Array:
    dense = bcq_mod.dequantize(w, dtype=jnp.float32)
    y = jnp.einsum("...n,mn->...m", x.astype(jnp.float32), dense,
                   preferred_element_type=jnp.float32)
    return y.astype(out_dtype or x.dtype)
