"""Shared in-kernel LUT machinery (FIGLUT §III-C/D/E).

The LUT build, the mu-bit key extraction from packed planes, and the
half-table sign-decoding read (hFFLUT) are the same math for every
LUT-consuming kernel — the generic ``lut_gemm`` bit-serial kernel and
the dedicated ``ternary_matmul`` fast path both import from here, so
the half-LUT sign trick lives in exactly one place.

Everything in this module is Pallas-safe: 2-D iota only, MXU
contractions via ``lax.dot_general`` with f32 accumulation, no gathers
unless the ``gather`` read mode is explicitly requested.  The host-side
reference implementations of the same math live in ``repro.core.lut``.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax

ReadMode = Literal["select", "onehot", "gather"]


def sign_matrix(mu: int, half: bool, dtype):
    """±1 sign matrix built from 2-D iota (TPU requires >=2-D iota)."""
    rows = (1 << (mu - 1)) if half else (1 << mu)
    base = (1 << (mu - 1)) if half else 0
    p = lax.broadcasted_iota(jnp.int32, (rows, mu), 0) + base
    j = lax.broadcasted_iota(jnp.int32, (rows, mu), 1)
    return (((p >> j) & 1) * 2 - 1).astype(dtype)


def build_lut(x_tile: jax.Array, mu: int, half: bool) -> jax.Array:
    """Activation tile [TB, TN] -> LUT [TB, TN//mu, P] (§III-E).

    The (groups x S^T) contraction runs on the MXU — the systolic
    analogue of the paper's two-step adder tree.  With ``half=True``
    only the MSB=1 rows are materialized (hFFLUT, §III-D).
    """
    tb, tn = x_tile.shape
    g = tn // mu
    s = sign_matrix(mu, half, jnp.float32)                # [P, mu]
    groups = x_tile.reshape(tb * g, mu)
    lut = lax.dot_general(groups, s,
                          dimension_numbers=(((1,), (1,)), ((), ())),
                          preferred_element_type=jnp.float32)
    return lut.reshape(tb, g, -1)                         # [TB, G, P]


def extract_keys(packed_tile: jax.Array, mu: int) -> jax.Array:
    """int[TM, TN//8] bytes -> int32 keys [TM, TN//mu] (LSB-first, mu | 8)."""
    tm, nb = packed_tile.shape
    per_byte = 8 // mu
    p32 = packed_tile.astype(jnp.int32)
    cols = []
    for s in range(per_byte):
        cols.append((p32 >> (s * mu)) & ((1 << mu) - 1))
    keys = jnp.stack(cols, axis=-1)                      # [TM, nb, per_byte]
    return keys.reshape(tm, nb * per_byte)


def read_lut(lut: jax.Array, keys: jax.Array, mu: int, half: bool,
             mode: ReadMode) -> jax.Array:
    """vals[b, m, g] = LUT[b, g, key[m, g]]  (sign-decoded if half).

    lut: [TB, G, P] (P = 2^mu or 2^(mu-1)); keys int32 [TM, G].
    """
    if half:
        hsz = 1 << (mu - 1)
        msb = keys >= hsz                                 # [TM, G]
        idx = jnp.where(msb, keys - hsz, (hsz - 1) - keys)
        sign = jnp.where(msb, 1.0, -1.0).astype(lut.dtype)
        n_entries = hsz
    else:
        idx = keys
        sign = None
        n_entries = lut.shape[-1]

    if mode == "select":
        # 2^mu-way mux sweep — the RAC's multiplexer, vectorized over lanes.
        acc = jnp.zeros((lut.shape[0], keys.shape[0], keys.shape[1]), lut.dtype)
        for p in range(n_entries):
            hit = (idx == p).astype(lut.dtype)            # [TM, G]
            acc = acc + hit[None, :, :] * lut[:, None, :, p]
        vals = acc
    elif mode == "onehot":
        onehot = (idx[..., None] ==
                  lax.broadcasted_iota(jnp.int32, (*idx.shape, n_entries), 2)
                  ).astype(lut.dtype)                     # [TM, G, P]
        # contract P with G as batch: [G,TM,P] x [G,P,TB] -> [G,TM,TB]
        vals = lax.dot_general(
            onehot.transpose(1, 0, 2), lut.transpose(1, 2, 0),
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ).transpose(2, 1, 0)                              # [TB, TM, G]
    elif mode == "gather":
        tb, tm = lut.shape[0], idx.shape[0]
        vals = jnp.take_along_axis(
            jnp.broadcast_to(lut[:, None], (tb, tm, lut.shape[1], lut.shape[2])),
            jnp.broadcast_to(idx[None, :, :, None], (tb, tm, idx.shape[1], 1)),
            axis=-1,
        )[..., 0]                                         # [TB, TM, G]
    else:
        raise ValueError(mode)

    if half:
        vals = vals * sign[None, :, :]
    return vals


def ternary_plane_bytes(sign_byte: jax.Array, mask_byte: jax.Array):
    """Decode the ternary bundle's (sign, mask) bytes into BCQ plane bytes.

    The ternary identity  w = (a/2)(b1 + b2)  with
    b1 = mask ? sign : +1  and  b2 = mask ? sign : -1  becomes, on the
    packed bit level (bit 1 = +1 / "nonzero"),

        b1 = sign | ~mask          b2 = sign & mask

    — two bitwise ops per byte, the in-kernel realization of the paper's
    sign-decoding unit.  Returns int32 byte planes for
    :func:`extract_keys`.
    """
    s32 = sign_byte.astype(jnp.int32)
    m32 = mask_byte.astype(jnp.int32)
    b1 = (s32 | (~m32 & 0xFF)) & 0xFF
    b2 = s32 & m32
    return b1, b2
