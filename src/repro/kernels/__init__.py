"""Pallas TPU kernels for FIGLUT.

  lut_gemm    — paper-faithful LUT-based FP-INT GEMM (LUT build in VMEM +
                keyed read-accumulate, hFFLUT symmetry; §III).
  bcq_matmul  — beyond-paper TPU-native path: packed bit-planes dequantized
                in VMEM + single MXU matmul per tile (DESIGN.md §2).

Each kernel ships ``ops.py`` (jit'd public wrapper) and ``ref.py``
(pure-jnp oracle swept against in tests).
"""
from .lut_gemm import lut_gemm
from .bcq_matmul import bcq_matmul

__all__ = ["lut_gemm", "bcq_matmul"]
