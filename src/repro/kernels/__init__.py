"""Pallas TPU kernels for FIGLUT.

  lut_gemm         — paper-faithful LUT-based FP-INT GEMM (LUT build in
                     VMEM + keyed read-accumulate, hFFLUT symmetry; §III).
  bcq_matmul       — beyond-paper TPU-native path: packed bit-planes
                     dequantized in VMEM + single MXU matmul per tile
                     (DESIGN.md §2).
  ternary_matmul   — dedicated 1.58-bit fast path: one sign plane + one
                     zero mask, in-kernel sign decode onto the half-LUT
                     (§III-D), a single shared-magnitude alpha row and
                     no offset — strictly fewer HBM bytes than generic
                     2-bit BCQ.
  paged_attention  — fused paged-KV decode attention: the block-table
                     gather runs inside the kernel (scalar-prefetched
                     index_map), so the serve engine's decode path never
                     materializes the gathered cache view — the same
                     "indirection stays on-chip" principle as the LUT
                     kernel's keyed reads.

Each kernel ships ``ops.py`` (jit'd public wrapper) and ``ref.py``
(pure-jnp oracle swept against in tests).
"""
from .lut_gemm import lut_gemm
from .bcq_matmul import bcq_matmul
from .ternary_matmul import ternary_matmul
from .paged_attention import paged_attention

__all__ = ["lut_gemm", "bcq_matmul", "ternary_matmul", "paged_attention"]
