from .ops import (divisor_clamp, paged_attention, paged_attention_int8,
                  paged_attention_mla, paged_prefill)
from .ref import (paged_decode_int8_ref, paged_decode_mla_ref,
                  paged_decode_ref, paged_prefill_ref)

__all__ = [
    "paged_attention", "paged_attention_int8", "paged_attention_mla",
    "paged_prefill", "paged_decode_ref", "paged_decode_int8_ref",
    "paged_decode_mla_ref", "paged_prefill_ref", "divisor_clamp",
]
