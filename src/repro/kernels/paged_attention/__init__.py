from .ops import divisor_clamp, paged_attention
from .ref import paged_decode_ref

__all__ = ["paged_attention", "paged_decode_ref", "divisor_clamp"]
