"""Pure-jnp oracle for paged decode attention.

``paged_decode_ref`` is the gather-then-attend formulation the fused
kernel must match: materialize the per-sequence contiguous view of the
pool (the ``paged_view`` semantics from ``models/attention.py``,
re-derived here so the oracle is independent of the model layer), then
run single-token attention with a full masked softmax and FP32
accumulation.

Liveness rule (identical to the kernel and to ``paged_view``): a view
slot contributes iff its table entry is allocated, its stored position
equals its logical view index, and it is causally visible
(``pos <= q_pos``).  Rows with no live slot return zeros, matching the
kernel's ``l == 0`` guard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_view(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """[NB, BS, ...] pool + [B, pages] tables -> [B, pages*BS, ...] view
    (unallocated entries read the trash block; masking happens later)."""
    b, pages = tables.shape
    bs = pool.shape[1]
    safe = jnp.maximum(tables, 0).reshape(-1)
    g = jnp.take(pool, safe, axis=0)                    # [B*pages, BS, ...]
    return g.reshape(b, pages * bs, *pool.shape[2:])


def live_mask(pos_pool: jax.Array, tables: jax.Array,
              positions: jax.Array) -> jax.Array:
    """bool [B, pages*BS]: slot live and causally visible for this step."""
    b, pages = tables.shape
    bs = pos_pool.shape[1]
    vpos = gather_view(pos_pool, tables)                # [B, pages*BS]
    allocated = jnp.repeat(tables >= 0, bs, axis=1)
    iota = jnp.arange(pages * bs, dtype=jnp.int32)[None]
    return allocated & (vpos == iota) & (vpos <= positions[:, None])


def paged_decode_ref(q, k_pool, v_pool, pos_pool, tables, positions, *,
                     scale=None, out_dtype=None):
    """Gathered-view decode attention oracle.

    q: [B, H, D]; k_pool/v_pool: [NB, BS, Hkv, D]; pos_pool: [NB, BS];
    tables: int32 [B, pages]; positions: int32 [B].
    Returns [B, H, D] (FP32 accumulation, cast to out_dtype or q.dtype).
    """
    b, h, d = q.shape
    hkv = k_pool.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else d ** -0.5

    kv = gather_view(k_pool, tables)                    # [B, L, Hkv, D]
    vv = gather_view(v_pool, tables)
    ok = live_mask(pos_pool, tables, positions)         # [B, L]

    qg = (q.reshape(b, hkv, rep, d).astype(jnp.float32) * scale
          ).astype(k_pool.dtype)
    s = jnp.einsum("bhrd,blhd->bhrl", qg, kv,
                   preferred_element_type=jnp.float32)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.where(ok[:, None, None, :], jnp.exp(s - m), 0.0)
    l = p.sum(-1)
    out = jnp.einsum("bhrl,blhd->bhrd", p.astype(v_pool.dtype), vv,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, d).astype(out_dtype or q.dtype)
