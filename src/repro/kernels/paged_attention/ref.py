"""Pure-jnp oracle for paged decode attention.

``paged_decode_ref`` is the gather-then-attend formulation the fused
kernel must match: materialize the per-sequence contiguous view of the
pool (the ``paged_view`` semantics from ``models/attention.py``,
re-derived here so the oracle is independent of the model layer), then
run single-token attention with a full masked softmax and FP32
accumulation.

Liveness rule (identical to the kernel and to ``paged_view``): a view
slot contributes iff its table entry is allocated, its stored position
equals its logical view index, and it is causally visible
(``pos <= q_pos``).  Rows with no live slot return zeros, matching the
kernel's ``l == 0`` guard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_view(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """[NB, BS, ...] pool + [B, pages] tables -> [B, pages*BS, ...] view
    (unallocated entries read the trash block; masking happens later)."""
    b, pages = tables.shape
    bs = pool.shape[1]
    safe = jnp.maximum(tables, 0).reshape(-1)
    g = jnp.take(pool, safe, axis=0)                    # [B*pages, BS, ...]
    return g.reshape(b, pages * bs, *pool.shape[2:])


def live_mask(pos_pool: jax.Array, tables: jax.Array,
              positions: jax.Array) -> jax.Array:
    """bool [B, pages*BS]: slot live and causally visible for this step."""
    b, pages = tables.shape
    bs = pos_pool.shape[1]
    vpos = gather_view(pos_pool, tables)                # [B, pages*BS]
    allocated = jnp.repeat(tables >= 0, bs, axis=1)
    iota = jnp.arange(pages * bs, dtype=jnp.int32)[None]
    return allocated & (vpos == iota) & (vpos <= positions[:, None])


def paged_decode_ref(q, k_pool, v_pool, pos_pool, tables, positions, *,
                     scale=None, out_dtype=None):
    """Gathered-view decode attention oracle.

    q: [B, H, D]; k_pool/v_pool: [NB, BS, Hkv, D]; pos_pool: [NB, BS];
    tables: int32 [B, pages]; positions: int32 [B].
    Returns [B, H, D] (FP32 accumulation, cast to out_dtype or q.dtype).
    """
    b, h, d = q.shape
    hkv = k_pool.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else d ** -0.5

    kv = gather_view(k_pool, tables)                    # [B, L, Hkv, D]
    vv = gather_view(v_pool, tables)
    ok = live_mask(pos_pool, tables, positions)         # [B, L]

    qg = (q.reshape(b, hkv, rep, d).astype(jnp.float32) * scale
          ).astype(k_pool.dtype)
    s = jnp.einsum("bhrd,blhd->bhrl", qg, kv,
                   preferred_element_type=jnp.float32)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.where(ok[:, None, None, :], jnp.exp(s - m), 0.0)
    l = p.sum(-1)
    out = jnp.einsum("bhrl,blhd->bhrd", p.astype(v_pool.dtype), vv,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, d).astype(out_dtype or q.dtype)


def paged_decode_int8_ref(q, k_pool, v_pool, k_scale, v_scale, pos_pool,
                          tables, positions, *, scale=None, out_dtype=None):
    """Gathered int8-KV decode oracle, matching ``decode_attend``'s
    ordering exactly: bf16 compute, per-slot ``k_scale`` folded into the
    raw scores BEFORE the softmax, ``v_scale`` folded into the
    (normalized) probabilities AFTER it.

    q: [B, H, D] float; k_pool/v_pool: int8 [NB, BS, Hkv, D];
    k_scale/v_scale: f32 [NB, BS, Hkv].
    """
    b, h, d = q.shape
    hkv = k_pool.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else d ** -0.5
    cdt = jnp.bfloat16

    kv = gather_view(k_pool, tables).astype(cdt)        # [B, L, Hkv, D]
    vv = gather_view(v_pool, tables).astype(cdt)
    ksv = gather_view(k_scale, tables)                  # [B, L, Hkv] f32
    vsv = gather_view(v_scale, tables)
    ok = live_mask(pos_pool, tables, positions)         # [B, L]

    qg = (q.reshape(b, hkv, rep, d).astype(jnp.float32) * scale).astype(cdt)
    s = jnp.einsum("bhrd,blhd->bhrl", qg, kv,
                   preferred_element_type=jnp.float32)
    s = s * ksv.transpose(0, 2, 1)[:, :, None, :]       # dequant fold
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.where(ok[:, None, None, :], jnp.exp(s - m), 0.0)
    l = p.sum(-1)
    p = p / jnp.maximum(l, 1e-30)[..., None]            # softmax first …
    p = p * vsv.transpose(0, 2, 1)[:, :, None, :]       # … then v_scale
    out = jnp.einsum("bhrl,blhd->bhrd", p.astype(cdt), vv,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(out_dtype or q.dtype)


def paged_decode_mla_ref(q_eff, q_rope, ckv_pool, krope_pool, pos_pool,
                         tables, positions, *, scale):
    """Gathered MLA absorbed-decode oracle.

    q_eff: f32 [B, H, lora]; q_rope: f32 [B, H, rope_dim]; latent pools
    [NB, BS, lora] / [NB, BS, rope_dim].  Returns the latent context,
    f32 [B, H, lora] (the caller applies ``w_uv``).
    """
    ckv = gather_view(ckv_pool, tables).astype(jnp.float32)   # [B, L, lora]
    kr = gather_view(krope_pool, tables).astype(jnp.float32)  # [B, L, dr]
    ok = live_mask(pos_pool, tables, positions)               # [B, L]

    s = (jnp.einsum("bhl,bkl->bhk", q_eff, ckv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,bkr->bhk", q_rope, kr,
                      preferred_element_type=jnp.float32)) * scale
    s = jnp.where(ok[:, None, :], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.where(ok[:, None, :], jnp.exp(s - m), 0.0)
    l = p.sum(-1, keepdims=True)
    p = p / jnp.maximum(l, 1e-30)
    return jnp.einsum("bhk,bkl->bhl", p, ckv,
                      preferred_element_type=jnp.float32)


def paged_prefill_ref(q, k_pool, v_pool, pos_pool, tables, positions, *,
                      scale=None, k_scale=None, v_scale=None,
                      out_dtype=None):
    """Gathered chunked-prefill oracle: per-query causal full softmax
    over the pool view.  Pad query rows (``positions < 0``) see no live
    slot and return zeros — matching the kernel's ``l == 0`` guard, NOT
    ``blockwise_attention``'s mean-of-v garbage on pads (both are
    discarded downstream).

    q: [B, C, H, D]; positions: int32 [B, C].  With ``k_scale`` /
    ``v_scale`` the int8 fold uses the fused kernel's ordering (scales
    applied to f32 scores / probabilities).
    """
    b, c, h, d = q.shape
    hkv = k_pool.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else d ** -0.5
    int8 = k_scale is not None
    cdt = jnp.bfloat16 if int8 else k_pool.dtype

    kv = gather_view(k_pool, tables).astype(cdt)        # [B, L, Hkv, D]
    vv = gather_view(v_pool, tables).astype(cdt)
    bsz = pos_pool.shape[1]
    pages = tables.shape[1]
    vpos = gather_view(pos_pool, tables)                # [B, L]
    iota = jnp.arange(pages * bsz, dtype=jnp.int32)[None]
    live = jnp.repeat(tables >= 0, bsz, axis=1) & (vpos == iota)
    ok = live[:, None, :] & (vpos[:, None, :] <= positions[:, :, None])

    qg = (q.reshape(b, c, hkv, rep, d).astype(jnp.float32) * scale
          ).astype(cdt)
    s = jnp.einsum("bchrd,blhd->bchrl", qg, kv,
                   preferred_element_type=jnp.float32)
    if int8:
        ksv = gather_view(k_scale, tables)              # [B, L, Hkv]
        s = s * ksv.transpose(0, 2, 1)[:, None, :, None, :]
    okb = ok[:, :, None, None, :]
    s = jnp.where(okb, s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.where(okb, jnp.exp(s - m), 0.0)
    l = p.sum(-1)
    p = p / jnp.maximum(l, 1e-30)[..., None]
    if int8:
        vsv = gather_view(v_scale, tables)
        p = p * vsv.transpose(0, 2, 1)[:, None, :, None, :]
    out = jnp.einsum("bchrl,blhd->bchrd", p.astype(cdt), vv,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, c, h, d).astype(out_dtype or q.dtype)
