"""Pallas TPU kernel: fused paged-KV decode attention.

The paged serving engine stores every layer's KV as a shared pool
``[num_blocks, block_size, Hkv, D]`` plus per-sequence block tables
``[B, max_blocks_per_seq]`` (see ``models/attention.py``).  The XLA
fallback materializes a gathered per-sequence view of the pool before
every decode step — a full-cache copy per layer, exactly the bandwidth
waste FIGLUT's LUT dataflow exists to avoid.  This kernel moves the
block-table lookup *into* the attention kernel, the same "indirection
stays on-chip" principle as the LUT kernel's keyed reads: each grid step
DMAs one physical pool block straight into VMEM via a block-table-driven
``index_map`` (scalar-prefetched, so the address is known before the
step runs) and folds it into a flash-style online softmax.  The gathered
view is never built.

Masking is identical to ``paged_view``'s liveness rule and happens on
the scores in-kernel: a slot contributes iff

  * its table entry is allocated (``table[b, j] >= 0``),
  * its stored position equals its logical view index ``j * bs + i``
    (recycled pool blocks still hold a dead sequence's positions — this
    is what makes pool recycling safe), and
  * its position is causally visible (``pos <= q_pos``).

``pos == -1`` pads and trash-block contents fail the second check, so
they are read but never attended — matching the gathered oracle.

Grid: ``(B, Hkv / block_h, num_logical_blocks)`` with the page dim
innermost; the output block (revisited across pages) doubles as the
FP32 accumulator, with running max / sum in VMEM scratch.  Rows with no
live slot at all (idle batch rows parked on the trash block) produce
zeros — the engine discards their outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(tables_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref,
                       o_ref, m_ref, l_ref, *, block_size: int, pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)                       # logical page (innermost)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0]                               # [bh, rep, d] (pre-scaled)
    k = k_ref[0]                               # [bs, bh, d]
    v = v_ref[0]
    s = jnp.einsum("hrd,khd->hrk", q, k,
                   preferred_element_type=jnp.float32)   # [bh, rep, bs]

    # liveness mask (the paged_view rule, applied to scores)
    entry = tables_ref[b, j]
    qpos = qpos_ref[b]
    logical = j * block_size + lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    slot_pos = pos_ref[...]                    # [1, bs]
    ok = (entry >= 0) & (slot_pos == logical) & (slot_pos <= qpos)
    okb = ok[:, None, :]                       # [1, 1, bs] -> broadcast
    s = jnp.where(okb, s, NEG_INF)

    # online softmax update; the output block is the FP32 accumulator
    m_prev = m_ref[...]                        # [bh, rep]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # fully-masked-so-far rows have m == NEG_INF: exp(NEG_INF - NEG_INF)
    # would be 1, so masked probabilities are forced to 0 explicitly
    p = jnp.where(okb, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)             # [bh, rep]
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + p.sum(axis=-1)
    pv = jnp.einsum("hrk,khd->hrd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    o_ref[0] = o_ref[0] * corr[..., None] + pv

    @pl.when(j == pages - 1)
    def _finish():
        l = l_ref[...]
        # rows with zero live slots keep l == 0 -> output 0 (discarded)
        o_ref[0] = o_ref[0] / jnp.maximum(l, 1e-30)[..., None]


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "block_h", "interpret"),
)
def paged_attention_tiled(q, k_pool, v_pool, pos_pool, tables, positions, *,
                          block_size: int, block_h: int,
                          interpret: bool = False):
    """Raw tiled kernel call (shapes already grouped / validated).

    q: [B, Hkv, rep, D] in KV storage dtype, *pre-scaled* by the caller
    (scale applied in f32 then rounded to the storage dtype — identical
    rounding to ``decode_attend``).
    k_pool / v_pool: [NB, BS, Hkv, D]; pos_pool: int32 [NB, BS].
    tables: int32 [B, pages]; positions: int32 [B].
    Returns f32 [B, Hkv, rep, D].  ``block_h`` must divide Hkv.
    """
    b, hkv, rep, d = q.shape
    nb, bs = pos_pool.shape
    pages = tables.shape[1]
    assert hkv % block_h == 0, (hkv, block_h)
    assert bs == block_size and k_pool.shape[:2] == (nb, bs)

    kernel = functools.partial(_paged_attn_kernel, block_size=block_size,
                               pages=pages)

    # unallocated (-1) table entries fetch the trash block 0 — its
    # contents are read but masked by the liveness rule above
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # tables, positions
        grid=(b, hkv // block_h, pages),
        in_specs=[
            pl.BlockSpec((1, block_h, rep, d),
                         lambda bi, hi, ji, tables, qpos: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_size, block_h, d),
                         lambda bi, hi, ji, tables, qpos:
                         (jnp.maximum(tables[bi, ji], 0), 0, hi, 0)),
            pl.BlockSpec((1, block_size, block_h, d),
                         lambda bi, hi, ji, tables, qpos:
                         (jnp.maximum(tables[bi, ji], 0), 0, hi, 0)),
            pl.BlockSpec((1, block_size),
                         lambda bi, hi, ji, tables, qpos:
                         (jnp.maximum(tables[bi, ji], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, block_h, rep, d),
                               lambda bi, hi, ji, tables, qpos:
                               (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_h, rep), jnp.float32),   # running max
            pltpu.VMEM((block_h, rep), jnp.float32),   # running sum
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), jnp.float32),
        interpret=interpret,
    )(tables, positions, q, k_pool, v_pool, pos_pool)
