"""Pallas TPU kernel: fused paged-KV decode attention.

The paged serving engine stores every layer's KV as a shared pool
``[num_blocks, block_size, Hkv, D]`` plus per-sequence block tables
``[B, max_blocks_per_seq]`` (see ``models/attention.py``).  The XLA
fallback materializes a gathered per-sequence view of the pool before
every decode step — a full-cache copy per layer, exactly the bandwidth
waste FIGLUT's LUT dataflow exists to avoid.  This kernel moves the
block-table lookup *into* the attention kernel, the same "indirection
stays on-chip" principle as the LUT kernel's keyed reads: each grid step
DMAs one physical pool block straight into VMEM via a block-table-driven
``index_map`` (scalar-prefetched, so the address is known before the
step runs) and folds it into a flash-style online softmax.  The gathered
view is never built.

Masking is identical to ``paged_view``'s liveness rule and happens on
the scores in-kernel: a slot contributes iff

  * its table entry is allocated (``table[b, j] >= 0``),
  * its stored position equals its logical view index ``j * bs + i``
    (recycled pool blocks still hold a dead sequence's positions — this
    is what makes pool recycling safe), and
  * its position is causally visible (``pos <= q_pos``).

``pos == -1`` pads and trash-block contents fail the second check, so
they are read but never attended — matching the gathered oracle.

Grid: ``(B, Hkv / block_h, num_logical_blocks)`` with the page dim
innermost; the output block (revisited across pages) doubles as the
FP32 accumulator, with running max / sum in VMEM scratch.  Rows with no
live slot at all (idle batch rows parked on the trash block) produce
zeros — the engine discards their outputs.

Variant coverage (the FLUTE offline-restructure-then-fuse pattern: all
layout work happens at quantize/admission time so the in-loop index
math stays trivial):

* ``_paged_attn_int8_kernel`` — int8-KV pools.  The per-slot
  ``k_scale``/``v_scale`` rows (``[NB, BS, Hkv]`` f32, written at
  admission by ``_quantize_kv``) ride the *same* block-table-driven DMA
  as the KV block, and the dequant fold happens on the score / value
  epilogues in-kernel: raw int8 scores are multiplied by ``k_scale``
  before the running max, and ``v_scale`` folds into the PV contraction
  only — the running sum ``l`` accumulates *unscaled* probabilities so
  the final normalization matches the gathered ``decode_attend``
  ordering (softmax first, then ``p * v_scale``).
* ``_paged_attn_mla_kernel`` — MLA latent pools.  The caller absorbs
  ``w_uk`` into the query (``q_eff = q_nope @ w_uk``) so scores live in
  latent space; the kernel reads ``ckv``/``k_rope`` blocks straight
  from the pool and returns the *latent* context (``w_uv`` is applied
  by the caller).  ``kv_map_fn`` never runs: the per-block compute IS
  the absorbed form.
* ``_paged_prefill_kernel`` — chunked-prefill flash attention.  The
  current chunk's queries attend over prior context (and the chunk
  itself, already inserted into the pool) via the same scalar-prefetch
  block-table indexing, with per-query causal masking across the chunk
  boundary and an online softmax over pool blocks.  Pad query rows
  (``pos < 0``) see no live slot and produce zeros.  An int8 flavour
  folds the per-slot scales exactly like the decode variant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(tables_ref, qpos_ref, q_ref, k_ref, v_ref, pos_ref,
                       o_ref, m_ref, l_ref, *, block_size: int, pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)                       # logical page (innermost)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0]                               # [bh, rep, d] (pre-scaled)
    k = k_ref[0]                               # [bs, bh, d]
    v = v_ref[0]
    s = jnp.einsum("hrd,khd->hrk", q, k,
                   preferred_element_type=jnp.float32)   # [bh, rep, bs]

    # liveness mask (the paged_view rule, applied to scores)
    entry = tables_ref[b, j]
    qpos = qpos_ref[b]
    logical = j * block_size + lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    slot_pos = pos_ref[...]                    # [1, bs]
    ok = (entry >= 0) & (slot_pos == logical) & (slot_pos <= qpos)
    okb = ok[:, None, :]                       # [1, 1, bs] -> broadcast
    s = jnp.where(okb, s, NEG_INF)

    # online softmax update; the output block is the FP32 accumulator
    m_prev = m_ref[...]                        # [bh, rep]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # fully-masked-so-far rows have m == NEG_INF: exp(NEG_INF - NEG_INF)
    # would be 1, so masked probabilities are forced to 0 explicitly
    p = jnp.where(okb, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)             # [bh, rep]
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + p.sum(axis=-1)
    pv = jnp.einsum("hrk,khd->hrd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    o_ref[0] = o_ref[0] * corr[..., None] + pv

    @pl.when(j == pages - 1)
    def _finish():
        l = l_ref[...]
        # rows with zero live slots keep l == 0 -> output 0 (discarded)
        o_ref[0] = o_ref[0] / jnp.maximum(l, 1e-30)[..., None]


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "block_h", "interpret"),
)
def paged_attention_tiled(q, k_pool, v_pool, pos_pool, tables, positions, *,
                          block_size: int, block_h: int,
                          interpret: bool = False):
    """Raw tiled kernel call (shapes already grouped / validated).

    q: [B, Hkv, rep, D] in KV storage dtype, *pre-scaled* by the caller
    (scale applied in f32 then rounded to the storage dtype — identical
    rounding to ``decode_attend``).
    k_pool / v_pool: [NB, BS, Hkv, D]; pos_pool: int32 [NB, BS].
    tables: int32 [B, pages]; positions: int32 [B].
    Returns f32 [B, Hkv, rep, D].  ``block_h`` must divide Hkv.
    """
    b, hkv, rep, d = q.shape
    nb, bs = pos_pool.shape
    pages = tables.shape[1]
    assert hkv % block_h == 0, (hkv, block_h)
    assert bs == block_size and k_pool.shape[:2] == (nb, bs)

    kernel = functools.partial(_paged_attn_kernel, block_size=block_size,
                               pages=pages)

    # unallocated (-1) table entries fetch the trash block 0 — its
    # contents are read but masked by the liveness rule above
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # tables, positions
        grid=(b, hkv // block_h, pages),
        in_specs=[
            pl.BlockSpec((1, block_h, rep, d),
                         lambda bi, hi, ji, tables, qpos: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_size, block_h, d),
                         lambda bi, hi, ji, tables, qpos:
                         (jnp.maximum(tables[bi, ji], 0), 0, hi, 0)),
            pl.BlockSpec((1, block_size, block_h, d),
                         lambda bi, hi, ji, tables, qpos:
                         (jnp.maximum(tables[bi, ji], 0), 0, hi, 0)),
            pl.BlockSpec((1, block_size),
                         lambda bi, hi, ji, tables, qpos:
                         (jnp.maximum(tables[bi, ji], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, block_h, rep, d),
                               lambda bi, hi, ji, tables, qpos:
                               (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_h, rep), jnp.float32),   # running max
            pltpu.VMEM((block_h, rep), jnp.float32),   # running sum
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), jnp.float32),
        interpret=interpret,
    )(tables, positions, q, k_pool, v_pool, pos_pool)


def _paged_attn_int8_kernel(tables_ref, qpos_ref, q_ref, k_ref, v_ref,
                            ks_ref, vs_ref, pos_ref, o_ref, m_ref, l_ref, *,
                            block_size: int, pages: int):
    """int8-KV decode: per-slot scale rows ride the block-table DMA and
    fold into the score / value epilogues (gathered ``decode_attend``
    ordering: k_scale before softmax, v_scale after — so ``l`` sums the
    UNSCALED probabilities)."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0]                               # [bh, rep, d] bf16 pre-scaled
    k = k_ref[0].astype(q.dtype)               # [bs, bh, d] int8 -> bf16
    v = v_ref[0].astype(q.dtype)
    ks = ks_ref[0]                             # [bs, bh] f32
    vs = vs_ref[0]
    s = jnp.einsum("hrd,khd->hrk", q, k,
                   preferred_element_type=jnp.float32)   # [bh, rep, bs]
    s = s * ks.T[:, None, :]                   # dequant fold, pre-softmax

    entry = tables_ref[b, j]
    qpos = qpos_ref[b]
    logical = j * block_size + lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    slot_pos = pos_ref[...]                    # [1, bs]
    ok = (entry >= 0) & (slot_pos == logical) & (slot_pos <= qpos)
    okb = ok[:, None, :]
    s = jnp.where(okb, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(okb, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    # l accumulates UNSCALED p: v_scale is a value-side factor, not a
    # probability reweighting — normalizing by scaled sums would diverge
    # from softmax-then-(p * v_scale)
    l_ref[...] = l_prev * corr + p.sum(axis=-1)
    pw = p * vs.T[:, None, :]                  # [bh, rep, bs]
    pv = jnp.einsum("hrk,khd->hrd", pw.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    o_ref[0] = o_ref[0] * corr[..., None] + pv

    @pl.when(j == pages - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = o_ref[0] / jnp.maximum(l, 1e-30)[..., None]


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "block_h", "interpret"),
)
def paged_attention_int8_tiled(q, k_pool, v_pool, k_scale, v_scale,
                               pos_pool, tables, positions, *,
                               block_size: int, block_h: int,
                               interpret: bool = False):
    """int8-KV tiled kernel call.

    q: [B, Hkv, rep, D] *compute* dtype (bf16), pre-scaled by the caller.
    k_pool / v_pool: int8 [NB, BS, Hkv, D]; k_scale / v_scale: f32
    [NB, BS, Hkv] (per-slot, per-kv-head dequant scales).
    Returns f32 [B, Hkv, rep, D].
    """
    b, hkv, rep, d = q.shape
    nb, bs = pos_pool.shape
    pages = tables.shape[1]
    assert hkv % block_h == 0, (hkv, block_h)
    assert bs == block_size and k_pool.shape[:2] == (nb, bs)
    assert k_scale.shape == (nb, bs, hkv), (k_scale.shape, (nb, bs, hkv))

    kernel = functools.partial(_paged_attn_int8_kernel,
                               block_size=block_size, pages=pages)

    def _pool_idx(bi, hi, ji, tables, qpos):
        return (jnp.maximum(tables[bi, ji], 0), 0, hi, 0)

    def _scale_idx(bi, hi, ji, tables, qpos):
        return (jnp.maximum(tables[bi, ji], 0), 0, hi)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # tables, positions
        grid=(b, hkv // block_h, pages),
        in_specs=[
            pl.BlockSpec((1, block_h, rep, d),
                         lambda bi, hi, ji, tables, qpos: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_size, block_h, d), _pool_idx),
            pl.BlockSpec((1, block_size, block_h, d), _pool_idx),
            pl.BlockSpec((1, block_size, block_h), _scale_idx),
            pl.BlockSpec((1, block_size, block_h), _scale_idx),
            pl.BlockSpec((1, block_size),
                         lambda bi, hi, ji, tables, qpos:
                         (jnp.maximum(tables[bi, ji], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, block_h, rep, d),
                               lambda bi, hi, ji, tables, qpos:
                               (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_h, rep), jnp.float32),
            pltpu.VMEM((block_h, rep), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), jnp.float32),
        interpret=interpret,
    )(tables, positions, q, k_pool, v_pool, k_scale, v_scale, pos_pool)


def _paged_attn_mla_kernel(tables_ref, qpos_ref, qe_ref, qr_ref, ckv_ref,
                           kr_ref, pos_ref, o_ref, m_ref, l_ref, *,
                           block_size: int, pages: int, scale: float):
    """MLA absorbed decode over latent pool blocks.  Scores are computed
    in latent space (``q_eff = q_nope @ w_uk`` absorbed by the caller)
    plus the decoupled rope term; the accumulated output is the LATENT
    context (caller applies ``w_uv``)."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    qe = qe_ref[0]                             # [bh, lora] f32
    qr = qr_ref[0]                             # [bh, dr] f32
    ckv = ckv_ref[0].astype(jnp.float32)       # [bs, lora]
    kr = kr_ref[0].astype(jnp.float32)         # [bs, dr]
    s = (jnp.einsum("hl,kl->hk", qe, ckv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("hr,kr->hk", qr, kr,
                      preferred_element_type=jnp.float32)) * scale

    entry = tables_ref[b, j]
    qpos = qpos_ref[b]
    logical = j * block_size + lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    slot_pos = pos_ref[...]                    # [1, bs]
    ok = (entry >= 0) & (slot_pos == logical) & (slot_pos <= qpos)
    s = jnp.where(ok, s, NEG_INF)              # [1, bs] broadcasts over h

    m_prev = m_ref[...]                        # [bh, 1]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
    pv = jnp.einsum("hk,kl->hl", p, ckv,
                    preferred_element_type=jnp.float32)
    o_ref[0] = o_ref[0] * corr + pv

    @pl.when(j == pages - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = o_ref[0] / jnp.maximum(l, 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "block_h", "scale", "interpret"),
)
def paged_attention_mla_tiled(q_eff, q_rope, ckv_pool, krope_pool,
                              pos_pool, tables, positions, *, scale: float,
                              block_size: int, block_h: int,
                              interpret: bool = False):
    """MLA tiled kernel call (absorbed decode).

    q_eff: f32 [B, H, lora] (w_uk already absorbed); q_rope: f32
    [B, H, rope_dim]; ckv_pool: [NB, BS, lora]; krope_pool:
    [NB, BS, rope_dim]; pos_pool: int32 [NB, BS].
    Returns the latent context, f32 [B, H, lora].  ``block_h`` tiles the
    QUERY head dim (MLA has no kv-head replication).
    """
    b, h, lora = q_eff.shape
    dr = q_rope.shape[-1]
    nb, bs = pos_pool.shape
    pages = tables.shape[1]
    assert h % block_h == 0, (h, block_h)
    assert bs == block_size and ckv_pool.shape == (nb, bs, lora)
    assert krope_pool.shape == (nb, bs, dr)

    kernel = functools.partial(_paged_attn_mla_kernel,
                               block_size=block_size, pages=pages,
                               scale=float(scale))

    def _pool_idx(bi, hi, ji, tables, qpos):
        return (jnp.maximum(tables[bi, ji], 0), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # tables, positions
        grid=(b, h // block_h, pages),
        in_specs=[
            pl.BlockSpec((1, block_h, lora),
                         lambda bi, hi, ji, tables, qpos: (bi, hi, 0)),
            pl.BlockSpec((1, block_h, dr),
                         lambda bi, hi, ji, tables, qpos: (bi, hi, 0)),
            pl.BlockSpec((1, block_size, lora), _pool_idx),
            pl.BlockSpec((1, block_size, dr), _pool_idx),
            pl.BlockSpec((1, block_size),
                         lambda bi, hi, ji, tables, qpos:
                         (jnp.maximum(tables[bi, ji], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, block_h, lora),
                               lambda bi, hi, ji, tables, qpos:
                               (bi, hi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_h, 1), jnp.float32),
            pltpu.VMEM((block_h, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, lora), jnp.float32),
        interpret=interpret,
    )(tables, positions, q_eff, q_rope, ckv_pool, krope_pool, pos_pool)


def _paged_prefill_kernel(tables_ref, qpos_ref, q_ref, k_ref, v_ref, *rest,
                          block_size: int, pages: int, int8: bool):
    """Chunked-prefill flash attention over pool blocks: the chunk's C
    queries (each with its own absolute position) attend over every live
    slot causally visible to them — prior context AND the already-
    inserted chunk itself — with per-query masking across the chunk
    boundary.  Pad query rows (``pos < 0``) see no live slot and yield
    zeros (``l == 0`` guard)."""
    if int8:
        ks_ref, vs_ref, pos_ref, o_ref, m_ref, l_ref = rest
    else:
        pos_ref, o_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0]                               # [C, bh, rep, d] pre-scaled
    k = k_ref[0].astype(q.dtype)               # [bs, bh, d]
    v = v_ref[0].astype(q.dtype)
    s = jnp.einsum("chrd,khd->chrk", q, k,
                   preferred_element_type=jnp.float32)   # [C, bh, rep, bs]
    if int8:
        s = s * ks_ref[0].T[None, :, None, :]  # [1, bh, 1, bs]

    entry = tables_ref[b, j]
    q_pos = qpos_ref[0]                        # [C]
    logical = j * block_size + lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    slot_pos = pos_ref[...]                    # [1, bs]
    live = (entry >= 0) & (slot_pos == logical)          # [1, bs]
    ok = live & (slot_pos <= q_pos[:, None])             # [C, bs] causal
    okb = ok[:, None, None, :]                 # [C, 1, 1, bs]
    s = jnp.where(okb, s, NEG_INF)

    m_prev = m_ref[...]                        # [C, bh, rep]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(okb, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + p.sum(axis=-1)
    if int8:
        pw = p * vs_ref[0].T[None, :, None, :]
    else:
        pw = p
    pv = jnp.einsum("chrk,khd->chrd", pw.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    o_ref[0] = o_ref[0] * corr[..., None] + pv

    @pl.when(j == pages - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = o_ref[0] / jnp.maximum(l, 1e-30)[..., None]


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "block_h", "interpret"),
)
def paged_prefill_tiled(q, k_pool, v_pool, pos_pool, tables, positions,
                        k_scale=None, v_scale=None, *, block_size: int,
                        block_h: int, interpret: bool = False):
    """Raw tiled chunked-prefill call.

    q: [B, C, Hkv, rep, D] in compute dtype, pre-scaled by the caller;
    positions: int32 [B, C] (absolute position per chunk token, -1 for
    pads — pad rows return zeros).  k_scale/v_scale (f32 [NB, BS, Hkv])
    switch on the int8 dequant fold.  Returns f32 [B, C, Hkv, rep, D].
    """
    b, c, hkv, rep, d = q.shape
    nb, bs = pos_pool.shape
    pages = tables.shape[1]
    int8 = k_scale is not None
    assert hkv % block_h == 0, (hkv, block_h)
    assert bs == block_size and k_pool.shape[:2] == (nb, bs)
    assert positions.shape == (b, c)

    kernel = functools.partial(_paged_prefill_kernel, block_size=block_size,
                               pages=pages, int8=int8)

    def _pool_idx(bi, hi, ji, tables):
        return (jnp.maximum(tables[bi, ji], 0), 0, hi, 0)

    def _scale_idx(bi, hi, ji, tables):
        return (jnp.maximum(tables[bi, ji], 0), 0, hi)

    # chunk positions are a regular VMEM input (C can be large), so only
    # the block tables ride the scalar-prefetch slot
    in_specs = [
        pl.BlockSpec((1, c), lambda bi, hi, ji, tables: (bi, 0)),
        pl.BlockSpec((1, c, block_h, rep, d),
                     lambda bi, hi, ji, tables: (bi, 0, hi, 0, 0)),
        pl.BlockSpec((1, block_size, block_h, d), _pool_idx),
        pl.BlockSpec((1, block_size, block_h, d), _pool_idx),
    ]
    args = [jnp.asarray(tables, jnp.int32), positions, q, k_pool, v_pool]
    if int8:
        in_specs += [pl.BlockSpec((1, block_size, block_h), _scale_idx),
                     pl.BlockSpec((1, block_size, block_h), _scale_idx)]
        args += [k_scale, v_scale]
    in_specs.append(pl.BlockSpec((1, block_size),
                                 lambda bi, hi, ji, tables:
                                 (jnp.maximum(tables[bi, ji], 0), 0)))
    args.append(pos_pool)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                 # tables only
        grid=(b, hkv // block_h, pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, c, block_h, rep, d),
                               lambda bi, hi, ji, tables:
                               (bi, 0, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c, block_h, rep), jnp.float32),
            pltpu.VMEM((c, block_h, rep), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, hkv, rep, d), jnp.float32),
        interpret=interpret,
    )(*args)
