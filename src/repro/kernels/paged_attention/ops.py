"""Public jit-friendly wrapper for the fused paged decode-attention kernel.

Launch geometry (the kv-head tile ``block_h``) is resolved through
:func:`repro.tune.dispatch.kernel_config` unless pinned by the caller —
tuned JSON-cache entry if one exists for this (batch-bucket, Hkv,
kv-capacity, dtype, rep, block_size, device) point, deterministic
heuristic otherwise.  The oracle for every path is ``ref.paged_decode_ref``.

The capability boundary (what falls back to the gathered-XLA path) lives
in :func:`repro.tune.dispatch.kernel_supports` — int8-KV pools, MLA
latent caches and sliding-window masking are not covered by this kernel
yet and are routed to ``models.attention.decode_attend`` over
``paged_view`` by the caller.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.tune import dispatch as _dispatch
from repro.tune.space import divisor_clamp
from . import paged_attention as _k


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    pos_pool: jax.Array, tables: jax.Array,
                    positions: jax.Array, *, scale: Optional[float] = None,
                    block_h: Optional[int] = None, interpret: bool = False,
                    out_dtype=None) -> jax.Array:
    """Fused decode attention straight from the paged KV pool.

    q: [B, H, D]; k_pool/v_pool: [NB, BS, Hkv, D]; pos_pool: int32
    [NB, BS]; tables: int32 [B, pages] (-1 = unallocated); positions:
    int32 [B] (absolute position of each row's new token).
    Returns [B, H, D] in ``out_dtype`` (default q.dtype), FP32 accum.
    """
    b, h, d = q.shape
    nb, bs, hkv, dk = k_pool.shape
    if dk != d:
        raise ValueError(f"head_dim mismatch: q {d} vs pool {dk}")
    if h % hkv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {hkv}")
    if v_pool.shape != k_pool.shape or pos_pool.shape != (nb, bs):
        raise ValueError("pool buffers disagree on [num_blocks, block_size]")
    rep = h // hkv
    pages = tables.shape[1]
    scale = scale if scale is not None else d ** -0.5

    if block_h is None:
        cfg = _dispatch.kernel_config(
            "paged_attention", b=b, m=hkv, n=pages * bs,
            dtype=k_pool.dtype, mu=rep, group_size=bs, interpret=interpret)
        block_h = cfg.block_h
    block_h = divisor_clamp(block_h, hkv)

    # scale in f32 THEN round to the storage dtype — identical rounding
    # to decode_attend so fused and gathered paths stay interchangeable
    qg = (q.reshape(b, hkv, rep, d).astype(jnp.float32) * scale
          ).astype(k_pool.dtype)
    out = _k.paged_attention_tiled(
        qg, k_pool, v_pool, jnp.asarray(pos_pool, jnp.int32),
        jnp.asarray(tables, jnp.int32), jnp.asarray(positions, jnp.int32),
        block_size=bs, block_h=block_h, interpret=interpret)
    return out.reshape(b, h, d).astype(out_dtype or q.dtype)
