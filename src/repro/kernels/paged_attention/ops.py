"""Public jit-friendly wrappers for the fused paged-attention kernels.

Launch geometry (the kv-head tile ``block_h``) is resolved through
:func:`repro.tune.dispatch.kernel_config` unless pinned by the caller —
tuned JSON-cache entry if one exists for this (batch-bucket, Hkv,
kv-capacity, dtype, rep, block_size, device) point, deterministic
heuristic otherwise.  The oracles live in ``ref``:
``paged_decode_ref`` / ``paged_decode_int8_ref`` / ``paged_decode_mla_ref``
for the decode variants and ``paged_prefill_ref`` for chunked prefill.

The capability boundary (what falls back to the gathered-XLA path) lives
in :func:`repro.tune.dispatch.kernel_unsupported_reason` — float, int8
and MLA-latent pools are covered for decode; float and int8 pools for
chunked prefill; sliding-window masking and MLA prefill (which needs the
decompressing ``kv_map_fn``) still gather.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.tune import dispatch as _dispatch
from repro.tune.space import divisor_clamp
from . import paged_attention as _k


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    pos_pool: jax.Array, tables: jax.Array,
                    positions: jax.Array, *, scale: Optional[float] = None,
                    block_h: Optional[int] = None, interpret: bool = False,
                    out_dtype=None) -> jax.Array:
    """Fused decode attention straight from the paged KV pool.

    q: [B, H, D]; k_pool/v_pool: [NB, BS, Hkv, D]; pos_pool: int32
    [NB, BS]; tables: int32 [B, pages] (-1 = unallocated); positions:
    int32 [B] (absolute position of each row's new token).
    Returns [B, H, D] in ``out_dtype`` (default q.dtype), FP32 accum.
    """
    b, h, d = q.shape
    nb, bs, hkv, dk = k_pool.shape
    if dk != d:
        raise ValueError(f"head_dim mismatch: q {d} vs pool {dk}")
    if h % hkv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {hkv}")
    if v_pool.shape != k_pool.shape or pos_pool.shape != (nb, bs):
        raise ValueError("pool buffers disagree on [num_blocks, block_size]")
    rep = h // hkv
    pages = tables.shape[1]
    scale = scale if scale is not None else d ** -0.5

    if block_h is None:
        cfg = _dispatch.kernel_config(
            "paged_attention", b=b, m=hkv, n=pages * bs,
            dtype=k_pool.dtype, mu=rep, group_size=bs, interpret=interpret)
        block_h = cfg.block_h
    block_h = divisor_clamp(block_h, hkv)

    # scale in f32 THEN round to the storage dtype — identical rounding
    # to decode_attend so fused and gathered paths stay interchangeable
    qg = (q.reshape(b, hkv, rep, d).astype(jnp.float32) * scale
          ).astype(k_pool.dtype)
    out = _k.paged_attention_tiled(
        qg, k_pool, v_pool, jnp.asarray(pos_pool, jnp.int32),
        jnp.asarray(tables, jnp.int32), jnp.asarray(positions, jnp.int32),
        block_size=bs, block_h=block_h, interpret=interpret)
    return out.reshape(b, h, d).astype(out_dtype or q.dtype)


def paged_attention_int8(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                         k_scale: jax.Array, v_scale: jax.Array,
                         pos_pool: jax.Array, tables: jax.Array,
                         positions: jax.Array, *,
                         scale: Optional[float] = None,
                         block_h: Optional[int] = None,
                         interpret: bool = False,
                         out_dtype=None) -> jax.Array:
    """Fused int8-KV decode attention: per-slot dequant scales ride the
    block-table DMA and fold in-kernel (``decode_attend`` int8 ordering).

    q: [B, H, D] float; k_pool/v_pool: int8 [NB, BS, Hkv, D];
    k_scale/v_scale: f32 [NB, BS, Hkv].  Returns [B, H, D].
    """
    b, h, d = q.shape
    nb, bs, hkv, dk = k_pool.shape
    if dk != d:
        raise ValueError(f"head_dim mismatch: q {d} vs pool {dk}")
    if h % hkv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {hkv}")
    if k_scale.shape != (nb, bs, hkv) or v_scale.shape != (nb, bs, hkv):
        raise ValueError("scale pools disagree with KV pool geometry")
    rep = h // hkv
    pages = tables.shape[1]
    scale = scale if scale is not None else d ** -0.5

    if block_h is None:
        cfg = _dispatch.kernel_config(
            "paged_attention", b=b, m=hkv, n=pages * bs,
            dtype=k_pool.dtype, mu=rep, group_size=bs, interpret=interpret)
        block_h = cfg.block_h
    block_h = divisor_clamp(block_h, hkv)

    # int8 pools compute in bf16 (decode_attend's compute dtype); the
    # q scaling still happens in f32 before the rounding
    qg = (q.reshape(b, hkv, rep, d).astype(jnp.float32) * scale
          ).astype(jnp.bfloat16)
    out = _k.paged_attention_int8_tiled(
        qg, k_pool, v_pool, k_scale, v_scale,
        jnp.asarray(pos_pool, jnp.int32), jnp.asarray(tables, jnp.int32),
        jnp.asarray(positions, jnp.int32),
        block_size=bs, block_h=block_h, interpret=interpret)
    return out.reshape(b, h, d).astype(out_dtype or q.dtype)


def paged_attention_mla(q_eff: jax.Array, q_rope: jax.Array,
                        ckv_pool: jax.Array, krope_pool: jax.Array,
                        pos_pool: jax.Array, tables: jax.Array,
                        positions: jax.Array, *, scale: float,
                        block_h: Optional[int] = None,
                        interpret: bool = False) -> jax.Array:
    """Fused MLA absorbed decode over the latent pool.

    q_eff: f32 [B, H, lora] (``w_uk`` absorbed by the caller); q_rope:
    f32 [B, H, rope_dim]; latent pools [NB, BS, lora] / [NB, BS,
    rope_dim].  Returns the latent context f32 [B, H, lora] — the caller
    applies ``w_uv``.  ``block_h`` tiles H (no kv-head replication).
    """
    b, h, lora = q_eff.shape
    nb, bs = pos_pool.shape
    if ckv_pool.shape != (nb, bs, lora):
        raise ValueError("ckv pool disagrees with q_eff lora dim")
    if krope_pool.shape[:2] != (nb, bs):
        raise ValueError("krope pool disagrees on [num_blocks, block_size]")
    pages = tables.shape[1]

    if block_h is None:
        cfg = _dispatch.kernel_config(
            "paged_attention", b=b, m=h, n=pages * bs,
            dtype=ckv_pool.dtype, mu=1, group_size=bs, interpret=interpret)
        block_h = cfg.block_h
    block_h = divisor_clamp(block_h, h)

    return _k.paged_attention_mla_tiled(
        q_eff.astype(jnp.float32), q_rope.astype(jnp.float32),
        ckv_pool, krope_pool, jnp.asarray(pos_pool, jnp.int32),
        jnp.asarray(tables, jnp.int32), jnp.asarray(positions, jnp.int32),
        scale=float(scale), block_size=bs, block_h=block_h,
        interpret=interpret)


def paged_prefill(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                  pos_pool: jax.Array, tables: jax.Array,
                  positions: jax.Array, *, scale: Optional[float] = None,
                  k_scale: Optional[jax.Array] = None,
                  v_scale: Optional[jax.Array] = None,
                  block_h: Optional[int] = None, interpret: bool = False,
                  out_dtype=None) -> jax.Array:
    """Fused chunked-prefill attention straight from the paged KV pool.

    q: [B, C, H, D] (the current chunk, already inserted into the pool);
    positions: int32 [B, C], -1 for pad rows (those return zeros).
    Passing ``k_scale``/``v_scale`` (f32 [NB, BS, Hkv]) enables the int8
    dequant fold.  Returns [B, C, H, D].
    """
    b, c, h, d = q.shape
    nb, bs, hkv, dk = k_pool.shape
    if dk != d:
        raise ValueError(f"head_dim mismatch: q {d} vs pool {dk}")
    if h % hkv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {hkv}")
    if positions.shape != (b, c):
        raise ValueError("positions must be [B, C] for chunked prefill")
    rep = h // hkv
    pages = tables.shape[1]
    scale = scale if scale is not None else d ** -0.5
    int8 = k_scale is not None

    if block_h is None:
        cfg = _dispatch.kernel_config(
            "paged_prefill", b=b, m=hkv, n=pages * bs,
            dtype=k_pool.dtype, mu=rep, group_size=bs, interpret=interpret)
        block_h = cfg.block_h
    block_h = divisor_clamp(block_h, hkv)

    cdt = jnp.bfloat16 if int8 else k_pool.dtype
    qg = (q.reshape(b, c, hkv, rep, d).astype(jnp.float32) * scale
          ).astype(cdt)
    out = _k.paged_prefill_tiled(
        qg, k_pool, v_pool, jnp.asarray(pos_pool, jnp.int32),
        jnp.asarray(tables, jnp.int32), jnp.asarray(positions, jnp.int32),
        k_scale, v_scale, block_size=bs, block_h=block_h,
        interpret=interpret)
    return out.reshape(b, c, h, d).astype(out_dtype or q.dtype)
