"""Logical-axis sharding: rules -> NamedShardings with divisibility fallback.

Every parameter/cache descriptor carries logical axis names (models/module
docstring).  ``build_shardings`` maps them onto mesh axes via a rules
table, with two production-grade guards:

  * **divisibility fallback** — if a dim is not divisible by its mesh-axis
    extent (mixtral's 8 experts on model=16, GQA kv=8 heads, ...), the
    mapping is dropped for that dim and the next candidate dim may claim
    the axis instead.  This is why one rules table serves all ten
    architectures: EP when experts divide, expert-internal TP otherwise;
    kv-head sharding when it divides, head_dim sharding otherwise.
  * **axis-conflict resolution** — a PartitionSpec may not repeat a mesh
    axis; dims are processed left-to-right and later dims skip axes
    already claimed.

Rules values may be a single mesh axis, a tuple (sharded over several,
e.g. FSDP over ("pod", "data")), or None.

BCQWeight leaves (quantized params) derive field shardings from the
logical axes of the original [*, out, in] weight: packed/alpha/z inherit
the row axis; the packed input dim inherits the input axis when the
*packed* byte count still divides.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bcq import BCQWeight

# logical axis -> mesh axis (or tuple of mesh axes, or None)
DEFAULT_RULES = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": "model",       # claimed only if heads axes fell through
    "mlp": "model",
    "experts": "model",        # EP when divisible, else falls to mlp-TP
    "embed": None,
    "lora": None,
    "batch": "data",
    "layers": None,
    "state": None,
    "kv_seq": "model",          # sequence-sharded KV when heads can't shard
}


def make_rules(*, fsdp: bool = False, multi_pod: bool = False,
               act_shard: bool = False, extra: Optional[dict] = None) -> dict:
    rules = dict(DEFAULT_RULES)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    rules["batch"] = data_axes
    if fsdp:
        rules["embed"] = data_axes      # 2-D weight sharding: TP x FSDP
    if act_shard:
        # shard the remat stash's embed dim over the model axis (training):
        # 60.5 -> 8.5 GiB/device on mamba2 train_4k
        rules["act_embed"] = "model"
    if extra:
        rules.update(extra)
    return rules


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(shape, axes, mesh: Mesh, rules: dict) -> P:
    """PartitionSpec for one array given its logical axes."""
    sizes = _axis_sizes(mesh)
    used = set()
    parts = []
    axes = axes or (None,) * len(shape)
    for dim, ax in zip(shape, axes):
        target = rules.get(ax) if ax is not None else None
        if target is None:
            parts.append(None)
            continue
        tup = (target,) if isinstance(target, str) else tuple(target)
        tup = tuple(a for a in tup if a in sizes and a not in used)
        total = int(np.prod([sizes[a] for a in tup])) if tup else 1
        if not tup or dim % total != 0:
            parts.append(None)          # divisibility fallback: replicate
            continue
        used.update(tup)
        parts.append(tup if len(tup) > 1 else tup[0])
    while parts and parts[-1] is None:
        parts.pop()                      # trailing Nones are implicit
    return P(*parts)


def _bcq_shardings(leaf: BCQWeight, axes, mesh: Mesh, rules: dict):
    """Shardings for a quantized weight's packed/alpha/z fields.

    General form: the original weight's logical axes are
    (*lead_batch, row_ax, in_ax) where lead_batch covers any stacked
    layers/experts dims kept as quantization batch dims; the packed
    planes insert a bits dim after the batch dims.
    """
    axes = tuple(axes) if axes else ()
    nb = leaf.packed.ndim - 3           # leading batch dims on the fields
    lead = axes[:nb] if len(axes) >= nb + 2 else (None,) * nb
    row_ax = axes[-2] if len(axes) >= 2 else None
    in_ax = axes[-1] if len(axes) >= 1 else None
    packed_axes = (*lead, None, row_ax, in_ax)
    alpha_axes = (*lead, None, row_ax, None)
    z_axes = (*lead, row_ax, None)
    return BCQWeight(
        packed=NamedSharding(mesh, spec_for(leaf.packed.shape, packed_axes,
                                            mesh, rules)),
        alpha=NamedSharding(mesh, spec_for(leaf.alpha.shape, alpha_axes,
                                           mesh, rules)),
        z=(NamedSharding(mesh, spec_for(leaf.z.shape, z_axes, mesh, rules))
           if leaf.z is not None else None),
        group_size=leaf.group_size, in_features=leaf.in_features,
        out_features=leaf.out_features, kind=leaf.kind,
    )


def _walk(tree, path=()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, path + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, path + (i,))
    else:
        yield path, tree


def _get(tree, path, default=None):
    node = tree
    try:
        for p in path:
            node = node[p]
        return node
    except (KeyError, IndexError, TypeError):
        return default


def _set(tree, path, value):
    if not path:
        return value
    head, rest = path[0], path[1:]
    if isinstance(tree, dict):
        out = dict(tree)
        out[head] = _set(tree[head], rest, value)
        return out
    out = list(tree)
    out[head] = _set(tree[head], rest, value)
    return type(tree)(out) if isinstance(tree, tuple) else out


def build_shardings(mesh: Mesh, tree, axes_tree, rules: dict):
    """NamedSharding pytree matching ``tree`` (params, opt state or cache).

    ``tree`` leaves: arrays / ShapeDtypeStructs / BCQWeight bundles.
    ``axes_tree`` leaves: logical-axes tuples at the same paths (BCQWeight
    paths resolve to the original dense weight's axes).
    """
    out = tree
    for path, leaf in list(_walk(tree)):
        if leaf is None:
            continue
        axes = _get(axes_tree, path)
        if isinstance(leaf, BCQWeight):
            out = _set(out, path, _bcq_shardings(leaf, axes, mesh, rules))
        elif hasattr(leaf, "shape"):
            spec = spec_for(leaf.shape, axes, mesh, rules)
            out = _set(out, path, NamedSharding(mesh, spec))
    return out


def batch_shardings(mesh: Mesh, specs: dict, rules: dict) -> dict:
    """Shardings for an input batch: leading dim = batch, rest replicated."""
    out = {}
    for k, v in specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, spec_for(v.shape, axes, mesh, rules))
    return out


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def shard_map_compat(fn, mesh: Mesh, *, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (it lived in
    ``jax.experimental.shard_map`` before being promoted).

    Always passes ``check_rep=False`` where the kwarg exists: the serve
    engine maps Pallas kernels, whose replication factors the checker
    cannot infer."""
    import inspect
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    kw = {}
    if "check_vma" in params:          # newest spelling of the checker
        kw["check_vma"] = False
    elif "check_rep" in params:
        kw["check_rep"] = False
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


# ---------------------------------------------------------------------------
# activation sharding constraints
# ---------------------------------------------------------------------------
# GSPMD propagation alone picks catastrophically bad layouts at a few key
# points (e.g. replicating [B, S, V] logits instead of sharding the vocab —
# a 26 GiB/device difference at train_4k scale).  Model code calls
# ``shard_act(x, logical_axes)``; launchers opt in via
# ``set_activation_rules(mesh, rules)``.  Without a registered mesh it is a
# no-op, so single-device tests/examples are unaffected.

_ACT: dict = {"mesh": None, "rules": None}


def set_activation_rules(mesh: Optional[Mesh], rules: Optional[dict]):
    _ACT["mesh"] = mesh
    _ACT["rules"] = rules


def shard_act(x, axes):
    mesh, rules = _ACT["mesh"], _ACT["rules"]
    if mesh is None or rules is None:
        return x
    spec = spec_for(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
