"""Unit tests for LUT construction/keying (core/lut.py)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import lut, bcq


def test_build_lut_entries():
    x = jnp.arange(1.0, 5.0)           # one group, mu=4
    table = lut.build_lut(x[None], mu=4)[0, 0]   # [16]
    # key p: bit j set -> +x_j
    for p in range(16):
        expect = sum((1 if (p >> j) & 1 else -1) * float(x[j]) for j in range(4))
        assert abs(float(table[p]) - expect) < 1e-6


def test_vertical_symmetry():
    """LUT[p] == -LUT[2^mu-1-p]  (paper §III-D, the hFFLUT property)."""
    x = jnp.array(np.random.default_rng(0).normal(size=(2, 8)).astype(np.float32))
    t = lut.build_lut(x, mu=4)
    flipped = t[..., ::-1]
    np.testing.assert_allclose(np.asarray(t), -np.asarray(flipped), atol=1e-6)


def test_half_lut_decode_matches_full():
    rng = np.random.default_rng(1)
    x = jnp.array(rng.normal(size=(3, 16)).astype(np.float32))
    keys = jnp.array(rng.integers(0, 16, size=(3, 4)), jnp.int32)
    full = lut.build_lut(x, mu=4)
    half = lut.build_half_lut(x, mu=4)
    assert half.shape[-1] == 8
    want = jnp.take_along_axis(full, keys[..., None], axis=-1)[..., 0]
    got = lut.decode_half_lut(half, keys, mu=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("mu", [1, 2, 4, 8])
def test_keys_from_packed_consistent(mu):
    rng = np.random.default_rng(mu)
    planes = jnp.array(rng.choice([-1.0, 1.0], size=(2, 4, 32)).astype(np.float32))
    packed = bcq.pack_planes(planes)
    keys = lut.keys_from_packed(packed, mu)
    want = lut.extract_keys(planes, mu)
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(want))


def test_generator_counts_match_paper():
    """mu=4 half table: 14 adds, 42%% fewer than the naive 24 (§III-E)."""
    naive = lut.naive_adder_count(4, half=True)
    tree = lut.generator_adder_count(4, half=True)
    assert naive == 24 and tree == 14
    assert 1 - tree / naive == pytest.approx(0.42, abs=0.01)


def test_generator_beats_k_racs_for_k_gt_4():
    """14 adds per LUT < k*(mu-1) straightforward adds when k > 4 (§III-E)."""
    adds_lut = lut.generator_adder_count(4, half=True)
    for k in (5, 8, 32):
        assert adds_lut < k * 3
    assert adds_lut > 4 * 3  # and not for k<=4 — the paper's break-even
