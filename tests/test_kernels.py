"""Per-kernel shape/dtype sweeps: Pallas kernels vs ref.py oracles.

Runs in interpret mode (CPU container); the kernel bodies execute exactly
as they would on TPU up to compiler scheduling.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bcq
from repro.kernels.lut_gemm import ops as lut_ops, ref as lut_ref
from repro.kernels.bcq_matmul import ops as mxu_ops, ref as mxu_ref


def _case(m, n, b, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    W = jnp.array(rng.normal(size=(m, n)).astype(np.float32))
    x = jnp.array(rng.normal(size=(b, n)).astype(np.float32), dtype=dtype)
    return W, x


SHAPES = [
    # (M, N, B) — aligned and deliberately ragged cases
    (128, 512, 8),
    (64, 128, 1),
    (96, 200, 5),
    (256, 384, 3),
    (33, 130, 2),
]


class TestLutGemmKernel:
    @pytest.mark.parametrize("m,n,b", SHAPES)
    @pytest.mark.parametrize("bits", [1, 2, 4])
    def test_matches_dense_oracle(self, m, n, b, bits):
        W, x = _case(m, n, b, seed=m + n + bits)
        wq = bcq.from_uniform(W, bits=bits, group_size=64)
        want = lut_ref.dense_ref(x, wq)
        got = lut_ops.lut_gemm(x, wq, interpret=True)
        scale = float(jnp.abs(want).max()) + 1e-6
        np.testing.assert_allclose(np.asarray(got) / scale,
                                   np.asarray(want) / scale, atol=2e-5)

    @pytest.mark.parametrize("read_mode", ["onehot", "select", "gather"])
    def test_read_modes_agree(self, read_mode):
        W, x = _case(128, 256, 4, seed=11)
        wq = bcq.quantize(W, bits=3, group_size=128, iters=2)
        want = lut_ref.dense_ref(x, wq)
        got = lut_ops.lut_gemm(x, wq, read_mode=read_mode, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=2e-4)

    @pytest.mark.parametrize("half_lut", [True, False])
    def test_half_lut_equivalence(self, half_lut):
        """hFFLUT decode must be bit-identical math to the full table."""
        W, x = _case(64, 128, 2, seed=3)
        wq = bcq.from_uniform(W, bits=4, group_size=64)
        got = lut_ops.lut_gemm(x, wq, half_lut=half_lut, interpret=True)
        want = lut_ref.dense_ref(x, wq)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=2e-4)

    @pytest.mark.parametrize("mu", [2, 4])
    def test_mu_values(self, mu):
        W, x = _case(64, 256, 2, seed=mu)
        wq = bcq.from_uniform(W, bits=2, group_size=64)
        got = lut_ops.lut_gemm(x, wq, mu=mu, interpret=True)
        want = lut_ref.dense_ref(x, wq)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=2e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        W, x = _case(64, 128, 2, seed=5, dtype=dtype)
        wq = bcq.from_uniform(W, bits=4, group_size=64)
        got = lut_ops.lut_gemm(x, wq, interpret=True)
        want = lut_ref.dense_ref(x, wq)
        assert got.dtype == dtype
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol * 10)

    def test_lut_ref_matches_dense_ref(self):
        """The lut_ref oracle itself must agree with dense dequant."""
        W, x = _case(96, 200, 5, seed=0)
        wq = bcq.from_uniform(W, bits=4, group_size=64)
        a = lut_ref.lut_ref(x, wq, mu=4, half_lut=True)
        b = lut_ref.dense_ref(x, wq)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=2e-4)

    def test_3d_batch(self):
        W, _ = _case(64, 128, 1, seed=8)
        x = jnp.array(np.random.default_rng(8).normal(size=(2, 3, 128)).astype(np.float32))
        wq = bcq.from_uniform(W, bits=4, group_size=64)
        got = lut_ops.lut_gemm(x, wq, interpret=True)
        assert got.shape == (2, 3, 64)
        want = lut_ref.dense_ref(x, wq)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=2e-4)


class TestBcqMatmulKernel:
    @pytest.mark.parametrize("m,n,b", SHAPES)
    @pytest.mark.parametrize("bits", [2, 4])
    def test_matches_oracle(self, m, n, b, bits):
        W, x = _case(m, n, b, seed=m * 2 + bits)
        wq = bcq.from_uniform(W, bits=bits, group_size=64)
        want = mxu_ref.bcq_matmul_ref(x, wq)
        got = mxu_ops.bcq_matmul(x, wq, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_agrees_with_lut_kernel(self):
        """Both kernels execute the same BCQ math."""
        W, x = _case(128, 512, 8, seed=21)
        wq = bcq.quantize(W, bits=3, group_size=128, iters=2)
        a = mxu_ops.bcq_matmul(x, wq, interpret=True)
        b = lut_ops.lut_gemm(x, wq, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=2e-4)
