"""Fused paged-attention decode kernel: equivalence matrix + dispatch.

Kernel level (interpret mode): the fused Pallas kernel must match the
gathered ``paged_view``-style oracle on GQA/MHA/MQA head layouts, f32
and bf16 pools, scrambled and *recycled* block tables (stale positions
from a dead owner), ``pos == -1`` pads, -1 table entries and fully-idle
rows, across the block_h launch-geometry space.

Model level: ``decode_step`` with ``paged_kernel="fused"`` must be
token/logit-equivalent to ``"gather"`` on every variant — running the
kernel where it is supported (GQA float pools) and falling back cleanly
through ``tune.dispatch.kernel_supports`` where it is not (MLA latent
caches, int8-KV pools, sliding-window masking).  The acceptance
invariant — the fused decode path never materializes the gathered view —
is pinned by monkeypatching ``paged_view`` to raise.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.kernels.paged_attention import (divisor_clamp, paged_attention,
                                           paged_decode_ref)
from repro.models import Model
from repro.models import attention as attn
from repro.serve import set_block_tables
from repro.tune import dispatch as tdispatch
from repro.tune.space import KernelConfig, candidate_configs, clamp_config

RNG = jax.random.PRNGKey(0)


def _f32(params):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params)


def _model(arch="opt_6_7b", **over):
    cfg = get_reduced(arch).replace(remat=False, dtype="float32",
                                    capacity_factor=8.0, **over)
    m = Model(cfg)
    return m, _f32(m.init(RNG))


def _pool_case(seed, *, b=3, h=8, hkv=4, d=16, nb=24, bs=4, pages=6,
               dtype=jnp.float32, recycle=True, idle_row=True):
    """Scrambled paged-decode problem: ragged live lengths, -1 table
    pads, stale positions in recycled blocks, optionally an idle row."""
    assert nb > b * pages, "pool too small for worst-case live blocks"
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.normal(size=(nb, bs, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(nb, bs, hkv, d)), dtype)
    q = jnp.asarray(rng.normal(size=(b, h, d)), dtype)
    tables = np.full((b, pages), -1, np.int32)
    pos = np.full((nb, bs), -1, np.int32)
    free = list(rng.permutation(np.arange(1, nb)))
    positions = np.zeros(b, np.int32)
    start = 1 if idle_row else 0         # row 0 idle: all table entries -1
    for row in range(start, b):
        live = int(rng.integers(1, pages * bs))
        positions[row] = live - 1
        for j in range(-(-live // bs)):
            blk = free.pop()
            tables[row, j] = blk
            pos[blk] = j * bs + np.arange(bs)
    if recycle and free:
        # a "freed" block still holding a dead owner's positions gets
        # handed to the last row at a DIFFERENT logical index: its stale
        # pos values fail the pos == logical check and must be masked
        stale = free.pop()
        pos[stale] = np.arange(bs)               # claims positions 0..bs-1
        j = int(np.argmax(tables[b - 1] < 0))
        if j > 0:                                 # logical index != 0
            tables[b - 1, j] = stale
    return (q, k, v, jnp.asarray(pos), jnp.asarray(tables),
            jnp.asarray(positions))


# ---------------------------------------------------------------------------
# kernel vs gathered oracle (interpret mode)
# ---------------------------------------------------------------------------


class TestFusedKernel:
    @pytest.mark.parametrize("h,hkv", [(8, 4), (4, 4), (6, 1)])  # GQA/MHA/MQA
    @pytest.mark.parametrize("seed", [0, 1])
    def test_matches_gathered_oracle(self, h, hkv, seed):
        q, k, v, pos, tables, positions = _pool_case(seed, h=h, hkv=hkv)
        want = paged_decode_ref(q, k, v, pos, tables, positions)
        got = paged_attention(q, k, v, pos, tables, positions,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_bf16_pool(self):
        q, k, v, pos, tables, positions = _pool_case(2, dtype=jnp.bfloat16)
        want = paged_decode_ref(q, k, v, pos, tables, positions)
        got = paged_attention(q, k, v, pos, tables, positions,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=2e-2)

    def test_idle_row_outputs_zero_not_nan(self):
        """A row whose table is all -1 (parked on the trash block) has no
        live slot: the kernel's l == 0 guard must yield zeros, the oracle
        likewise — never NaN from a fully-masked softmax."""
        q, k, v, pos, tables, positions = _pool_case(3, idle_row=True)
        got = paged_attention(q, k, v, pos, tables, positions,
                              interpret=True)
        want = paged_decode_ref(q, k, v, pos, tables, positions)
        assert np.isfinite(np.asarray(got)).all()
        assert np.abs(np.asarray(got)[0]).max() == 0.0
        assert np.abs(np.asarray(want)[0]).max() == 0.0

    def test_block_h_space_agrees(self):
        """Every clamped block_h launch produces the same numbers."""
        q, k, v, pos, tables, positions = _pool_case(4, h=8, hkv=4)
        want = paged_attention(q, k, v, pos, tables, positions,
                               interpret=True, block_h=4)
        for cfg in candidate_configs("paged_attention", b=3, m=4,
                                     n=6 * 4, group_size=4):
            got = paged_attention(q, k, v, pos, tables, positions,
                                  interpret=True, block_h=cfg.block_h)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-6)

    def test_recycled_block_stale_pos_masked(self):
        """Zeroing the recycled block's K/V must not change the output:
        its stale positions are masked, so its contents are dead."""
        q, k, v, pos, tables, positions = _pool_case(5, recycle=True)
        stale_blocks = sorted(set(range(k.shape[0]))
                              - set(np.asarray(tables).ravel().tolist()))
        base = paged_attention(q, k, v, pos, tables, positions,
                               interpret=True)
        k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
        # scribble over every block NOT in any table AND over the trash
        # block 0 — none of them may be observable
        for blk in (*stale_blocks, 0):
            k2[blk] = 7.7
            v2[blk] = -7.7
        got = paged_attention(q, jnp.asarray(k2, k.dtype),
                              jnp.asarray(v2, v.dtype), pos, tables,
                              positions, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# dispatch: config space, capability probe, divisor clamp
# ---------------------------------------------------------------------------


class TestDispatch:
    def test_kernel_config_resolves(self):
        cfg = tdispatch.kernel_config("paged_attention", b=4, m=4, n=128,
                                      dtype=jnp.float32, mu=2, group_size=8)
        assert isinstance(cfg, KernelConfig)
        assert cfg.block_h in (1, 2, 4)          # a divisor of m=4

    def test_divisor_clamp(self):
        assert divisor_clamp(0, 6) == 6
        assert divisor_clamp(4, 6) == 3
        assert divisor_clamp(5, 8) == 4
        assert divisor_clamp(1, 7) == 1
        assert clamp_config(KernelConfig(block_h=5), "paged_attention",
                            b=1, m=8, n=64, group_size=8).block_h == 4

    def test_candidates_deduped_and_lead_with_heuristic(self):
        cands = candidate_configs("paged_attention", b=2, m=4, n=64,
                                  group_size=8)
        assert len(cands) == len(set(cands))
        assert cands[0].block_h == 4             # heuristic: all heads

    def test_supports_matrix(self):
        ok = dict(m=8, n=64, group_size=8, n_kv_heads=4)
        assert tdispatch.kernel_supports("paged_attention", **ok)
        assert not tdispatch.kernel_supports(
            "paged_attention", **{**ok, "kv_dtype": "int8"})
        assert not tdispatch.kernel_supports(
            "paged_attention", **{**ok, "window": 16})
        assert not tdispatch.kernel_supports(
            "paged_attention", **{**ok, "latent": True})
        assert not tdispatch.kernel_supports(
            "paged_attention", m=7, n=64, group_size=8, n_kv_heads=4)
        # GEMM-kernel path unchanged by the new caps
        assert tdispatch.kernel_supports("lut_gemm", m=64, n=128,
                                         group_size=64)
        assert not tdispatch.kernel_supports("lut_gemm", m=64, n=128,
                                             group_size=12)

    def test_paged_kernel_mode_host_mirror(self):
        cfg = get_reduced("opt_6_7b").replace(paged_kernel="fused")
        assert attn.paged_kernel_mode(cfg, block_size=4, pages=8) == "fused"
        assert attn.paged_kernel_mode(cfg.replace(paged_kernel="gather"),
                                      block_size=4, pages=8) == "gather"
        # auto off-TPU: gather (the kernel is not hardware-native here)
        assert attn.paged_kernel_mode(cfg.replace(paged_kernel="auto"),
                                      block_size=4, pages=8) == "gather"
        for bad in ({"kv_cache_bits": 8},
                    {"attention": "mla", "kv_lora_rank": 8,
                     "qk_rope_head_dim": 4}):
            assert attn.paged_kernel_mode(cfg.replace(**bad),
                                          block_size=4, pages=8) == "gather"
        with pytest.raises(ValueError):
            attn.paged_kernel_mode(cfg.replace(paged_kernel="bogus"),
                                   block_size=4, pages=8)


# ---------------------------------------------------------------------------
# sliding-window: a fallback variant at the op-router level
# ---------------------------------------------------------------------------


def test_window_falls_back_and_masks():
    """window != 0 is not fused; the router must gather and apply the
    window mask (only reachable through direct op calls — SWA configs
    keep their ring caches and never page)."""
    q, k, v, pos, tables, positions = _pool_case(6, idle_row=False)
    cache = {"k": k, "v": v, "pos": pos, "block_tables": tables}
    assert not attn.fused_paged_supported(cache, q.shape[1], window=8)
    got = attn.paged_decode_attend(q[:, None], cache,
                                   positions[:, None], window=8,
                                   mode="fused")
    kv = attn.paged_view(cache)
    want = attn.decode_attend(q[:, None], kv, positions[:, None], window=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    # and the windowed result must differ from the unwindowed one for a
    # row with more than `window` live tokens (the mask actually bites)
    row = int(np.argmax(np.asarray(positions) >= 8))
    unwindowed = attn.paged_decode_attend(q[:, None], cache,
                                          positions[:, None], mode="gather")
    assert np.abs(np.asarray(got)[row] - np.asarray(unwindowed)[row]).max() \
        > 1e-6


# ---------------------------------------------------------------------------
# model level: fused vs gathered decode across variants
# ---------------------------------------------------------------------------


def _serve_tokens(m, params, mode, seed=7, steps=4):
    """Chunked-prefill a scrambled table then greedy-decode ``steps``
    tokens with the given paged_kernel mode; returns (tokens, logits)."""
    cfg = m.cfg.replace(paged_kernel=mode)
    mm = Model(cfg)
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 11)), jnp.int32)
    cache = mm.init_paged_cache(1, num_blocks=12, block_size=4,
                                max_blocks_per_seq=8)
    table = np.full((1, 8), -1, np.int32)
    table[0, :5] = [7, 2, 9, 4, 1]               # scrambled physical order
    cache = set_block_tables(cache, table)
    logits, cache = mm.prefill_chunk(params, {"tokens": toks}, cache,
                                     jnp.int32(0), jnp.int32(10))
    out, last = [], logits
    pos = 11
    for _ in range(steps):
        tok = int(np.argmax(np.asarray(last)[0]))
        out.append(tok)
        last, cache = mm.decode_step(
            params, jnp.asarray([[tok]], jnp.int32), cache, pos)
        pos += 1
    return out, np.asarray(last)


@pytest.mark.parametrize("arch,over", [
    ("opt_6_7b", {}),                            # GQA -> fused kernel
    ("phi4_mini_3_8b", {}),                      # RoPE GQA -> fused kernel
    ("opt_6_7b", {"kv_cache_bits": 8}),          # int8-KV -> clean fallback
])
def test_decode_fused_matches_gather(arch, over):
    m, params = _model(arch, **over)
    toks_f, logits_f = _serve_tokens(m, params, "fused")
    toks_g, logits_g = _serve_tokens(m, params, "gather")
    assert toks_f == toks_g
    np.testing.assert_allclose(logits_f, logits_g, atol=2e-4)
    assert np.isfinite(logits_f).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch,over", [
    ("minicpm3_4b", {}),                         # MLA -> clean fallback
    ("opt_6_7b", {"scan_layers": True}),         # stacked leaves, in-scan
])
def test_decode_fused_matches_gather_slow(arch, over):
    m, params = _model(arch, **over)
    toks_f, logits_f = _serve_tokens(m, params, "fused")
    toks_g, logits_g = _serve_tokens(m, params, "gather")
    assert toks_f == toks_g
    np.testing.assert_allclose(logits_f, logits_g, atol=2e-4)


def test_fused_decode_never_materializes_view(monkeypatch):
    """The acceptance invariant: with the fused kernel selected, the
    decode step must not call ``paged_view`` at all."""
    m, params = _model()

    def boom(cache):
        raise AssertionError("paged_view materialized on the fused "
                             "decode path")
    mm = Model(m.cfg.replace(paged_kernel="fused"))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, m.cfg.vocab_size, (1, 7)), jnp.int32)
    cache = mm.init_paged_cache(1, num_blocks=8, block_size=4,
                                max_blocks_per_seq=4)
    cache = set_block_tables(cache, np.array([[3, 1, 5, -1]], np.int32))
    _, cache = mm.prefill_chunk(params, {"tokens": toks}, cache,
                                jnp.int32(0), jnp.int32(6))
    monkeypatch.setattr(attn, "paged_view", boom)
    logits, _ = mm.decode_step(params, toks[:, :1], cache, 7)
    assert np.isfinite(np.asarray(logits)).all()
    # sanity: the gathered path DOES go through paged_view
    mg = Model(m.cfg.replace(paged_kernel="gather"))
    with pytest.raises(Exception):
        mg.decode_step(params, toks[:, :1], cache, 7)
