"""Fused paged-attention kernels: equivalence matrix + dispatch.

Kernel level (interpret mode): each fused Pallas kernel must match its
gathered ``paged_view``-style oracle — the float decode kernel on
GQA/MHA/MQA head layouts, f32 and bf16 pools; the int8 decode kernel
with per-slot dequant scales; the MLA latent decode kernel; and the
chunked-prefill kernel against ``blockwise_attention`` — all on
scrambled and *recycled* block tables (stale positions from a dead
owner), ``pos == -1`` pads, -1 table entries and fully-idle rows,
across the block_h launch-geometry space.

Model level: ``decode_step``/``prefill_chunk`` with
``paged_kernel="fused"`` must be token/logit-equivalent to ``"gather"``
on every variant — running the right kernel where one is supported (GQA
float, int8-KV, MLA decode) and falling back cleanly through
``tune.dispatch.kernel_unsupported_reason`` where none is (sliding-
window masking, MLA prefill).  The acceptance invariant — neither the
fused decode path nor the fused prefill path materializes the gathered
view — is pinned by monkeypatching ``paged_view`` to raise.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.kernels.paged_attention import (divisor_clamp, paged_attention,
                                           paged_attention_int8,
                                           paged_attention_mla,
                                           paged_decode_int8_ref,
                                           paged_decode_mla_ref,
                                           paged_decode_ref, paged_prefill,
                                           paged_prefill_ref)
from repro.models import Model
from repro.models import attention as attn
from repro.serve import set_block_tables
from repro.tune import cache as tcache
from repro.tune import dispatch as tdispatch
from repro.tune.space import KernelConfig, candidate_configs, clamp_config

RNG = jax.random.PRNGKey(0)


def _f32(params):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params)


def _model(arch="opt_6_7b", **over):
    cfg = get_reduced(arch).replace(remat=False, dtype="float32",
                                    capacity_factor=8.0, **over)
    m = Model(cfg)
    return m, _f32(m.init(RNG))


def _pool_case(seed, *, b=3, h=8, hkv=4, d=16, nb=24, bs=4, pages=6,
               dtype=jnp.float32, recycle=True, idle_row=True):
    """Scrambled paged-decode problem: ragged live lengths, -1 table
    pads, stale positions in recycled blocks, optionally an idle row."""
    assert nb > b * pages, "pool too small for worst-case live blocks"
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.normal(size=(nb, bs, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(nb, bs, hkv, d)), dtype)
    q = jnp.asarray(rng.normal(size=(b, h, d)), dtype)
    tables = np.full((b, pages), -1, np.int32)
    pos = np.full((nb, bs), -1, np.int32)
    free = list(rng.permutation(np.arange(1, nb)))
    positions = np.zeros(b, np.int32)
    start = 1 if idle_row else 0         # row 0 idle: all table entries -1
    for row in range(start, b):
        live = int(rng.integers(1, pages * bs))
        positions[row] = live - 1
        for j in range(-(-live // bs)):
            blk = free.pop()
            tables[row, j] = blk
            pos[blk] = j * bs + np.arange(bs)
    if recycle and free:
        # a "freed" block still holding a dead owner's positions gets
        # handed to the last row at a DIFFERENT logical index: its stale
        # pos values fail the pos == logical check and must be masked
        stale = free.pop()
        pos[stale] = np.arange(bs)               # claims positions 0..bs-1
        j = int(np.argmax(tables[b - 1] < 0))
        if j > 0:                                 # logical index != 0
            tables[b - 1, j] = stale
    return (q, k, v, jnp.asarray(pos), jnp.asarray(tables),
            jnp.asarray(positions))


def _int8_pool_case(seed, **kw):
    """_pool_case with the K/V pools re-drawn as int8 + per-slot scales
    (same scrambled/recycled table layout)."""
    q, k, v, pos, tables, positions = _pool_case(seed, **kw)
    rng = np.random.default_rng(seed + 100)
    nb, bs, hkv, d = k.shape
    k8 = jnp.asarray(np.clip(np.round(rng.normal(size=k.shape) * 40),
                             -127, 127), jnp.int8)
    v8 = jnp.asarray(np.clip(np.round(rng.normal(size=v.shape) * 40),
                             -127, 127), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.05, (nb, bs, hkv)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.01, 0.05, (nb, bs, hkv)), jnp.float32)
    return q, k8, v8, ks, vs, pos, tables, positions


def _mla_pool_case(seed, *, b=3, h=8, lora=12, dr=8, nb=24, bs=4, pages=6):
    """Latent-pool analogue of _pool_case (absorbed-decode inputs)."""
    _, _, _, pos, tables, positions = _pool_case(seed, b=b, nb=nb, bs=bs,
                                                 pages=pages)
    rng = np.random.default_rng(seed + 200)
    ckv = jnp.asarray(rng.normal(size=(nb, bs, lora)), jnp.float32)
    krope = jnp.asarray(rng.normal(size=(nb, bs, dr)), jnp.float32)
    q_eff = jnp.asarray(rng.normal(size=(b, h, lora)), jnp.float32)
    q_rope = jnp.asarray(rng.normal(size=(b, h, dr)), jnp.float32)
    return q_eff, q_rope, ckv, krope, pos, tables, positions


# ---------------------------------------------------------------------------
# kernel vs gathered oracle (interpret mode)
# ---------------------------------------------------------------------------


class TestFusedKernel:
    @pytest.mark.parametrize("h,hkv", [(8, 4), (4, 4), (6, 1)])  # GQA/MHA/MQA
    @pytest.mark.parametrize("seed", [0, 1])
    def test_matches_gathered_oracle(self, h, hkv, seed):
        q, k, v, pos, tables, positions = _pool_case(seed, h=h, hkv=hkv)
        want = paged_decode_ref(q, k, v, pos, tables, positions)
        got = paged_attention(q, k, v, pos, tables, positions,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_bf16_pool(self):
        q, k, v, pos, tables, positions = _pool_case(2, dtype=jnp.bfloat16)
        want = paged_decode_ref(q, k, v, pos, tables, positions)
        got = paged_attention(q, k, v, pos, tables, positions,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=2e-2)

    def test_idle_row_outputs_zero_not_nan(self):
        """A row whose table is all -1 (parked on the trash block) has no
        live slot: the kernel's l == 0 guard must yield zeros, the oracle
        likewise — never NaN from a fully-masked softmax."""
        q, k, v, pos, tables, positions = _pool_case(3, idle_row=True)
        got = paged_attention(q, k, v, pos, tables, positions,
                              interpret=True)
        want = paged_decode_ref(q, k, v, pos, tables, positions)
        assert np.isfinite(np.asarray(got)).all()
        assert np.abs(np.asarray(got)[0]).max() == 0.0
        assert np.abs(np.asarray(want)[0]).max() == 0.0

    def test_block_h_space_agrees(self):
        """Every clamped block_h launch produces the same numbers."""
        q, k, v, pos, tables, positions = _pool_case(4, h=8, hkv=4)
        want = paged_attention(q, k, v, pos, tables, positions,
                               interpret=True, block_h=4)
        for cfg in candidate_configs("paged_attention", b=3, m=4,
                                     n=6 * 4, group_size=4):
            got = paged_attention(q, k, v, pos, tables, positions,
                                  interpret=True, block_h=cfg.block_h)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-6)

    def test_recycled_block_stale_pos_masked(self):
        """Zeroing the recycled block's K/V must not change the output:
        its stale positions are masked, so its contents are dead."""
        q, k, v, pos, tables, positions = _pool_case(5, recycle=True)
        stale_blocks = sorted(set(range(k.shape[0]))
                              - set(np.asarray(tables).ravel().tolist()))
        base = paged_attention(q, k, v, pos, tables, positions,
                               interpret=True)
        k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
        # scribble over every block NOT in any table AND over the trash
        # block 0 — none of them may be observable
        for blk in (*stale_blocks, 0):
            k2[blk] = 7.7
            v2[blk] = -7.7
        got = paged_attention(q, jnp.asarray(k2, k.dtype),
                              jnp.asarray(v2, v.dtype), pos, tables,
                              positions, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   atol=1e-6)


class TestFusedInt8Kernel:
    @pytest.mark.parametrize("h,hkv", [(8, 4), (4, 4), (6, 1)])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_matches_gathered_int8_oracle(self, h, hkv, seed):
        """Per-slot scales fold in-kernel to the decode_attend ordering
        (bf16 compute -> atol at bf16-epsilon scale)."""
        q, k8, v8, ks, vs, pos, tables, positions = _int8_pool_case(
            seed, h=h, hkv=hkv)
        want = paged_decode_int8_ref(q, k8, v8, ks, vs, pos, tables,
                                     positions)
        got = paged_attention_int8(q, k8, v8, ks, vs, pos, tables,
                                   positions, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-2)

    def test_recycled_block_and_scales_masked(self):
        """Scribbling dead blocks' VALUES AND SCALES must be invisible —
        the scale rows ride the same table-driven DMA, so a stale block's
        scales must never touch a live score."""
        q, k8, v8, ks, vs, pos, tables, positions = _int8_pool_case(5)
        stale = sorted(set(range(k8.shape[0]))
                       - set(np.asarray(tables).ravel().tolist()))
        base = paged_attention_int8(q, k8, v8, ks, vs, pos, tables,
                                    positions, interpret=True)
        k2, v2 = np.asarray(k8).copy(), np.asarray(v8).copy()
        ks2, vs2 = np.asarray(ks).copy(), np.asarray(vs).copy()
        for blk in (*stale, 0):
            k2[blk], v2[blk] = 127, -127
            ks2[blk], vs2[blk] = 99.0, -99.0
        got = paged_attention_int8(
            q, jnp.asarray(k2, jnp.int8), jnp.asarray(v2, jnp.int8),
            jnp.asarray(ks2), jnp.asarray(vs2), pos, tables, positions,
            interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   atol=1e-6)

    def test_idle_row_outputs_zero_not_nan(self):
        q, k8, v8, ks, vs, pos, tables, positions = _int8_pool_case(3)
        got = paged_attention_int8(q, k8, v8, ks, vs, pos, tables,
                                   positions, interpret=True)
        assert np.isfinite(np.asarray(got)).all()
        assert np.abs(np.asarray(got)[0]).max() == 0.0


class TestFusedMlaKernel:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_matches_gathered_mla_oracle(self, seed):
        q_eff, q_rope, ckv, krope, pos, tables, positions = _mla_pool_case(
            seed)
        sc = (12 + 8) ** -0.5
        want = paged_decode_mla_ref(q_eff, q_rope, ckv, krope, pos, tables,
                                    positions, scale=sc)
        got = paged_attention_mla(q_eff, q_rope, ckv, krope, pos, tables,
                                  positions, scale=sc, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_block_h_space_agrees(self):
        q_eff, q_rope, ckv, krope, pos, tables, positions = _mla_pool_case(4)
        sc = (12 + 8) ** -0.5
        want = paged_attention_mla(q_eff, q_rope, ckv, krope, pos, tables,
                                  positions, scale=sc, interpret=True,
                                  block_h=8)
        for cfg in candidate_configs("paged_attention", b=3, m=8, n=24,
                                     group_size=4):
            got = paged_attention_mla(q_eff, q_rope, ckv, krope, pos,
                                      tables, positions, scale=sc,
                                      interpret=True, block_h=cfg.block_h)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-6)

    def test_recycled_block_stale_pos_masked(self):
        q_eff, q_rope, ckv, krope, pos, tables, positions = _mla_pool_case(5)
        sc = (12 + 8) ** -0.5
        stale = sorted(set(range(ckv.shape[0]))
                       - set(np.asarray(tables).ravel().tolist()))
        base = paged_attention_mla(q_eff, q_rope, ckv, krope, pos, tables,
                                   positions, scale=sc, interpret=True)
        c2, r2 = np.asarray(ckv).copy(), np.asarray(krope).copy()
        for blk in (*stale, 0):
            c2[blk], r2[blk] = 7.7, -7.7
        got = paged_attention_mla(q_eff, q_rope, jnp.asarray(c2),
                                  jnp.asarray(r2), pos, tables, positions,
                                  scale=sc, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   atol=1e-6)


class TestFusedPrefillKernel:
    def _chunk(self, seed, positions, *, b, c, h, d):
        """A chunk of queries per row: positions[row]-c+1 .. positions[row]
        (clamped at -1 pads below position 0)."""
        rng = np.random.default_rng(seed + 300)
        q = jnp.asarray(rng.normal(size=(b, c, h, d)), jnp.float32)
        cpos = (np.asarray(positions)[:, None]
                - np.arange(c - 1, -1, -1)[None]).astype(np.int32)
        cpos = np.where(cpos < 0, -1, cpos)
        return q, jnp.asarray(cpos)

    @pytest.mark.parametrize("h,hkv", [(8, 4), (4, 4), (6, 1)])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_matches_gathered_oracle(self, h, hkv, seed):
        _, k, v, pos, tables, positions = _pool_case(seed, h=h, hkv=hkv)
        q, cpos = self._chunk(seed, positions, b=3, c=5, h=h, d=16)
        want = paged_prefill_ref(q, k, v, pos, tables, cpos)
        got = paged_prefill(q, k, v, pos, tables, cpos, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    @pytest.mark.parametrize("seed", [0, 1])
    def test_int8_matches_gathered_oracle(self, seed):
        _, k8, v8, ks, vs, pos, tables, positions = _int8_pool_case(seed)
        q, cpos = self._chunk(seed, positions, b=3, c=5, h=8, d=16)
        want = paged_prefill_ref(q, k8, v8, pos, tables, cpos,
                                 k_scale=ks, v_scale=vs)
        got = paged_prefill(q, k8, v8, pos, tables, cpos, k_scale=ks,
                            v_scale=vs, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-2)

    def test_matches_blockwise_attention_on_live_rows(self):
        """End-to-end cross-check against the generic flash path the
        gathered prefill used: identical on non-pad query rows."""
        _, k, v, pos, tables, positions = _pool_case(7, idle_row=False)
        q, cpos = self._chunk(7, positions, b=3, c=5, h=8, d=16)
        cache = {"k": k, "v": v, "pos": pos, "block_tables": tables}
        kv = attn.paged_view(cache)
        want = attn.blockwise_attention(q, kv["k"], kv["v"], cpos,
                                        kv["pos"], causal=True)
        got = paged_prefill(q, k, v, pos, tables, cpos, interpret=True)
        live = np.asarray(cpos) >= 0
        np.testing.assert_allclose(np.asarray(got)[live],
                                   np.asarray(want)[live], atol=1e-5)

    def test_pad_rows_zero_causality_and_block_h(self):
        _, k, v, pos, tables, positions = _pool_case(8)
        q, cpos = self._chunk(8, positions, b=3, c=6, h=8, d=16)
        got = paged_prefill(q, k, v, pos, tables, cpos, interpret=True)
        assert np.isfinite(np.asarray(got)).all()
        pads = np.asarray(cpos) < 0
        if pads.any():
            assert np.abs(np.asarray(got)[pads]).max() == 0.0
        for bh in (1, 2, 4):
            same = paged_prefill(q, k, v, pos, tables, cpos, block_h=bh,
                                 interpret=True)
            np.testing.assert_allclose(np.asarray(same), np.asarray(got),
                                       atol=1e-6)
        # causality across the chunk boundary: scribbling a key slot
        # AFTER a query's position must not change that query's output
        row = 2
        qp = int(np.asarray(positions)[row])
        k2 = np.asarray(k).copy()
        blk = int(np.asarray(tables)[row, qp // 4])
        k2[blk, qp % 4] = 50.0                   # the row's LAST position
        got2 = paged_prefill(q, jnp.asarray(k2, k.dtype), v, pos, tables,
                             cpos, interpret=True)
        early = np.asarray(cpos)[row] < qp
        np.testing.assert_allclose(np.asarray(got2)[row][early[:, None]
                                                         .repeat(8, 1)],
                                   np.asarray(got)[row][early[:, None]
                                                        .repeat(8, 1)],
                                   atol=1e-6)
        changed = np.asarray(cpos)[row] == qp
        assert np.abs(np.asarray(got2)[row][changed]
                      - np.asarray(got)[row][changed]).max() > 1e-4


# ---------------------------------------------------------------------------
# dispatch: config space, capability probe, divisor clamp
# ---------------------------------------------------------------------------


class TestDispatch:
    def test_kernel_config_resolves(self):
        cfg = tdispatch.kernel_config("paged_attention", b=4, m=4, n=128,
                                      dtype=jnp.float32, mu=2, group_size=8)
        assert isinstance(cfg, KernelConfig)
        assert cfg.block_h in (1, 2, 4)          # a divisor of m=4

    def test_divisor_clamp(self):
        assert divisor_clamp(0, 6) == 6
        assert divisor_clamp(4, 6) == 3
        assert divisor_clamp(5, 8) == 4
        assert divisor_clamp(1, 7) == 1
        assert clamp_config(KernelConfig(block_h=5), "paged_attention",
                            b=1, m=8, n=64, group_size=8).block_h == 4

    def test_candidates_deduped_and_lead_with_heuristic(self):
        cands = candidate_configs("paged_attention", b=2, m=4, n=64,
                                  group_size=8)
        assert len(cands) == len(set(cands))
        assert cands[0].block_h == 4             # heuristic: all heads

    def test_supports_matrix(self):
        ok = dict(m=8, n=64, group_size=8, n_kv_heads=4)
        assert tdispatch.kernel_supports("paged_attention", **ok)
        # this PR's coverage lifts: int8-KV and MLA decode are fused now
        assert tdispatch.kernel_supports(
            "paged_attention", **{**ok, "kv_dtype": "int8"})
        assert tdispatch.kernel_supports(
            "paged_attention", **{**ok, "latent": True})
        assert tdispatch.kernel_supports("paged_prefill", **ok)
        assert tdispatch.kernel_supports(
            "paged_prefill", **{**ok, "kv_dtype": "int8"})
        # the reasons name WHICH cap failed, not just that one did
        rsn = tdispatch.kernel_unsupported_reason
        assert rsn("paged_attention", **{**ok, "window": 16}) == "window"
        assert rsn("paged_attention", m=7, n=64, group_size=8,
                   n_kv_heads=4) == "heads"
        assert rsn("paged_attention", **{**ok, "tp": 3}) == "tp"
        assert rsn("paged_attention",
                   **{**ok, "kv_dtype": "int4"}) == "kv_dtype"
        assert rsn("paged_prefill", **{**ok, "latent": True}) == "latent"
        assert rsn("nope", **ok) == "unknown_kernel"
        assert rsn("paged_attention", **ok) is None
        # GEMM-kernel path unchanged by the new caps
        assert tdispatch.kernel_supports("lut_gemm", m=64, n=128,
                                         group_size=64)
        assert rsn("lut_gemm", m=64, n=128, group_size=12) == "group_size"

    def test_unsupported_reason_lands_on_trace(self):
        from repro.obs.trace import Tracer, activate
        t = Tracer()
        with activate(t):
            tdispatch.kernel_unsupported_reason(
                "paged_prefill", m=8, n=64, group_size=8, n_kv_heads=4,
                latent=True)
        ev = [e for e in t.events
              if e.get("name") == "kernel_unsupported:paged_prefill"]
        assert ev and ev[0]["args"]["reason"] == "latent"

    def test_stale_cache_cannot_resurrect_bad_config(self):
        """Old tune-cache entries must not force an invalid launch on the
        new prefill kernel: 'paged_prefill' is a NEW cache-key kernel name
        (pre-PR caches keyed every paged entry 'paged_attention', so they
        can never collide), and even a poisoned entry is divisor-clamped
        before launch."""
        key = tcache.cache_key("paged_prefill", b=2, m=8, n=24,
                               dtype=jnp.float32, mu=2, group_size=4)
        assert "paged_prefill" in key            # disjoint from old keys
        poisoned = clamp_config(KernelConfig(block_h=5), "paged_prefill",
                                b=2, m=8, n=24, group_size=4)
        assert poisoned.block_h == 4             # clamped to a divisor of m

    def test_paged_kernel_mode_host_mirror(self):
        cfg = get_reduced("opt_6_7b").replace(paged_kernel="fused")
        assert attn.paged_kernel_mode(cfg, block_size=4, pages=8) == "fused"
        assert attn.paged_kernel_mode(cfg.replace(paged_kernel="gather"),
                                      block_size=4, pages=8) == "gather"
        # auto off-TPU: gather (the kernel is not hardware-native here)
        assert attn.paged_kernel_mode(cfg.replace(paged_kernel="auto"),
                                      block_size=4, pages=8) == "gather"
        # int8-KV and MLA decode are fused variants now
        for lifted in ({"kv_cache_bits": 8},
                       {"attention": "mla", "kv_lora_rank": 8,
                        "qk_rope_head_dim": 4}):
            assert attn.paged_kernel_mode(cfg.replace(**lifted),
                                          block_size=4, pages=8) == "fused"
        with pytest.raises(ValueError):
            attn.paged_kernel_mode(cfg.replace(paged_kernel="bogus"),
                                   block_size=4, pages=8)

    def test_paged_prefill_mode_host_mirror(self):
        cfg = get_reduced("opt_6_7b").replace(paged_kernel="fused")
        assert attn.paged_prefill_mode(cfg, block_size=4, pages=8) == "fused"
        assert attn.paged_prefill_mode(cfg.replace(kv_cache_bits=8),
                                       block_size=4, pages=8) == "fused"
        # MLA prefill needs the decompressing kv_map_fn -> stays gathered
        mla = cfg.replace(attention="mla", kv_lora_rank=8,
                          qk_rope_head_dim=4)
        assert attn.paged_prefill_mode(mla, block_size=4,
                                       pages=8) == "gather"
        assert attn.paged_prefill_mode(cfg.replace(paged_kernel="gather"),
                                       block_size=4, pages=8) == "gather"


# ---------------------------------------------------------------------------
# sliding-window: a fallback variant at the op-router level
# ---------------------------------------------------------------------------


def test_window_falls_back_and_masks():
    """window != 0 is not fused; the router must gather and apply the
    window mask (only reachable through direct op calls — SWA configs
    keep their ring caches and never page)."""
    q, k, v, pos, tables, positions = _pool_case(6, idle_row=False)
    cache = {"k": k, "v": v, "pos": pos, "block_tables": tables}
    assert not attn.fused_paged_supported(cache, q.shape[1], window=8)
    got = attn.paged_decode_attend(q[:, None], cache,
                                   positions[:, None], window=8,
                                   mode="fused")
    kv = attn.paged_view(cache)
    want = attn.decode_attend(q[:, None], kv, positions[:, None], window=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    # and the windowed result must differ from the unwindowed one for a
    # row with more than `window` live tokens (the mask actually bites)
    row = int(np.argmax(np.asarray(positions) >= 8))
    unwindowed = attn.paged_decode_attend(q[:, None], cache,
                                          positions[:, None], mode="gather")
    assert np.abs(np.asarray(got)[row] - np.asarray(unwindowed)[row]).max() \
        > 1e-6


# ---------------------------------------------------------------------------
# model level: fused vs gathered decode across variants
# ---------------------------------------------------------------------------


def _serve_tokens(m, params, mode, seed=7, steps=4):
    """Chunked-prefill a scrambled table then greedy-decode ``steps``
    tokens with the given paged_kernel mode; returns (tokens, logits)."""
    cfg = m.cfg.replace(paged_kernel=mode)
    mm = Model(cfg)
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 11)), jnp.int32)
    cache = mm.init_paged_cache(1, num_blocks=12, block_size=4,
                                max_blocks_per_seq=8)
    table = np.full((1, 8), -1, np.int32)
    table[0, :5] = [7, 2, 9, 4, 1]               # scrambled physical order
    cache = set_block_tables(cache, table)
    logits, cache = mm.prefill_chunk(params, {"tokens": toks}, cache,
                                     jnp.int32(0), jnp.int32(10))
    out, last = [], logits
    pos = 11
    for _ in range(steps):
        tok = int(np.argmax(np.asarray(last)[0]))
        out.append(tok)
        last, cache = mm.decode_step(
            params, jnp.asarray([[tok]], jnp.int32), cache, pos)
        pos += 1
    return out, np.asarray(last)


@pytest.mark.parametrize("arch,over,atol", [
    ("opt_6_7b", {}, 2e-4),                      # GQA -> fused kernels
    ("phi4_mini_3_8b", {}, 2e-4),                # RoPE GQA -> fused kernels
    # int8-KV -> fused kernels; the wider logit atol is the bf16
    # running-vs-global softmax rounding accumulated over the stack
    # (token equality is the serve-level contract)
    ("opt_6_7b", {"kv_cache_bits": 8}, 2e-3),
])
def test_decode_fused_matches_gather(arch, over, atol):
    m, params = _model(arch, **over)
    toks_f, logits_f = _serve_tokens(m, params, "fused")
    toks_g, logits_g = _serve_tokens(m, params, "gather")
    assert toks_f == toks_g
    np.testing.assert_allclose(logits_f, logits_g, atol=atol)
    assert np.isfinite(logits_f).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch,over", [
    ("minicpm3_4b", {}),                  # MLA -> fused decode, gathered
                                          # prefill (kv_map_fn decompress)
    ("opt_6_7b", {"scan_layers": True}),  # stacked leaves, in-scan
])
def test_decode_fused_matches_gather_slow(arch, over):
    m, params = _model(arch, **over)
    toks_f, logits_f = _serve_tokens(m, params, "fused")
    toks_g, logits_g = _serve_tokens(m, params, "gather")
    assert toks_f == toks_g
    np.testing.assert_allclose(logits_f, logits_g, atol=2e-4)


@pytest.mark.parametrize("over", [{}, {"kv_cache_bits": 8}])
def test_fused_paths_never_materialize_view(monkeypatch, over):
    """The acceptance invariant: with the fused kernels selected, neither
    the chunked-prefill step nor the decode step may call ``paged_view``
    at all — for float AND int8-KV pools."""
    m, params = _model(**over)

    def boom(cache):
        raise AssertionError("paged_view materialized on a fused path")
    mm = Model(m.cfg.replace(paged_kernel="fused"))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, m.cfg.vocab_size, (1, 7)), jnp.int32)
    cache = mm.init_paged_cache(1, num_blocks=8, block_size=4,
                                max_blocks_per_seq=4)
    cache = set_block_tables(cache, np.array([[3, 1, 5, -1]], np.int32))
    # patched BEFORE prefill: the chunked-prefill flash kernel reads the
    # pool through the block table, never through a gathered view
    monkeypatch.setattr(attn, "paged_view", boom)
    _, cache = mm.prefill_chunk(params, {"tokens": toks}, cache,
                                jnp.int32(0), jnp.int32(6))
    logits, _ = mm.decode_step(params, toks[:, :1], cache, 7)
    assert np.isfinite(np.asarray(logits)).all()
    # sanity: the gathered path DOES go through paged_view
    mg = Model(m.cfg.replace(paged_kernel="gather"))
    with pytest.raises(Exception):
        mg.decode_step(params, toks[:, :1], cache, 7)


@pytest.mark.slow
def test_fused_mla_decode_never_materializes_view(monkeypatch):
    """MLA: absorbed decode is fused (prefill legitimately gathers for
    the decompressing kv_map_fn, so patch only after the prefill)."""
    m, params = _model("minicpm3_4b")
    mm = Model(m.cfg.replace(paged_kernel="fused"))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, m.cfg.vocab_size, (1, 7)), jnp.int32)
    cache = mm.init_paged_cache(1, num_blocks=8, block_size=4,
                                max_blocks_per_seq=4)
    cache = set_block_tables(cache, np.array([[3, 1, 5, -1]], np.int32))
    _, cache = mm.prefill_chunk(params, {"tokens": toks}, cache,
                                jnp.int32(0), jnp.int32(6))

    def boom(cache):
        raise AssertionError("paged_view materialized on the fused MLA "
                             "decode path")
    monkeypatch.setattr(attn, "paged_view", boom)
    logits, _ = mm.decode_step(params, toks[:, :1], cache, 7)
    assert np.isfinite(np.asarray(logits)).all()
