"""Unit tests for BCQ quantization (core/bcq.py)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bcq


RNG = np.random.default_rng(42)


def _w(m, n, seed=0):
    return jnp.array(np.random.default_rng(seed).normal(size=(m, n)).astype(np.float32))


class TestPacking:
    def test_roundtrip(self):
        planes = jnp.array(RNG.choice([-1.0, 1.0], size=(3, 4, 64)).astype(np.float32))
        packed = bcq.pack_planes(planes)
        assert packed.dtype == jnp.uint8
        assert packed.shape == (3, 4, 8)
        out = bcq.unpack_planes(packed)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(planes))

    def test_pack_rejects_unaligned(self):
        with pytest.raises(ValueError):
            bcq.pack_planes(jnp.ones((1, 2, 9)))

    def test_accepts_01_planes(self):
        bits = jnp.array(RNG.integers(0, 2, size=(2, 2, 16)).astype(np.float32))
        packed = bcq.pack_planes(bits)
        out = bcq.unpack_planes(packed)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(bits) * 2 - 1)


class TestFromUniform:
    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    def test_matches_rtn_levels(self, bits):
        W = _w(32, 128, seed=bits)
        wq = bcq.from_uniform(W, bits=bits, group_size=64)
        dense = np.asarray(bcq.dequantize(wq))
        # independent RTN reference
        Wg = np.asarray(W).reshape(32, 2, 64)
        wmin, wmax = Wg.min(-1, keepdims=True), Wg.max(-1, keepdims=True)
        s = np.maximum((wmax - wmin) / (2**bits - 1), 1e-12)
        rtn = (np.clip(np.round((Wg - wmin) / s), 0, 2**bits - 1) * s + wmin)
        np.testing.assert_allclose(dense, rtn.reshape(32, 128), rtol=0, atol=1e-4)

    def test_unaligned_input_dim(self):
        W = _w(16, 100)
        wq = bcq.from_uniform(W, bits=4, group_size=64)
        assert wq.in_features == 100
        dense = bcq.dequantize(wq)
        assert dense.shape == (16, 100)
        # error bounded by half step of the worst group
        err = float(jnp.abs(dense - W).max())
        assert err < float((W.max() - W.min()) / 15)


class TestQuantize:
    def test_error_decreases_with_bits(self):
        W = _w(64, 256)
        errs = []
        for bits in (1, 2, 3, 4):
            wq = bcq.quantize(W, bits=bits, group_size=128, iters=4)
            errs.append(float(jnp.mean((bcq.dequantize(wq) - W) ** 2)))
        assert all(a > b for a, b in zip(errs, errs[1:])), errs

    def test_no_nans(self):
        # includes pathological all-positive rows (constant greedy planes)
        W = jnp.abs(_w(16, 128)) + 0.5
        wq = bcq.quantize(W, bits=3, group_size=64, iters=5)
        assert not bool(jnp.isnan(wq.alpha).any())
        assert not bool(jnp.isnan(bcq.dequantize(wq)).any())

    def test_beats_rtn(self):
        """Non-uniform BCQ <= uniform RTN error (paper Table VI premise)."""
        W = _w(64, 256, seed=7)
        for bits in (2, 3):
            e_bcq = float(jnp.mean((bcq.dequantize(
                bcq.quantize(W, bits, 128, iters=5)) - W) ** 2))
            e_rtn = float(jnp.mean((bcq.dequantize(
                bcq.from_uniform(W, bits, 128)) - W) ** 2))
            assert e_bcq <= e_rtn * 1.02, (bits, e_bcq, e_rtn)

    def test_alternating_improves_on_greedy(self):
        W = _w(64, 256, seed=9)
        e0 = float(jnp.mean((bcq.dequantize(
            bcq.quantize(W, 3, 128, iters=0)) - W) ** 2))
        e5 = float(jnp.mean((bcq.dequantize(
            bcq.quantize(W, 3, 128, iters=5)) - W) ** 2))
        assert e5 < e0, (e0, e5)

    def test_offset_helps_asymmetric(self):
        W = jnp.abs(_w(32, 128)) + 2.0   # strongly shifted distribution
        e_off = float(jnp.mean((bcq.dequantize(
            bcq.quantize(W, 2, 64, iters=4, with_offset=True)) - W) ** 2))
        e_no = float(jnp.mean((bcq.dequantize(
            bcq.quantize(W, 2, 64, iters=4, with_offset=False)) - W) ** 2))
        assert e_off < e_no

    def test_nbytes_compression(self):
        W = _w(128, 1024)
        wq = bcq.quantize(W, bits=4, group_size=128, iters=1)
        dense_bytes = 128 * 1024 * 2           # bf16
        assert wq.nbytes() < dense_bytes * 0.5  # >2x compression at 4-bit
