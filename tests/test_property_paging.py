"""Hypothesis property tests for the paged-KV block allocator, the
refcounted prefix sharing on top of it, and the trash-block write
routing.

Invariants (the ones the paged cache's correctness rests on):

  * random admit/extend/preempt/free sequences never double-book a
    block, never hand out the reserved trash block 0, and never leak —
    the pool's books balance after every operation and drain to empty;
  * random share/decref walks keep refcounts consistent: a holder is
    never added twice, ``free`` is a decref that recycles only at
    refcount 0, and releasing every holder drains the pool to empty
    (refcounts can never go negative — the pool asserts on any
    free-by-non-holder);
  * random scheduler walks (cache off) keep every running sequence's
    block table disjoint from every other's and free of block 0; with
    a prefix cache, tables may overlap but every block a sequence
    WRITES (decode append, prefill chunk) is privately owned —
    ``pool.writable(block, uid)`` — so shared blocks are immutable;
    after drain + ``cache.clear()`` the pool is fully free;
  * random register/lookup/evict walks on the prefix index only ever
    serve chains whose tokens verify, and eviction only touches
    cache-only (refcount-1) blocks;
  * device-side ``_paged_insert`` routes every invalid write (negative
    position, unallocated / out-of-range logical block) to the trash
    block: no write ever aliases a block owned by a live sequence.
  * the chunked-prefill flash kernel matches its gathered oracle on
    random pool layouts, ragged chunk positions and pad rows — every
    drawn (tables, positions, chunk) agrees with ``paged_prefill_ref``
    and pad query rows come back exactly zero.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -e .[dev]) — the suite "
           "must collect without it")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.kernels.paged_attention import paged_prefill, paged_prefill_ref
from repro.models import attention as attn
from repro.serve import BlockPool, PrefixCache, Request, Scheduler

_SET = dict(max_examples=40, deadline=None,
            suppress_health_check=[HealthCheck.too_slow])
# the kernel walk runs a Pallas interpret launch per example — keep the
# draw count low enough that the walk stays in tier-1 budget
_KSET = dict(max_examples=12, deadline=None,
             suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# BlockPool: alloc / free walks
# ---------------------------------------------------------------------------


@st.composite
def pool_ops(draw):
    num_blocks = draw(st.integers(3, 33))
    ops = draw(st.lists(
        st.tuples(st.sampled_from(["alloc", "free_some", "free_all"]),
                  st.integers(0, 7),        # owner id
                  st.integers(1, 6)),       # alloc count / free count
        min_size=1, max_size=40))
    return num_blocks, ops


@given(pool_ops())
@settings(**_SET)
def test_pool_never_double_books_or_leaks(case):
    num_blocks, ops = case
    pool = BlockPool(num_blocks, block_size=4)
    held = {}                                 # owner -> [blocks]
    for op, owner, n in ops:
        if op == "alloc":
            got = pool.alloc(owner, n)
            if got is None:                   # all-or-nothing: no strand
                assert n > pool.free_blocks
            else:
                assert 0 not in got
                for b in got:
                    for o, blks in held.items():
                        assert b not in blks, f"block {b} double-booked"
                held.setdefault(owner, []).extend(got)
        elif op == "free_some" and held.get(owner):
            take = held[owner][:n]
            pool.free(take, owner)
            held[owner] = held[owner][len(take):]
        elif op == "free_all" and held.get(owner):
            pool.free(held.pop(owner), owner)
        pool.check()
        assert pool.used_blocks == sum(len(b) for b in held.values())
    for owner, blks in list(held.items()):    # drain: nothing leaked
        pool.free(blks, owner)
    pool.check()
    assert pool.free_blocks == pool.capacity


# ---------------------------------------------------------------------------
# BlockPool: refcounted share / decref walks
# ---------------------------------------------------------------------------


@st.composite
def share_ops(draw):
    num_blocks = draw(st.integers(3, 33))
    ops = draw(st.lists(
        st.tuples(st.sampled_from(["alloc", "share", "decref"]),
                  st.integers(0, 5),        # owner id
                  st.integers(1, 4)),       # alloc count / op count
        min_size=1, max_size=50))
    return num_blocks, ops


@given(share_ops())
@settings(**_SET)
def test_pool_refcounts_balance_and_drain(case):
    """Random alloc/share/decref walks: the holder model below mirrors
    the pool exactly, refcounts match it after every op, a shared block
    only recycles when its LAST holder releases, and releasing every
    hold drains the pool to empty."""
    num_blocks, ops = case
    pool = BlockPool(num_blocks, block_size=4)
    held = {}                                 # owner -> [blocks] (holds)
    for op, owner, n in ops:
        if op == "alloc":
            got = pool.alloc(owner, n)
            if got is None:
                assert n > pool.free_blocks
            else:
                held.setdefault(owner, []).extend(got)
        elif op == "share":
            # share a block some OTHER owner holds and this one doesn't
            mine = set(held.get(owner, []))
            cands = sorted({b for o, blks in held.items() if o != owner
                            for b in blks} - mine)
            if cands:
                b = cands[n % len(cands)]
                rc = pool.refcount(b)
                pool.share([b], owner)
                held.setdefault(owner, []).append(b)
                assert pool.refcount(b) == rc + 1
        elif op == "decref" and held.get(owner):
            take = held[owner][:n]
            for b in take:
                rc = pool.refcount(b)
                was_free = pool.free_blocks
                pool.free([b], owner)
                held[owner].remove(b)
                assert pool.refcount(b) == rc - 1
                # recycle exactly at refcount 0, never before
                assert pool.free_blocks == was_free + (rc == 1)
        pool.check()
        # the pool's distinct-block count matches the holder model
        assert pool.used_blocks == len({b for blks in held.values()
                                        for b in blks})
        for o, blks in held.items():
            for b in blks:
                rc = sum(bb == b for bl in held.values() for bb in bl)
                assert pool.refcount(b) == rc
                # the immutability predicate: sole holder <=> writable
                assert pool.writable(b, o) == (rc == 1)
    for owner, blks in list(held.items()):    # drain every hold
        pool.free(blks, owner)
    pool.check()
    assert pool.free_blocks == pool.capacity


# ---------------------------------------------------------------------------
# PrefixCache: register / lookup / evict walks
# ---------------------------------------------------------------------------


@st.composite
def prefix_cases(draw):
    num_blocks = draw(st.integers(6, 24))
    block_size = draw(st.sampled_from([2, 4]))
    # low-entropy token streams so chains collide on purpose
    streams = draw(st.lists(
        st.lists(st.integers(0, 2), min_size=1, max_size=24),
        min_size=1, max_size=6))
    evicts = draw(st.lists(st.integers(1, 4), max_size=4))
    return num_blocks, block_size, streams, evicts


@given(prefix_cases())
@settings(**_SET)
def test_prefix_cache_serves_only_verified_chains(case):
    """Register every stream's full blocks (private writer blocks), then:
    every lookup's adopted chain must token-match the query; eviction
    frees only cache-only blocks; clear() drains the pool."""
    num_blocks, bs, streams, evicts = case
    pool = BlockPool(num_blocks, bs)
    cache = PrefixCache(pool)
    for uid, toks in enumerate(streams):
        key, blocks = None, []
        for j in range(len(toks) // bs):
            got = pool.alloc((uid, j), 1)     # writer's private block
            if got is None:
                break
            blocks.append(((uid, j), got[0]))
            key = cache.register(key, tuple(toks[j * bs:(j + 1) * bs]),
                                 got[0])
            assert key is not None            # int tuples don't collide
        for owner, b in blocks:               # writer retires; cache holds
            pool.free([b], owner)
        pool.check()
    for toks in streams:
        hits, _ = cache.lookup(toks, len(toks) // bs)
        # adopted chain must reproduce the query's tokens block-for-block
        for j, blk in enumerate(hits):
            e = next(e for e in cache.entries.values() if e.block == blk
                     and e.depth == j)
            assert e.tokens == tuple(toks[j * bs:(j + 1) * bs])
        assert pool.refcount(hits[0]) >= 1 if hits else True
    for n in evicts:
        before = len(cache)
        freed = cache.evict(n)
        assert freed <= n and len(cache) == before - freed
        pool.check()
    cache.clear()
    pool.check()
    assert pool.free_blocks == pool.capacity, "cache leaked blocks"


# ---------------------------------------------------------------------------
# Scheduler: random admit/extend/preempt walks (model-free)
# ---------------------------------------------------------------------------


@st.composite
def sched_cases(draw):
    num_blocks = draw(st.integers(4, 24))
    block_size = draw(st.sampled_from([2, 4, 8]))
    rows = draw(st.integers(1, 4))
    reqs = draw(st.lists(
        st.tuples(st.integers(1, 40),         # prompt len
                  st.integers(1, 8)),         # max_new_tokens
        min_size=1, max_size=8))
    return num_blocks, block_size, rows, reqs


@given(sched_cases())
@settings(**_SET)
def test_scheduler_tables_stay_disjoint_and_drain(case):
    num_blocks, block_size, rows, reqs = case
    pool = BlockPool(num_blocks, block_size)
    sched = Scheduler(pool, rows=rows, buckets=(8,),
                      max_blocks_per_seq=max(num_blocks - 1, 1))
    for i, (plen, new) in enumerate(reqs):
        sched.submit(Request(uid=i, prompt=np.zeros(plen, np.int32),
                             max_new_tokens=new))
    for _ in range(400):
        if not sched.has_work():
            break
        plan = sched.plan_tick()
        seen = set()
        for seq in sched.running:
            assert 0 not in seq.table, "trash block handed to a sequence"
            tset = set(seq.table)
            assert len(tset) == len(seq.table)
            assert not (tset & seen), "block shared between live sequences"
            seen |= tset
        pool.check()
        for seq in plan.failed:
            sched.finish(seq)
            seq.req.done = True
        for seq in plan.decode:
            seq.kv_len += 1
            seq.req.out_tokens.append(0)
            if len(seq.req.out_tokens) >= seq.req.max_new_tokens:
                sched.finish(seq)
                seq.req.done = True
        if plan.prefill is not None:
            seq = plan.prefill.seq
            seq.kv_len += plan.prefill.length
            if seq.kv_len >= seq.prefill_target:
                seq.req.out_tokens.append(0)
                if len(seq.req.out_tokens) >= seq.req.max_new_tokens:
                    sched.finish(seq)
                    seq.req.done = True
    assert not sched.has_work(), "scheduler wedged"
    pool.check()
    assert pool.free_blocks == pool.capacity, "blocks leaked at drain"


@st.composite
def prefix_sched_cases(draw):
    num_blocks = draw(st.integers(6, 24))
    block_size = draw(st.sampled_from([2, 4]))
    rows = draw(st.integers(1, 4))
    # low-entropy prompts drawn from {0, 1} so block-aligned prefixes
    # collide constantly — the walk exercises sharing, CoW and eviction
    reqs = draw(st.lists(
        st.tuples(st.lists(st.integers(0, 1), min_size=1, max_size=24),
                  st.integers(1, 8)),         # max_new_tokens
        min_size=1, max_size=8))
    return num_blocks, block_size, rows, reqs


@given(prefix_sched_cases())
@settings(**_SET)
def test_scheduler_with_prefix_cache_never_writes_shared_blocks(case):
    """Random scheduler walks with the prefix cache on: block tables may
    overlap between sequences (that is the feature), but every block a
    sequence is about to WRITE — the decode append's target and every
    block a prefill chunk covers — is held by that sequence alone.
    Refcounts stay balanced every tick, and after drain +
    ``cache.clear()`` the pool is fully free."""
    num_blocks, block_size, rows, reqs = case
    pool = BlockPool(num_blocks, block_size)
    cache = PrefixCache(pool)
    sched = Scheduler(pool, rows=rows, buckets=(8,),
                      max_blocks_per_seq=max(num_blocks - 1, 1),
                      prefix_cache=cache)
    for i, (ptoks, new) in enumerate(reqs):
        sched.submit(Request(uid=i, prompt=np.asarray(ptoks, np.int32),
                             max_new_tokens=new))
    shared_seen = 0
    for _ in range(400):
        if not sched.has_work():
            break
        plan = sched.plan_tick()
        for seq in sched.running:
            assert 0 not in seq.table, "trash block handed to a sequence"
            assert len(set(seq.table)) == len(seq.table)
            # adopted blocks sit at the same logical index for every
            # holder: kv_len never went backwards past a shared block
            assert seq.kv_len >= seq.shared_tokens \
                or seq.kv_len == 0                  # preempted, not yet rerun
        shared_seen += sum(pool.refcount(b) > 2 for s in sched.running
                           for b in s.table)
        for seq in plan.decode:
            blk = seq.table[seq.kv_len // block_size]
            assert pool.writable(blk, seq.uid), \
                "decode append targets a shared block"
        if plan.prefill is not None:
            seq, c = plan.prefill.seq, plan.prefill
            lo, hi = c.start // block_size, \
                (c.start + c.length - 1) // block_size
            for blk in seq.table[lo:hi + 1]:
                assert pool.writable(blk, seq.uid), \
                    "prefill chunk covers a shared block"
        pool.check()
        for seq in plan.failed:
            sched.finish(seq)
            seq.req.done = True
        for seq in plan.decode:
            seq.kv_len += 1
            seq.req.out_tokens.append(0)
            if len(seq.req.out_tokens) >= seq.req.max_new_tokens:
                sched.finish(seq)
                seq.req.done = True
        if plan.prefill is not None:
            seq = plan.prefill.seq
            seq.kv_len += plan.prefill.length
            if seq.kv_len >= seq.prefill_target:
                seq.req.out_tokens.append(0)
                if len(seq.req.out_tokens) >= seq.req.max_new_tokens:
                    sched.finish(seq)
                    seq.req.done = True
    assert not sched.has_work(), "scheduler wedged"
    pool.check()
    # retired sequences released their holds; only the cache remains
    assert pool.used_blocks == len(cache)
    cache.clear()
    pool.check()
    assert pool.free_blocks == pool.capacity, "blocks leaked at drain"


# ---------------------------------------------------------------------------
# device side: trash-block routing never aliases a live block
# ---------------------------------------------------------------------------


@st.composite
def insert_cases(draw):
    nb = draw(st.integers(3, 10))
    bs = draw(st.sampled_from([2, 4]))
    pages = draw(st.integers(1, 4))
    n_alloc = draw(st.integers(0, min(pages, nb - 1)))
    at = draw(st.integers(-2 * bs, (pages + 2) * bs))   # incl. invalid
    s = draw(st.integers(1, 2 * bs))
    seed = draw(st.integers(0, 999))
    return nb, bs, pages, n_alloc, at, s, seed


@given(insert_cases())
@settings(**_SET)
def test_paged_insert_only_touches_owned_or_trash(case):
    nb, bs, pages, n_alloc, at, s, seed = case
    rng = np.random.default_rng(seed)
    perm = rng.permutation(np.arange(1, nb))[:n_alloc]
    table = np.full((1, pages), -1, np.int32)
    table[0, :n_alloc] = perm
    cache = {
        "k": jnp.zeros((nb, bs, 2, 4), jnp.float32),
        "pos": jnp.full((nb, bs), -1, jnp.int32),
        "block_tables": jnp.asarray(table),
    }
    upd = jnp.asarray(rng.normal(size=(1, s, 2, 4)), jnp.float32)
    new = attn.cache_insert(cache, {"k": upd}, at)
    touched = np.nonzero(
        np.abs(np.asarray(new["k"]) - np.asarray(cache["k"])).reshape(
            nb, -1).max(1))[0]
    pos_touched = np.nonzero(
        (np.asarray(new["pos"]) != np.asarray(cache["pos"])).reshape(
            nb, -1).max(1))[0]
    allowed = set(perm.tolist()) | {0}        # owned blocks + trash
    for blk in (*touched, *pos_touched):
        assert blk in allowed, f"write aliased unowned block {blk}"
    # positions recorded in owned blocks must be the logical positions
    # of this write; the trash block never records a live position
    newpos = np.asarray(new["pos"])
    write_lo, write_hi = at, at + s
    for j, blk in enumerate(table[0]):
        if blk < 0:
            continue
        got = newpos[blk]
        for i, p in enumerate(got):
            logical = j * bs + i
            if write_lo <= logical < write_hi and logical >= 0:
                assert p == logical
            else:
                assert p == -1
    assert (newpos[0] == -1).all(), "trash block recorded a live position"


# ---------------------------------------------------------------------------
# device side: chunked-prefill kernel vs gathered oracle
# ---------------------------------------------------------------------------


@st.composite
def prefill_kernel_cases(draw):
    bs = draw(st.sampled_from([2, 4]))
    pages = draw(st.integers(1, 4))
    b = draw(st.integers(1, 3))
    h, hkv = draw(st.sampled_from([(4, 2), (2, 2), (3, 1)]))
    c = draw(st.integers(1, 2 * bs))          # chunk length
    # per-row context end within capacity; small values force pad rows
    ends = draw(st.lists(st.integers(0, pages * bs - 1),
                         min_size=b, max_size=b))
    seed = draw(st.integers(0, 999))
    return bs, pages, b, h, hkv, c, ends, seed


@given(prefill_kernel_cases())
@settings(**_KSET)
def test_prefill_kernel_matches_oracle_on_random_layouts(case):
    bs, pages, b, h, hkv, c, ends, seed = case
    nb = b * pages + 2
    rng = np.random.default_rng(seed)
    d = 8
    k = jnp.asarray(rng.normal(size=(nb, bs, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(nb, bs, hkv, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, c, h, d)), jnp.float32)
    tables = np.full((b, pages), -1, np.int32)
    pos = np.full((nb, bs), -1, np.int32)
    free = list(rng.permutation(np.arange(1, nb)))
    for row, end in enumerate(ends):
        for j in range(end // bs + 1):
            blk = free.pop()
            tables[row, j] = blk
            pos[blk] = j * bs + np.arange(bs)
    # the chunk ends at each row's context end; earlier rows pad at -1
    cpos = (np.asarray(ends)[:, None]
            - np.arange(c - 1, -1, -1)[None]).astype(np.int32)
    cpos = np.where(cpos < 0, -1, cpos)
    got = paged_prefill(q, k, v, jnp.asarray(pos), jnp.asarray(tables),
                        jnp.asarray(cpos), interpret=True)
    want = paged_prefill_ref(q, k, v, jnp.asarray(pos),
                             jnp.asarray(tables), jnp.asarray(cpos))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()
    pads = cpos < 0
    if pads.any():
        assert np.abs(np.asarray(got)[pads]).max() == 0.0
