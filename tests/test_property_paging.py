"""Hypothesis property tests for the paged-KV block allocator and the
trash-block write routing.

Invariants (the ones the paged cache's correctness rests on):

  * random admit/extend/preempt/free sequences never double-book a
    block, never hand out the reserved trash block 0, and never leak —
    the pool's books balance after every operation and drain to empty;
  * random scheduler walks keep every running sequence's block table
    disjoint from every other's and free of block 0;
  * device-side ``_paged_insert`` routes every invalid write (negative
    position, unallocated / out-of-range logical block) to the trash
    block: no write ever aliases a block owned by a live sequence.
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -e .[dev]) — the suite "
           "must collect without it")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.models import attention as attn
from repro.serve import BlockPool, Request, Scheduler

_SET = dict(max_examples=40, deadline=None,
            suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# BlockPool: alloc / free walks
# ---------------------------------------------------------------------------


@st.composite
def pool_ops(draw):
    num_blocks = draw(st.integers(3, 33))
    ops = draw(st.lists(
        st.tuples(st.sampled_from(["alloc", "free_some", "free_all"]),
                  st.integers(0, 7),        # owner id
                  st.integers(1, 6)),       # alloc count / free count
        min_size=1, max_size=40))
    return num_blocks, ops


@given(pool_ops())
@settings(**_SET)
def test_pool_never_double_books_or_leaks(case):
    num_blocks, ops = case
    pool = BlockPool(num_blocks, block_size=4)
    held = {}                                 # owner -> [blocks]
    for op, owner, n in ops:
        if op == "alloc":
            got = pool.alloc(owner, n)
            if got is None:                   # all-or-nothing: no strand
                assert n > pool.free_blocks
            else:
                assert 0 not in got
                for b in got:
                    for o, blks in held.items():
                        assert b not in blks, f"block {b} double-booked"
                held.setdefault(owner, []).extend(got)
        elif op == "free_some" and held.get(owner):
            take = held[owner][:n]
            pool.free(take, owner)
            held[owner] = held[owner][len(take):]
        elif op == "free_all" and held.get(owner):
            pool.free(held.pop(owner), owner)
        pool.check()
        assert pool.used_blocks == sum(len(b) for b in held.values())
    for owner, blks in list(held.items()):    # drain: nothing leaked
        pool.free(blks, owner)
    pool.check()
    assert pool.free_blocks == pool.capacity


# ---------------------------------------------------------------------------
# Scheduler: random admit/extend/preempt walks (model-free)
# ---------------------------------------------------------------------------


@st.composite
def sched_cases(draw):
    num_blocks = draw(st.integers(4, 24))
    block_size = draw(st.sampled_from([2, 4, 8]))
    rows = draw(st.integers(1, 4))
    reqs = draw(st.lists(
        st.tuples(st.integers(1, 40),         # prompt len
                  st.integers(1, 8)),         # max_new_tokens
        min_size=1, max_size=8))
    return num_blocks, block_size, rows, reqs


@given(sched_cases())
@settings(**_SET)
def test_scheduler_tables_stay_disjoint_and_drain(case):
    num_blocks, block_size, rows, reqs = case
    pool = BlockPool(num_blocks, block_size)
    sched = Scheduler(pool, rows=rows, buckets=(8,),
                      max_blocks_per_seq=max(num_blocks - 1, 1))
    for i, (plen, new) in enumerate(reqs):
        sched.submit(Request(uid=i, prompt=np.zeros(plen, np.int32),
                             max_new_tokens=new))
    for _ in range(400):
        if not sched.has_work():
            break
        plan = sched.plan_tick()
        seen = set()
        for seq in sched.running:
            assert 0 not in seq.table, "trash block handed to a sequence"
            tset = set(seq.table)
            assert len(tset) == len(seq.table)
            assert not (tset & seen), "block shared between live sequences"
            seen |= tset
        pool.check()
        for seq in plan.failed:
            sched.finish(seq)
            seq.req.done = True
        for seq in plan.decode:
            seq.kv_len += 1
            seq.req.out_tokens.append(0)
            if len(seq.req.out_tokens) >= seq.req.max_new_tokens:
                sched.finish(seq)
                seq.req.done = True
        if plan.prefill is not None:
            seq = plan.prefill.seq
            seq.kv_len += plan.prefill.length
            if seq.kv_len >= seq.prefill_target:
                seq.req.out_tokens.append(0)
                if len(seq.req.out_tokens) >= seq.req.max_new_tokens:
                    sched.finish(seq)
                    seq.req.done = True
    assert not sched.has_work(), "scheduler wedged"
    pool.check()
    assert pool.free_blocks == pool.capacity, "blocks leaked at drain"


# ---------------------------------------------------------------------------
# device side: trash-block routing never aliases a live block
# ---------------------------------------------------------------------------


@st.composite
def insert_cases(draw):
    nb = draw(st.integers(3, 10))
    bs = draw(st.sampled_from([2, 4]))
    pages = draw(st.integers(1, 4))
    n_alloc = draw(st.integers(0, min(pages, nb - 1)))
    at = draw(st.integers(-2 * bs, (pages + 2) * bs))   # incl. invalid
    s = draw(st.integers(1, 2 * bs))
    seed = draw(st.integers(0, 999))
    return nb, bs, pages, n_alloc, at, s, seed


@given(insert_cases())
@settings(**_SET)
def test_paged_insert_only_touches_owned_or_trash(case):
    nb, bs, pages, n_alloc, at, s, seed = case
    rng = np.random.default_rng(seed)
    perm = rng.permutation(np.arange(1, nb))[:n_alloc]
    table = np.full((1, pages), -1, np.int32)
    table[0, :n_alloc] = perm
    cache = {
        "k": jnp.zeros((nb, bs, 2, 4), jnp.float32),
        "pos": jnp.full((nb, bs), -1, jnp.int32),
        "block_tables": jnp.asarray(table),
    }
    upd = jnp.asarray(rng.normal(size=(1, s, 2, 4)), jnp.float32)
    new = attn.cache_insert(cache, {"k": upd}, at)
    touched = np.nonzero(
        np.abs(np.asarray(new["k"]) - np.asarray(cache["k"])).reshape(
            nb, -1).max(1))[0]
    pos_touched = np.nonzero(
        (np.asarray(new["pos"]) != np.asarray(cache["pos"])).reshape(
            nb, -1).max(1))[0]
    allowed = set(perm.tolist()) | {0}        # owned blocks + trash
    for blk in (*touched, *pos_touched):
        assert blk in allowed, f"write aliased unowned block {blk}"
    # positions recorded in owned blocks must be the logical positions
    # of this write; the trash block never records a live position
    newpos = np.asarray(new["pos"])
    write_lo, write_hi = at, at + s
    for j, blk in enumerate(table[0]):
        if blk < 0:
            continue
        got = newpos[blk]
        for i, p in enumerate(got):
            logical = j * bs + i
            if write_lo <= logical < write_hi and logical >= 0:
                assert p == logical
            else:
                assert p == -1
    assert (newpos[0] == -1).all(), "trash block recorded a live position"
