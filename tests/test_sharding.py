"""Distribution-layer tests: sharding rules, divisibility fallback,
BCQWeight field shardings, elastic re-mesh on small fake meshes.

Uses 8 fake CPU devices (set before jax init via a session-scoped env
check — these tests run in their own module so the device count is safe
to pin here as long as no other test initialized jax first with 1 dev;
to stay robust we spawn a subprocess when the live device count is 1).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> dict:
    """Run code under 8 fake devices in a clean interpreter; returns JSON."""
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(code))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=570,
                       env={**os.environ, "PYTHONPATH": SRC})
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_spec_divisibility_fallback():
    out = run_sub("""
    import jax, json
    from repro.parallel import sharding as shd
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = shd.make_rules()
    specs = {
        "divisible": str(shd.spec_for((16, 64), ("heads", "embed"), mesh, rules)),
        "indivisible": str(shd.spec_for((6, 64), ("heads", "embed"), mesh, rules)),
        "conflict": str(shd.spec_for((8, 8, 64), ("experts", "mlp", "embed"),
                                     mesh, rules)),
        "conflict_fallback": str(shd.spec_for((6, 8, 64), ("experts", "mlp", "embed"),
                                              mesh, rules)),
    }
    print(json.dumps(specs))
    """)
    assert out["divisible"] == "PartitionSpec('model',)"
    assert out["indivisible"] == "PartitionSpec()"          # 6 % 4 -> replicate
    assert out["conflict"] == "PartitionSpec('model',)"     # experts claims it
    assert out["conflict_fallback"] == "PartitionSpec(None, 'model')"  # EP->TP


def test_bcq_weight_shardings_and_lowering():
    out = run_sub("""
    import jax, json
    import jax.numpy as jnp
    from repro.parallel import sharding as shd
    from repro.quant.ptq import abstract_quantized_params
    from repro.models.module import ParamDesc, abstract_params, logical_axes
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = shd.make_rules()
    desc = {"q": ParamDesc((64, 32), jnp.bfloat16, ("heads", "embed"))}
    ap = abstract_params(desc)
    axes = logical_axes(desc)
    qp = abstract_quantized_params(ap, axes, bits=4, group_size=32)
    sh = shd.build_shardings(mesh, qp, axes, rules)
    w = qp["q"]; s = sh["q"]
    out = {"packed": str(s.packed.spec), "alpha": str(s.alpha.spec),
           "z": str(s.z.spec), "packed_shape": list(w.packed.shape)}
    # prove it lowers: y = x @ dequant(w).T under the mesh
    from repro.core.lut_gemm import bcq_apply
    x = jax.ShapeDtypeStruct((8, 32), jnp.bfloat16)
    with mesh:
        c = jax.jit(lambda xx, ww: bcq_apply(xx, ww, "bcq_xla"),
                    in_shardings=(None, s)).lower(x, qp["q"]).compile()
    out["lowered"] = True
    print(json.dumps(out))
    """)
    assert "model" in out["packed"]
    assert out["lowered"]


def test_elastic_remesh_checkpoint_roundtrip(tmp_path):
    """Save on a 2x4 mesh, restore onto 4x2 and 1x8 — topology-agnostic."""
    out = run_sub(f"""
    import jax, json, numpy as np
    import jax.numpy as jnp
    from repro.parallel import sharding as shd
    from repro.train import checkpoint as ckpt
    from repro.launch.mesh import make_mesh_for
    from repro.launch.mesh import make_mesh
    mesh1 = make_mesh((2, 4), ("data", "model"))
    rules = shd.make_rules()
    tree = {{"w": jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)}}
    axes = {{"w": ("heads", "embed")}}
    sh1 = shd.build_shardings(mesh1, tree, axes, rules)
    tree = jax.tree_util.tree_map(jax.device_put, tree, sh1)
    ckpt.save(r"{tmp_path}", 3, tree)
    ok = []
    for shape in ((4, 2), (1, 8), (8, 1)):
        mesh2 = make_mesh(shape, ("data", "model"))
        sh2 = shd.build_shardings(mesh2, tree, axes, rules)
        out, step, _ = ckpt.restore(r"{tmp_path}", 3, shardings=sh2)
        ok.append(bool(np.array_equal(np.asarray(out["w"]),
                                      np.arange(64*32).reshape(64, 32))))
    print(json.dumps({{"ok": ok}}))
    """)
    assert out["ok"] == [True, True, True]


def test_distributed_train_step_runs():
    """End-to-end: 2x4 mesh, real (tiny) model, two sharded train steps
    EXECUTE (not just compile) and losses are finite."""
    out = run_sub("""
    import jax, json
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.models import Model
    from repro.optim import adamw
    from repro.parallel import sharding as shd
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = shd.make_rules(fsdp=True, act_shard=True)
    shd.set_activation_rules(mesh, rules)
    cfg = get_reduced("phi4_mini_3_8b").replace(
        d_model=64, n_heads=8, n_kv_heads=4, head_dim=8, d_ff=128,
        vocab_size=512, n_layers=2, scan_layers=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    axes = model.axes()
    p_sh = shd.build_shardings(mesh, params, axes, rules)
    params = jax.tree_util.tree_map(jax.device_put, params, p_sh)
    opt = adamw.init_state(params)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1)
    pipe = SyntheticLM(vocab_size=512, seq_len=32, global_batch=8, seed=0)

    @jax.jit
    def step(params, opt, batch):
        loss, g = jax.value_and_grad(model.loss_fn)(params, batch)
        p2, o2, m = adamw.apply_updates(params, g, opt, ocfg)
        return p2, o2, loss

    losses = []
    with mesh:
        for i in range(2):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
            params, opt, loss = step(params, opt, batch)
            losses.append(float(loss))
    print(json.dumps({"losses": losses}))
    """)
    assert all(np.isfinite(l) for l in out["losses"])
