"""Unified repro.quant API: spec round-trip, format/backend registries
with capability negotiation + fallback, mixed precision planning, and
quantized-checkpoint save -> load -> serve equivalence."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.bcq import BCQWeight, dequantize, from_uniform
from repro.models import Model
from repro.quant import (QuantSpec, QuantManifest, available_backends,
                         available_formats, execute_linear, fallback_chain,
                         get_format, kernel_for, load_quantized, plan_bits,
                         quantize_model, resolve_backend, save_quantized)
from repro.quant.ptq import collect_linears
from repro.quant.ptq import quantize_model as ptq_quantize_model
from repro.serve import Request, ServeEngine

RNG = jax.random.PRNGKey(0)


def _f32(params):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params)


def _model(arch="opt_6_7b", **over):
    cfg = get_reduced(arch).replace(remat=False, dtype="float32", **over)
    m = Model(cfg)
    return m, _f32(m.init(RNG))


def _w(out=16, n=64, seed=0):
    return jnp.array(np.random.default_rng(seed).normal(size=(out, n)),
                     jnp.float32)


# ---------------------------------------------------------------------------
# QuantSpec
# ---------------------------------------------------------------------------


class TestSpec:
    def test_json_roundtrip(self):
        s = QuantSpec(format="bcq", bits=2.4, group_size=64, iters=3,
                      backend="lut_pallas", candidates=(2, 3, 4),
                      overrides={"stack/scan/0/mixer/q": 4})
        s2 = QuantSpec.from_json(s.to_json())
        assert s2 == s
        d = json.loads(s.to_json())
        assert d["overrides"] == {"stack/scan/0/mixer/q": 4}

    def test_aliases_and_fractional(self):
        s = QuantSpec(format="uniform", bits=2.4)
        assert s.format == "rtn"
        assert s.is_fractional and s.is_mixed
        assert s.candidate_bits == (2, 3, 4)
        assert not QuantSpec(bits=3).is_mixed

    def test_ternary_bits_default_and_conflict(self):
        from repro.core.plane import TERNARY_BITS
        # ternary carries log2(3) bits/weight; 1.58 and the historical
        # "2" (plane count) both canonicalize onto the sentinel
        assert QuantSpec(format="ternary").bits == TERNARY_BITS
        assert QuantSpec(format="ternary", bits=2).bits == TERNARY_BITS
        assert QuantSpec(format="ternary", bits=1.58).bits == TERNARY_BITS
        with pytest.raises(ValueError, match="log2"):
            QuantSpec(format="ternary", bits=4)

    def test_sub2_bits_candidates_include_ternary(self):
        from repro.core.plane import TERNARY_BITS
        s = QuantSpec(bits=1.58)
        assert s.bits == TERNARY_BITS and s.is_fractional
        assert s.candidate_bits == (TERNARY_BITS, 2, 3)
        # integer-candidate fractional plans are unchanged
        assert QuantSpec(bits=2.4).candidate_bits == (2, 3, 4)

    def test_file_roundtrip(self, tmp_path):
        p = str(tmp_path / "spec.json")
        s = QuantSpec(bits=3, group_size=32)
        s.save(p)
        assert QuantSpec.load(p) == s

    def test_validation(self):
        with pytest.raises(ValueError):
            QuantSpec(bits=-1)
        with pytest.raises(ValueError):
            QuantSpec(group_size=0)


# ---------------------------------------------------------------------------
# format registry
# ---------------------------------------------------------------------------


class TestFormats:
    def test_registry_contents(self):
        assert {"bcq", "rtn", "ternary"} <= set(available_formats())
        with pytest.raises(KeyError):
            get_format("no_such_format")

    def test_rtn_routes_to_from_uniform(self):
        w = _w()
        via_registry = get_format("rtn").quantize(w, bits=3, group_size=16,
                                                  iters=0)
        direct = from_uniform(w, bits=3, group_size=16)
        assert np.array_equal(via_registry.packed, direct.packed)
        assert np.allclose(via_registry.alpha, direct.alpha)

    def test_ternary_correctness_vs_reference(self):
        """Dequantized ternary must match an independent numpy run of the
        octav-style alternating fixed point exactly (the sign+mask plane
        encoding adds no error)."""
        w = _w(out=8, n=32, seed=1)
        g = 8
        wq = get_format("ternary").quantize(w, bits=2, group_size=g)
        assert wq.kind == "ternary"
        assert wq.bits == 2                      # sign + mask planes
        assert wq.z is None and wq.alpha.shape[0] == 1
        got = np.asarray(dequantize(wq))

        wg = np.asarray(w).reshape(8, 32 // g, g)
        absw = np.abs(wg)
        a = absw.mean(-1)
        for _ in range(12):
            mask = absw > a[..., None] / 2.0
            a = (absw * mask).sum(-1) / np.maximum(mask.sum(-1), 1)
        mask = absw > a[..., None] / 2.0
        ref = (np.sign(wg) * mask * a[..., None]).reshape(8, 32)
        assert np.allclose(got, ref, atol=1e-6)

    def test_ternary_clipping_beats_twn_threshold(self):
        """The alternating fixed point must not reconstruct worse than
        the TWN 0.7*mean|w| heuristic it replaced (MSE, per matrix)."""
        w = np.asarray(_w(out=16, n=64, seed=7))
        g = 16
        wq = get_format("ternary").quantize(jnp.asarray(w), bits=2,
                                            group_size=g)
        got = np.asarray(dequantize(wq))
        mse_opt = float(((w - got) ** 2).mean())

        wg = w.reshape(16, 64 // g, g)
        delta = 0.7 * np.abs(wg).mean(-1, keepdims=True)
        mask = np.abs(wg) > delta
        a = (np.abs(wg) * mask).sum(-1) / np.maximum(mask.sum(-1), 1)
        twn = (np.sign(wg) * mask * a[..., None]).reshape(16, 64)
        mse_twn = float(((w - twn) ** 2).mean())
        assert mse_opt <= mse_twn + 1e-9

    def test_ternary_three_levels_per_group(self):
        w = _w(out=4, n=32, seed=2)
        wq = get_format("ternary").quantize(w, bits=2, group_size=16)
        d = np.asarray(dequantize(wq)).reshape(4, 2, 16)
        for r in range(4):
            for g in range(2):
                assert len(np.unique(np.round(d[r, g], 5))) <= 3

    def test_ternary_exact_on_ternary_input(self):
        a = 0.5
        t = np.random.default_rng(3).integers(-1, 2, size=(4, 16))
        wq = get_format("ternary").quantize(jnp.array(a * t, jnp.float32),
                                            bits=2, group_size=16)
        assert np.allclose(np.asarray(dequantize(wq)), a * t, atol=1e-6)


# ---------------------------------------------------------------------------
# backend registry: capability negotiation + fallback chain
# ---------------------------------------------------------------------------


class TestBackends:
    def _wq(self, **kw):
        return get_format("bcq").quantize(_w(), bits=2, group_size=16,
                                          iters=1, **kw)

    def test_chains(self):
        assert fallback_chain("mxu_pallas") == ("mxu_pallas", "bcq_xla",
                                                "dense")
        assert fallback_chain("lut_pallas")[-1] == "dense"
        assert fallback_chain("ternary_pallas") == ("ternary_pallas",
                                                    "bcq_xla", "dense")
        assert fallback_chain(None) == fallback_chain("auto")
        assert fallback_chain("auto")[0] == "ternary_pallas"
        with pytest.raises(KeyError):
            fallback_chain("no_such_backend")

    def test_auto_resolves_native_off_tpu(self):
        # on CPU auto must not pick an interpret-mode Pallas kernel
        assert resolve_backend("auto", self._wq()) == "bcq_xla"
        assert kernel_for("auto") is None

    def test_explicit_pallas_honoured(self):
        # explicit preference runs (interpret mode is a legitimate ask)
        assert resolve_backend("lut_pallas", self._wq()) == "lut_pallas"
        assert kernel_for("lut_pallas") == "lut_gemm"
        assert kernel_for("mxu_pallas") == "bcq_matmul"
        assert kernel_for("ternary_pallas") == "ternary_matmul"

    def test_kind_aware_negotiation(self):
        wt = get_format("ternary").quantize(_w(), bits=2, group_size=16)
        wb = self._wq()
        # the dedicated kernel only claims ternary bundles...
        assert resolve_backend("ternary_pallas", wt) == "ternary_pallas"
        assert resolve_backend("ternary_pallas", wb) == "bcq_xla"
        # ...and the generic plane kernels never claim ternary ones
        assert resolve_backend("lut_pallas", wt) == "bcq_xla"
        assert resolve_backend("mxu_pallas", wt) == "bcq_xla"
        assert resolve_backend("bcq_xla_planes", wt) == "bcq_xla"

    def test_capability_fallback_on_stacked_weight(self):
        wq = self._wq()
        stacked = BCQWeight(packed=wq.packed[None], alpha=wq.alpha[None],
                            z=wq.z[None], group_size=wq.group_size,
                            in_features=wq.in_features,
                            out_features=wq.out_features)
        # Pallas wrappers take 2-D logical weights only -> negotiation
        # walks the chain down to bcq_xla instead of crashing
        assert resolve_backend("mxu_pallas", stacked) == "bcq_xla"
        assert resolve_backend("lut_pallas", stacked) == "bcq_xla"

    def test_kernel_supports_probe(self):
        from repro.tune.dispatch import kernel_supports
        assert kernel_supports("lut_gemm", m=16, n=64, group_size=16)
        assert not kernel_supports("lut_gemm", m=16, n=64, group_size=12)
        assert not kernel_supports("bcq_matmul", m=16, n=64, group_size=16,
                                   bits=9)
        assert not kernel_supports("no_such_kernel", m=1, n=1, group_size=8)

    def test_dense_always_available(self):
        assert "dense" in available_backends()
        assert "bcq_xla" in available_backends()

    def test_execute_linear_backends_agree(self):
        wq = self._wq()
        x = jnp.array(np.random.default_rng(4).normal(size=(3, 64)),
                      jnp.float32)
        ref = x @ dequantize(wq).T
        for backend in (None, "dense", "bcq_xla", "bcq_xla_planes"):
            y = execute_linear(x, wq, backend=backend)
            assert np.allclose(y, ref, atol=0.1), backend

    def test_execute_linear_ternary_backends_agree(self):
        wt = get_format("ternary").quantize(_w(), bits=2, group_size=16)
        x = jnp.array(np.random.default_rng(5).normal(size=(3, 64)),
                      jnp.float32)
        ref = x @ dequantize(wt).T
        for backend in (None, "dense", "bcq_xla", "ternary_pallas"):
            y = execute_linear(x, wt, backend=backend)
            assert np.allclose(y, ref, atol=0.1), backend

    def test_execute_linear_dense_leaf(self):
        w = _w()
        x = jnp.ones((2, 64), jnp.float32)
        y = execute_linear(x, w, backend=None)
        assert np.allclose(y, x @ w.T, atol=1e-4)


# ---------------------------------------------------------------------------
# quantize_model: spec-driven PTQ + manifest + mixed precision
# ---------------------------------------------------------------------------


class TestQuantizeModel:
    def test_uniform_spec_matches_internal_ptq(self):
        m, params = _model()
        spec = QuantSpec(bits=3, group_size=32, iters=2)
        qp, manifest = quantize_model(params, spec, m.axes())
        qp_ptq = ptq_quantize_model(params, m.axes(), bits=3,
                                    method="bcq", group_size=32, iters=2)
        leaves = jax.tree_util.tree_leaves(qp)
        leaves_l = jax.tree_util.tree_leaves(qp_ptq)
        assert len(leaves) == len(leaves_l)
        for a, b in zip(leaves, leaves_l):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert manifest.n_layers > 0
        assert manifest.avg_plane_bits == 3.0
        assert manifest.quant_bytes < manifest.dense_bytes

    def test_manifest_json(self, tmp_path):
        m, params = _model()
        qp, manifest = quantize_model(params, QuantSpec(bits=2, iters=1,
                                                        group_size=32),
                                      m.axes())
        p = str(tmp_path / "manifest.json")
        manifest.save(p)
        d = json.load(open(p))
        m2 = QuantManifest.from_dict(d)
        assert m2.avg_plane_bits == manifest.avg_plane_bits
        assert {l["path"] for l in m2.layers} == \
            set(collect_linears(params, m.axes()))
        assert all(l["plane_bits"] == 2 for l in m2.layers)

    def test_fractional_bits_drive_mixed_precision(self):
        m, params = _model()
        spec = QuantSpec(bits=2.4, group_size=32, iters=1)
        qp, manifest = quantize_model(params, spec, m.axes())
        widths = {l["plane_bits"] for l in manifest.layers}
        assert len(widths) > 1, "2.4-bit plan should mix bit-widths"
        assert min(widths) >= 2
        assert 2.0 < manifest.avg_plane_bits <= 2.4 + 1e-9
        # model still runs end-to-end on the mixed tree
        mq = Model(m.cfg.replace(quant=spec))
        logits = mq.forward(qp, {"tokens": jnp.ones((1, 8), jnp.int32)})
        assert bool(jnp.isfinite(logits).all())

    def test_overrides_pin_layers(self):
        m, params = _model()
        lin = collect_linears(params, m.axes())
        pinned = sorted(lin)[0]
        spec = QuantSpec(bits=2, iters=1, group_size=32,
                         overrides={pinned: 4})
        qp, manifest = quantize_model(params, spec, m.axes())
        by_path = {l["path"]: l["plane_bits"] for l in manifest.layers}
        assert by_path[pinned] == 4
        assert all(b == 2 for p, b in by_path.items() if p != pinned)

    def test_plan_bits_ternary_fixed(self):
        m, params = _model()
        lin = collect_linears(params, m.axes())
        plan = plan_bits(lin, QuantSpec(format="ternary"))
        assert set(plan.values()) == {2}

    def test_unknown_override_path_rejected(self):
        m, params = _model()
        lin = collect_linears(params, m.axes())
        with pytest.raises(ValueError, match="not quantizable"):
            plan_bits(lin, QuantSpec(bits=3, overrides={"no/such/layer": 2}))

    def test_overrides_rejected_for_fixed_plane_format(self):
        m, params = _model()
        lin = collect_linears(params, m.axes())
        pinned = sorted(lin)[0]
        with pytest.raises(ValueError, match="fixed"):
            plan_bits(lin, QuantSpec(format="ternary",
                                     overrides={pinned: 3}))

    def test_zero_bits_rejected_with_clear_error(self):
        m, params = _model()
        with pytest.raises(ValueError, match="bits"):
            quantize_model(params, QuantSpec(bits=0), m.axes())

    def test_ternary_model_end_to_end(self):
        from repro.core.plane import TERNARY_BITS
        m, params = _model()
        spec = QuantSpec(format="ternary", group_size=32)
        qp, manifest = quantize_model(params, spec, m.axes())
        assert manifest.avg_plane_bits == 2.0        # sign + mask stored
        for layer in manifest.layers:
            assert layer["format"] == "ternary"
            assert layer["effective_bits"] == TERNARY_BITS
        mq = Model(m.cfg.replace(quant=spec))
        logits = mq.forward(qp, {"tokens": jnp.ones((1, 8), jnp.int32)})
        assert bool(jnp.isfinite(logits).all())

    def test_ternary_manifest_bytes_beat_generic_2bit(self):
        """Ternary must report STRICTLY fewer packed bytes than generic
        2-bit BCQ on the same model (1 scale row, no offset) — the
        manifest no longer overstates ternary model size."""
        m, params = _model()
        _, man_t = quantize_model(params, QuantSpec(format="ternary",
                                                    group_size=32), m.axes())
        _, man_b = quantize_model(params, QuantSpec(bits=2, iters=0,
                                                    group_size=32), m.axes())
        assert man_t.quant_bytes < man_b.quant_bytes
        assert man_t.avg_effective_bits < man_b.avg_effective_bits


# ---------------------------------------------------------------------------
# quantized checkpoints
# ---------------------------------------------------------------------------


class TestQuantCheckpoint:
    def test_save_load_roundtrip(self, tmp_path):
        m, params = _model()
        spec = QuantSpec(bits=3, group_size=32, iters=1, backend="bcq_xla")
        qp, manifest = quantize_model(params, spec, m.axes())
        d = str(tmp_path / "qckpt")
        save_quantized(d, qp, spec, manifest, arch=m.cfg.name)
        qp2, spec2, manifest2, extra = load_quantized(d)
        assert spec2 == spec
        assert manifest2.avg_plane_bits == manifest.avg_plane_bits
        assert extra["arch"] == m.cfg.name

        flat1 = jax.tree_util.tree_leaves_with_path(qp)
        flat2 = jax.tree_util.tree_leaves_with_path(qp2)
        assert len(flat1) == len(flat2)
        for (p1, l1), (p2, l2) in zip(flat1, flat2):
            assert p1 == p2
            assert l1.dtype == l2.dtype, p1
            assert np.array_equal(np.asarray(l1), np.asarray(l2)), p1

    def test_load_rejects_unquantized_ckpt(self, tmp_path):
        from repro.train import checkpoint as ckpt
        d = str(tmp_path / "plain")
        ckpt.save(d, 0, {"w": np.ones((2, 2))})
        with pytest.raises(ValueError, match="not a quantized checkpoint"):
            load_quantized(d)

    def test_checkpoint_serves_identically_to_quantize_at_launch(
            self, tmp_path):
        """save -> load -> serve must be token-for-token identical to
        quantize-at-launch (greedy)."""
        m, params = _model()
        spec = QuantSpec(bits=3, group_size=32, iters=1)
        qp, _ = quantize_model(params, spec, m.axes())
        d = str(tmp_path / "qckpt")
        save_quantized(d, qp, spec, arch=m.cfg.name)
        qp2, spec2, _, _ = load_quantized(d)

        cfg = m.cfg.replace(quant=spec)
        rng = np.random.default_rng(0)
        def run(ps):
            eng = ServeEngine(Model(cfg), ps, slots=2, cache_len=48,
                              prefill_buckets=(16,))
            reqs = [Request(uid=i,
                            prompt=rng.integers(0, cfg.vocab_size, (int(l),)),
                            max_new_tokens=5)
                    for i, l in enumerate([7, 12])]
            return {r.uid: r.out_tokens for r in eng.run(reqs)}

        rng = np.random.default_rng(0)
        out_launch = run(qp)
        rng = np.random.default_rng(0)
        out_loaded = run(qp2)
        assert out_launch == out_loaded
        assert all(len(t) == 5 for t in out_launch.values())


# ---------------------------------------------------------------------------
# config integration (the removed gemm_backend/quant_bits shims must stay
# removed — QuantSpec is the single source of truth)
# ---------------------------------------------------------------------------


class TestConfigIntegration:
    def test_linear_apply_backend_string(self):
        from repro.core import linear_apply
        wq = get_format("bcq").quantize(_w(), bits=2, group_size=16, iters=1)
        x = jnp.ones((2, 64), jnp.float32)
        y = linear_apply(wq, x, backend="bcq_xla")
        assert np.allclose(y, x @ dequantize(wq).T, atol=0.1)

    def test_config_backend_preference_via_spec_only(self):
        import dataclasses
        cfg = get_reduced("opt_6_7b")
        field_names = {f.name for f in dataclasses.fields(type(cfg))}
        assert "gemm_backend" not in field_names     # shim removed
        assert "quant_bits" not in field_names       # shim removed
        assert not hasattr(QuantSpec, "from_legacy")
        assert cfg.quant_spec() is None
        spec = QuantSpec(bits=2, backend="lut_pallas")
        assert cfg.replace(quant=spec).backend_preference == "lut_pallas"
        assert cfg.replace(quant=spec).quant_spec() is spec
