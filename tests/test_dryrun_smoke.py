"""Smoke tests for the dry-run path itself on a small fake-device mesh.

Each runs `build_cell` + lower + compile in a subprocess (fresh jax, 16
fake devices standing in for the 512-device production run) with REDUCED
configs patched in — guards the launch/dryrun plumbing against
regressions without the cost of full-size lowering.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_cell_sub(arch: str, shape: str, extra: str = "") -> dict:
    prog = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax
    import repro.launch.mesh as mesh_mod
    # shrink the production mesh to the test device count
    mesh_mod.make_production_mesh = lambda multi_pod=False: mesh_mod.make_mesh(
        (2, 2, 4) if multi_pod else (4, 4),
        ("pod", "data", "model") if multi_pod else ("data", "model"))
    import repro.launch.dryrun as dr
    import repro.configs.base as base
    from repro.configs import get_reduced
    real_get = dr.__dict__  # noqa
    import repro.configs as cfgs
    orig = cfgs.get_config
    def reduced_cfg(a):
        c = get_reduced(a)
        return c.replace(scan_layers=True, max_seq_len=4096)
    import repro.launch.dryrun
    repro.launch.dryrun.__dict__["build_cell"].__globals__["get_config"] = reduced_cfg
    # shrink shapes
    from repro.configs.base import SHAPES, ShapeCfg
    SHAPES["train_4k"] = ShapeCfg("train_4k", 64, 8, "train")
    SHAPES["prefill_32k"] = ShapeCfg("prefill_32k", 128, 4, "prefill")
    SHAPES["decode_32k"] = ShapeCfg("decode_32k", 128, 8, "decode")
    SHAPES["long_500k"] = ShapeCfg("long_500k", 256, 4, "decode")
    res = dr.run_cell("{arch}", "{shape}", roofline=False {extra})
    print("RESULT" + json.dumps({{"ok": bool(res["compile_ok"]),
                                  "mem": res["device_mem_gb"]}}))
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=560,
                       env={**os.environ, "PYTHONPATH": SRC})
    assert r.returncode == 0, r.stderr[-2500:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


@pytest.mark.parametrize("arch,shape", [
    ("stablelm_1_6b", "train_4k"),
    ("mixtral_8x7b", "decode_32k"),
    ("mamba2_2_7b", "long_500k"),
    ("whisper_medium", "prefill_32k"),
])
def test_dryrun_cell_compiles(arch, shape):
    out = run_cell_sub(arch, shape)
    assert out["ok"]


def test_dryrun_multi_pod():
    out = run_cell_sub("stablelm_1_6b", "train_4k", extra=", multi_pod=True")
    assert out["ok"]


def test_dryrun_skips_long_context_for_full_attention():
    with pytest.raises(AssertionError) as e:
        run_cell_sub("phi4_mini_3_8b", "long_500k")
    assert "SKIP" in str(e.value)
