"""Hypothesis property tests for the system's core invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -e .[dev]) — the suite "
           "must collect without it")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import bcq, lut
from repro.core.lut_gemm import bcq_xla_matmul, bcq_xla_matmul_fused

_SET = dict(max_examples=25, deadline=None,
            suppress_health_check=[HealthCheck.too_slow])


@st.composite
def weight_matrices(draw):
    m = draw(st.integers(8, 48))
    n = draw(st.integers(16, 160))
    seed = draw(st.integers(0, 2**16))
    scale = draw(st.floats(0.01, 10.0))
    rng = np.random.default_rng(seed)
    return jnp.array((rng.normal(size=(m, n)) * scale).astype(np.float32))


@given(weight_matrices(), st.integers(1, 4))
@settings(**_SET)
def test_pack_unpack_roundtrip(W, bits):
    wq = bcq.quantize(W, bits=bits, group_size=32, iters=1)
    planes = bcq.unpack_planes(wq.packed)
    repacked = bcq.pack_planes(planes)
    np.testing.assert_array_equal(np.asarray(repacked), np.asarray(wq.packed))


@given(weight_matrices(), st.integers(2, 4))
@settings(**_SET)
def test_from_uniform_error_bound(W, bits):
    """RTN-as-BCQ reconstruction error is <= half a quantization step."""
    wq = bcq.from_uniform(W, bits=bits, group_size=32)
    dense = bcq.dequantize(wq)
    Wg = np.asarray(W)
    # per-group step bound
    m, n = Wg.shape
    npad = -(-n // 32) * 32
    Wp = np.pad(Wg, ((0, 0), (0, npad - n)), mode="edge").reshape(m, -1, 32)
    step = (Wp.max(-1) - Wp.min(-1)) / (2**bits - 1)
    bound = np.repeat(step, 32, axis=-1).reshape(m, npad)[:, :n]
    err = np.abs(np.asarray(dense) - Wg)
    assert (err <= bound / 2 + 1e-5).all()


@given(weight_matrices())
@settings(**_SET)
def test_quantize_error_monotone_in_bits(W):
    errs = [float(jnp.mean((bcq.dequantize(
        bcq.quantize(W, bits=b, group_size=32, iters=2)) - W) ** 2))
        for b in (1, 2, 3)]
    assert errs[0] >= errs[1] - 1e-7 and errs[1] >= errs[2] - 1e-7


@given(weight_matrices(), st.integers(0, 2**16))
@settings(**_SET)
def test_backends_agree(W, seed):
    """bcq_xla (per-plane) == fused dequant matmul for any quantized weight.

    Compared at f32 compute dtype — the algebraic-equivalence property;
    the serve path's bf16 compute dtype trades ~bf16-eps accuracy for
    halved weight traffic (covered by the kernel tests' tolerances).
    """
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=(3, W.shape[1])).astype(np.float32))
    wq = bcq.from_uniform(W, bits=3, group_size=32)
    a = bcq_xla_matmul(x, wq)
    b = bcq_xla_matmul_fused(x, wq, compute_dtype=jnp.float32)
    scale = float(jnp.abs(b).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(a) / scale, np.asarray(b) / scale,
                               atol=3e-6)


@given(st.integers(1, 6))
@settings(max_examples=6, deadline=None)
def test_lut_symmetry_any_mu(mu):
    rng = np.random.default_rng(mu)
    x = jnp.array(rng.normal(size=(1, mu * 4)).astype(np.float32))
    t = lut.build_lut(x, mu=mu)
    np.testing.assert_allclose(np.asarray(t), -np.asarray(t[..., ::-1]),
                               atol=1e-6)


@given(weight_matrices())
@settings(**_SET)
def test_dequantize_shape_and_finite(W):
    wq = bcq.quantize(W, bits=2, group_size=32, iters=1)
    d = bcq.dequantize(wq)
    assert d.shape == W.shape
    assert bool(jnp.isfinite(d).all())
