"""Substrate tests: data pipeline, optimizer, checkpointing, trainer
fault-tolerance (failure recovery, straggler detection), serving engine.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLM, MemmapTokens
from repro.models import Model
from repro.optim import adamw
from repro.serve.engine import ServeEngine, Request
from repro.train import checkpoint as ckpt
from repro.train.trainer import Trainer, TrainConfig

CFG = get_reduced("opt_6_7b").replace(remat=False)


class TestDataPipeline:
    def test_deterministic_replay(self):
        p1 = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        p2 = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        np.testing.assert_array_equal(p1.batch_at(7)["tokens"],
                                      p2.batch_at(7)["tokens"])

    def test_shards_partition(self):
        full = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        s0 = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=3,
                         data_shard=0, data_shards=2)
        s1 = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=3,
                         data_shard=1, data_shards=2)
        assert s0.batch_at(0)["tokens"].shape == (2, 16)
        assert not np.array_equal(s0.batch_at(0)["tokens"],
                                  s1.batch_at(0)["tokens"])

    def test_has_learnable_structure(self):
        p = SyntheticLM(vocab_size=64, seq_len=512, global_batch=2, seed=0)
        toks = p.batch_at(0)["tokens"]
        # bigram structure: successor entropy < unconditional entropy
        succ = {}
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                succ.setdefault(int(a), []).append(int(b))
        top_frac = np.mean([
            max(np.bincount(v).max(), 1) / len(v)
            for v in succ.values() if len(v) >= 5])
        assert top_frac > 0.2, top_frac   # way above 1/64 uniform

    def test_memmap_source(self, tmp_path):
        arr = np.arange(1024, dtype=np.int32)
        f = tmp_path / "toks.bin"
        arr.tofile(f)
        p = MemmapTokens(path=str(f), seq_len=32, global_batch=2)
        b = p.batch_at(0)["tokens"]
        assert b.shape == (2, 32)
        np.testing.assert_array_equal(b[0], np.arange(32))


class TestAdamW:
    def test_descends(self):
        w = {"w": jnp.array([2.0, -3.0])}
        opt = adamw.init_state(w)
        cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                                weight_decay=0.0)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(50):
            g = jax.grad(loss)(w)
            w, opt, _ = adamw.apply_updates(w, g, opt, cfg)
        assert float(loss(w)) < 0.1

    def test_clipping(self):
        w = {"w": jnp.zeros(3)}
        opt = adamw.init_state(w)
        cfg = adamw.AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1)
        g = {"w": jnp.full(3, 1e6)}
        _, _, m = adamw.apply_updates(w, g, opt, cfg)
        assert float(m["grad_norm"]) > 1e5   # reported pre-clip

    def test_compression_roundtrip_with_error_feedback(self):
        g = {"a": jnp.array(np.random.default_rng(0).normal(size=(64,)) * 1e-3,
                            jnp.float32)}
        q, s, resid = adamw.compress_grads(g)
        assert q["a"].dtype == jnp.int8
        deq = adamw.decompress_grads(q, s)
        err1 = float(jnp.abs(deq["a"] - g["a"]).max())
        # residual carries the error: feeding it back reduces bias
        q2, s2, _ = adamw.compress_grads(g, resid)
        deq2 = adamw.decompress_grads(q2, s2)
        two_step = (deq["a"] + deq2["a"]) / 2
        err2 = float(jnp.abs(two_step - g["a"]).max())
        assert err2 <= err1 * 1.01


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": [jnp.ones(4), {"c": jnp.zeros((2, 2), jnp.bfloat16)}],
                "step": jnp.int32(7)}
        ckpt.save(str(tmp_path), 7, tree)
        out, step, _ = ckpt.restore(str(tmp_path))
        assert step == 7
        np.testing.assert_array_equal(out["a"], tree["a"])
        assert out["b"][1]["c"].dtype == np.dtype("bfloat16") or \
            out["b"][1]["c"].dtype == jnp.bfloat16

    def test_atomic_commit_ignores_partial(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"x": jnp.ones(2)})
        # a crashed write leaves a .tmp dir — must be invisible
        os.makedirs(tmp_path / "step_00000009.tmp")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_async_and_gc(self, tmp_path):
        ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ac.save_async(s, {"x": jnp.full(3, s)})
        ac.wait()
        assert ckpt.list_steps(str(tmp_path)) == [3, 4]
        out, s, _ = ckpt.restore(str(tmp_path))
        assert s == 4 and float(out["x"][0]) == 4


class TestTrainerFaultTolerance:
    def _trainer(self, tmp_path, steps=8, **kw):
        model = Model(CFG)
        tc = TrainConfig(steps=steps, ckpt_every=2, ckpt_dir=str(tmp_path),
                         log_every=100, **kw)
        oc = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
        return Trainer(model, oc, tc)

    def _pipe(self):
        return SyntheticLM(vocab_size=CFG.vocab_size, seq_len=32,
                           global_batch=4, seed=1)

    def test_loss_decreases(self, tmp_path):
        tr = self._trainer(tmp_path, steps=20)
        _, hist = tr.run(self._pipe())
        first = np.mean([h["loss"] for h in hist[:4]])
        last = np.mean([h["loss"] for h in hist[-4:]])
        assert last < first

    def test_failure_recovery_resumes_from_checkpoint(self, tmp_path):
        tr = self._trainer(tmp_path, steps=8)
        state, hist = tr.run(self._pipe(), inject_failure_at=5)
        # failed at 5, resumed from ckpt at 4, finished all 8 steps
        assert int(state["step"]) == 8
        assert len(hist) >= 8

    def test_restart_after_kill_resumes(self, tmp_path):
        tr = self._trainer(tmp_path, steps=4)
        tr.run(self._pipe())
        # new trainer process picks up where the old one stopped
        tr2 = self._trainer(tmp_path, steps=6)
        state, hist = tr2.run(self._pipe())
        assert int(state["step"]) == 6
        assert len(hist) == 2          # only 2 fresh steps

    def test_deterministic_resume_matches_uninterrupted(self, tmp_path):
        pA = self._pipe()
        trA = self._trainer(tmp_path / "a", steps=6)
        stateA, _ = trA.run(pA)
        trB1 = self._trainer(tmp_path / "b", steps=6)
        stateB, _ = trB1.run(self._pipe(), inject_failure_at=4)
        la = jax.tree_util.tree_leaves(stateA["params"])
        lb = jax.tree_util.tree_leaves(stateB["params"])
        for a, b in zip(la, lb):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-5)

    def test_straggler_detection(self, tmp_path):
        import time as _t
        tr = self._trainer(tmp_path, steps=10, straggler_factor=2.0)
        pipe = self._pipe()
        orig = pipe.batch_at

        def slow_batch(step):
            if step == 7:
                _t.sleep(4.0)          # simulated slow host
            return orig(step)
        pipe.batch_at = slow_batch
        tr.run(pipe)
        assert 7 in tr.stragglers or 8 in tr.stragglers


class TestServeEngine:
    def test_batched_generation(self):
        model = Model(CFG.replace(max_seq_len=256))
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, slots=4, cache_len=96,
                          prefill_buckets=(16, 32))
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, CFG.vocab_size, size=(8 + i,)),
                        max_new_tokens=6) for i in range(6)]
        done = eng.run(reqs, max_ticks=200)
        assert len(done) == 6
        for r in done:
            assert len(r.out_tokens) == 6
            assert all(0 <= t < CFG.vocab_size for t in r.out_tokens)

    def test_continuous_batching_overlap(self):
        """More requests than slots: engine must recycle slots."""
        model = Model(CFG.replace(max_seq_len=256))
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, slots=2, cache_len=64,
                          prefill_buckets=(16,))
        rng = np.random.default_rng(1)
        reqs = [Request(uid=i, prompt=rng.integers(0, CFG.vocab_size, (8,)),
                        max_new_tokens=4) for i in range(5)]
        done = eng.run(reqs, max_ticks=200)
        assert len(done) == 5

    def test_greedy_decode_deterministic(self):
        model = Model(CFG.replace(max_seq_len=256))
        params = model.init(jax.random.PRNGKey(0))
        prompt = np.arange(10) % CFG.vocab_size
        outs = []
        for _ in range(2):
            eng = ServeEngine(model, params, slots=1, cache_len=64,
                              prefill_buckets=(16,))
            done = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=5)])
            outs.append(done[0].out_tokens)
        assert outs[0] == outs[1]
