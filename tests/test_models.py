"""Per-architecture smoke tests (deliverable f) + model-level invariants.

Every assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU, asserting output shapes and no NaNs; decode
paths are checked against full-sequence forward (teacher-forcing match).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced, SHAPES
from repro.models import Model
from repro.quant import QuantSpec, quantize_model

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=24, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab_size, (b, s)),
                                 jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jnp.array(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)) * 0.1,
            jnp.bfloat16)
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.array(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)) * 0.1,
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_reduced(arch)
        m = Model(cfg)
        params = m.init(RNG)
        batch = _batch(cfg)
        logits = m.forward(params, batch)
        s_total = batch["tokens"].shape[1] + (
            batch["patch_embeds"].shape[1] if "patch_embeds" in batch else 0)
        assert logits.shape == (2, s_total, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())

    def test_train_step_no_nans(self, arch):
        cfg = get_reduced(arch)
        m = Model(cfg)
        params = m.init(RNG)
        batch = _batch(cfg)
        loss, grads = jax.value_and_grad(m.loss_fn)(params, batch)
        assert bool(jnp.isfinite(loss))
        finite = jax.tree_util.tree_reduce(
            lambda a, g: a and bool(jnp.isfinite(g).all()), grads, True)
        assert finite

    def test_full_config_registered(self, arch):
        cfg = get_config(arch)
        assert cfg.n_layers >= 12 and cfg.vocab_size > 1000
        # layer plan covers all layers
        assert len([cfg.layer_kind(i) for i in range(cfg.n_layers)]) == cfg.n_layers


@pytest.mark.parametrize("arch", ["phi4_mini_3_8b", "minicpm3_4b",
                                  "mamba2_2_7b", "mixtral_8x7b",
                                  "jamba_1_5_large_398b", "whisper_medium",
                                  "deepseek_v2_236b", "pixtral_12b"])
@pytest.mark.parametrize("strict_f32", [False, True])
def test_decode_matches_forward(arch, strict_f32):
    """prefill+decode logits == full-forward logits (teacher forcing).

    strict_f32 runs everything in f32 — decode must match the forward
    path to accumulation noise (structural exactness); the bf16 run
    allows softmax-probability rounding noise (the decode fast path and
    the chunked online-softmax round p at different scales).
    """
    cfg = get_reduced(arch).replace(remat=False, capacity_factor=8.0)
    if strict_f32:
        cfg = cfg.replace(dtype="float32")
    m = Model(cfg)
    params = m.init(RNG)
    if strict_f32:
        params = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32)
            if x.dtype == jnp.bfloat16 else x, params)
    b, s = 2, 24
    batch = _batch(cfg, b, s)
    full = m.forward(params, batch)
    off = cfg.num_patches if cfg.num_patches else 0
    t0 = s - 4
    cache = m.init_cache(b, 40)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :t0]
    logits, cache = m.prefill(params, pre, cache)
    errs = [float(jnp.abs(logits - full[:, off + t0 - 1]).max())]
    for t in range(t0, s - 1):
        logits, cache = m.decode_step(params, batch["tokens"][:, t:t + 1],
                                      cache, off + t)
        errs.append(float(jnp.abs(logits - full[:, off + t]).max()))
    # MoE bf16: the router's top-k can legitimately flip a near-tied
    # expert between the two paths (their attention outputs differ by
    # bf16 rounding), which perturbs logits by O(gate gap), not by
    # rounding noise — the strict_f32 variant is the structural
    # equivalence guard there
    tol = 2e-4 if strict_f32 else (1e-1 if cfg.n_experts else 1e-2)
    assert max(errs) < tol, errs


def test_swa_ring_buffer_decode():
    """Sliding-window cache: decoding past the window stays consistent with
    a full-cache model (same window masking)."""
    cfg = get_reduced("mixtral_8x7b").replace(
        remat=False, capacity_factor=8.0, sliding_window=8, dtype="float32")
    m = Model(cfg)
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        m.init(RNG))
    b, s = 1, 20
    batch = _batch(cfg, b, s)
    full = m.forward(params, batch)          # window masking applies
    # ring cache of exactly window size
    cache = m.init_cache(b, cfg.sliding_window)
    errs = []
    logits, cache = m.prefill(params, {"tokens": batch["tokens"][:, :4]}, cache)
    errs.append(float(jnp.abs(logits - full[:, 3]).max()))
    for t in range(4, s - 1):
        logits, cache = m.decode_step(params, batch["tokens"][:, t:t + 1],
                                      cache, t)
        errs.append(float(jnp.abs(logits - full[:, t]).max()))
    assert max(errs) < 2e-4, errs


def test_scan_matches_unrolled():
    """scan-over-layers executes the same math as the unrolled stack."""
    cfg_u = get_reduced("phi4_mini_3_8b").replace(remat=False, n_layers=4)
    cfg_s = cfg_u.replace(scan_layers=True)
    mu_, ms_ = Model(cfg_u), Model(cfg_s)
    params_u = mu_.init(RNG)
    # f32 everywhere so the comparison is exact math, not bf16 noise
    params_u = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params_u)
    # stack the unrolled per-layer params into the scan layout
    layers = params_u["stack"]["layers"]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)
    params_s = dict(params_u)
    params_s["stack"] = {"scan": [stacked]}
    batch = _batch(cfg_u)
    out_u = mu_.forward(params_u, batch)
    out_s = ms_.forward(params_s, batch)
    np.testing.assert_allclose(np.asarray(out_u, np.float32),
                               np.asarray(out_s, np.float32), atol=1e-5)


def test_jamba_layer_plan():
    cfg = get_config("jamba_1_5_large_398b")
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    assert kinds.count("attn") == 9 and kinds.count("mamba") == 63
    mlps = [cfg.mlp_kind(i) for i in range(cfg.n_layers)]
    assert mlps.count("moe") == 36


def test_deepseek_layer_plan():
    cfg = get_config("deepseek_v2_236b")
    assert cfg.mlp_kind(0) == "dense"
    assert all(cfg.mlp_kind(i) == "moe" for i in range(1, cfg.n_layers))


@pytest.mark.parametrize("arch", ["phi4_mini_3_8b", "mixtral_8x7b"])
def test_quantized_model_close_to_fp(arch):
    """4-bit BCQ model's loss stays near the FP loss (Table IV analogue)."""
    cfg = get_reduced(arch).replace(remat=False, capacity_factor=8.0)
    m = Model(cfg)
    params = m.init(RNG)
    batch = _batch(cfg)
    loss_fp = float(m.loss_fn(params, batch))
    spec = QuantSpec(bits=4, group_size=32, iters=2, backend="bcq_xla")
    qparams, _ = quantize_model(params, spec, m.axes())
    mq = Model(cfg.replace(quant=spec))
    loss_q = float(mq.loss_fn(qparams, batch))
    assert abs(loss_q - loss_fp) < 0.05, (loss_fp, loss_q)


def test_input_specs_all_cells():
    """input_specs builds a well-formed spec for every (arch x shape)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.supports_long_context():
                continue
            specs = cfg.input_specs(shape)
            assert "tokens" in specs
            for v in specs.values():
                assert all(d > 0 for d in v.shape)
