"""Unit tests for the roofline extraction machinery + complexity claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis as ra
from repro.core import energy_model as em
from repro.configs import get_config, SHAPES


class TestCollectiveParser:
    def test_parses_shapes_and_ops(self):
        hlo = """
          %ar = f32[128,256]{1,0} all-reduce(f32[128,256] %x), replica_groups={}
          %ag.1 = bf16[64]{0} all-gather(bf16[32] %y), dimensions={0}
          %aa = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%a, %b)
          %rs = f32[16]{0} reduce-scatter(f32[64] %z), dimensions={0}
          %cp = u8[1024]{0} collective-permute(u8[1024] %w)
          %dot = f32[128,128]{1,0} dot(%p, %q)
        """
        out = ra.collective_bytes(hlo)
        assert out["all-reduce"] == 128 * 256 * 4
        assert out["all-gather"] == 64 * 2
        assert out["all-to-all"] == 2 * 8 * 8 * 4
        assert out["reduce-scatter"] == 16 * 4
        assert out["collective-permute"] == 1024
        assert "dot" not in out

    def test_real_compiled_module(self):
        # a sharded matmul on 1 device has no collectives
        f = jax.jit(lambda a, b: a @ b)
        c = f.lower(jnp.ones((8, 8)), jnp.ones((8, 8))).compile()
        assert sum(ra.collective_bytes(c.as_text()).values()) == 0


class TestRooflineTerms:
    def test_bottleneck_selection(self):
        r = ra.Roofline(flops=197e12, bytes_accessed=1, coll_bytes=1,
                        coll_breakdown={})
        assert r.bottleneck == "compute" and r.t_compute == pytest.approx(1.0)
        r = ra.Roofline(flops=1, bytes_accessed=819e9 * 2, coll_bytes=1,
                        coll_breakdown={})
        assert r.bottleneck == "memory" and r.t_memory == pytest.approx(2.0)
        r = ra.Roofline(flops=1, bytes_accessed=1, coll_bytes=50e9 * 3,
                        coll_breakdown={})
        assert r.bottleneck == "collective"

    def test_extrapolation_exact_for_linear(self):
        r1 = ra.Roofline(flops=10, bytes_accessed=100, coll_bytes=4,
                         coll_breakdown={"all-reduce": 4})
        r2 = ra.Roofline(flops=16, bytes_accessed=150, coll_bytes=6,
                         coll_breakdown={"all-reduce": 6})
        r = ra.extrapolate(r1, r2, 1, 2, 10)
        assert r.flops == pytest.approx(10 + 6 * 9)
        assert r.bytes_accessed == pytest.approx(100 + 50 * 9)
        assert r.coll_breakdown["all-reduce"] == pytest.approx(4 + 2 * 9)

    def test_serve_analytic_kernel_beats_dense(self):
        cfg = get_config("phi4_mini_3_8b")
        rows = ra.serve_analytic_bytes(cfg, SHAPES["decode_32k"], 3.6e9, 4)
        assert rows["kernel_q"]["weight_bytes"] < \
            rows["dense_bf16"]["weight_bytes"] / 3
        assert rows["kernel_q"]["t_memory_s"] < rows["dense_bf16"]["t_memory_s"]
        # cache term identical across execution paths
        assert rows["kernel_q"]["cache_bytes"] == rows["dense_bf16"]["cache_bytes"]


class TestComplexityTableI:
    """Paper Table I: computational complexity per engine."""

    def test_figlut_reduces_bitserial_by_mu(self):
        m, n, k, q, mu = 512, 512, 8, 3, 4
        ifpu_ops = m * n * k * q
        figlut_reads = m * n * k * q // mu
        assert figlut_reads * mu == ifpu_ops

    def test_energy_model_orderings_stable(self):
        """The calibrated model must preserve the paper's orderings."""
        r = {e: em.model_report(e, "opt-6.7b", B=32, q=4).tops_per_w
             for e in ("FPE", "iFPU", "FIGNA", "FIGLUT-I")}
        assert r["FIGLUT-I"] > r["FIGNA"] > r["iFPU"] > r["FPE"]
        r3 = {e: em.model_report(e, "opt-6.7b", B=32, q=3).tops_per_w
              for e in ("FIGNA", "FIGLUT-I")}
        ratio = r3["FIGLUT-I"] / r3["FIGNA"]
        assert 1.59 * 0.7 < ratio < 1.59 * 1.4   # the +59% headline claim
