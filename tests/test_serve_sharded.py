"""Sharded paged serving: token-for-token equivalence of the mesh
engine against the single-device engine, KV-pool sharding placement, and
the capability negotiation that routes mesh-indivisible head counts to
the gathered path.

Subprocess harness per ``tests/test_sharding.py``: each case runs in a
clean interpreter with 8 fake CPU devices (the device count must be
pinned before jax initializes) and reports JSON on stdout.  The fused
kernel runs under the Pallas interpreter inside ``shard_map`` — slow but
bit-exact, which is the point: greedy decode over a (2, 4) TP/DP mesh
must match the unsharded engine token for token.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, prelude: str = "") -> dict:
    """Run code under 8 fake devices in a clean interpreter; returns JSON.

    ``code`` is dedented BEFORE the (unindented) prelude is prepended —
    mixing the two indentation levels would defeat textwrap.dedent."""
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + prelude + textwrap.dedent(code))
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=570,
                       env={**os.environ, "PYTHONPATH": SRC})
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


_COMMON = """
import jax, json
import jax.numpy as jnp
import numpy as np
from repro.configs import get_reduced
from repro.models import Model
from repro.serve import PagedServeEngine, Request
from repro.launch.mesh import make_mesh

def f32(params):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params)

def requests(cfg, lens=(5, 11, 3, 17), max_new=5):
    rng = np.random.default_rng(0)
    return [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, (int(l),)),
                    max_new_tokens=max_new)
            for i, l in enumerate(lens)]

def tokens_of(done):
    return {str(r.uid): r.out_tokens for r in done}

def first_attn_leaf(cache, key):
    stack = cache.get("layers") or cache.get("prefix") or cache["scan"]
    return stack[0]["self"][key]

KW = dict(num_blocks=24, block_size=4, max_batch=4, max_seq_len=64,
          prefill_buckets=(8, 16))
"""


def test_sharded_matches_single_device_gqa_bcq():
    """Acceptance: on an 8-fake-device (2, 4) mesh, greedy paged decode
    of a GQA + BCQ-quantized model matches the single-device engine
    token-for-token in BOTH fused and gather kernel modes, and the KV
    pool leaves are actually sharded over the model axis."""
    out = run_sub("""
    from repro.quant import QuantSpec, quantize_model
    cfg = get_reduced("opt_6_7b").replace(
        remat=False, dtype="float32", n_heads=8, n_kv_heads=4, head_dim=16)
    model = Model(cfg)
    params = f32(model.init(jax.random.PRNGKey(0)))
    spec = QuantSpec(bits=3, group_size=32, iters=2, backend="bcq_xla")
    qparams, _ = quantize_model(params, spec, model.axes())
    qmodel = Model(cfg.replace(quant=spec))

    base = PagedServeEngine(qmodel, qparams, **KW)
    ref = tokens_of(base.run(requests(cfg)))
    base.pool.check()

    mesh = make_mesh((2, 4), ("data", "model"))
    res = {"ref_lens": sorted(len(v) for v in ref.values())}
    for mode in ("fused", "gather"):
        eng = PagedServeEngine(qmodel, qparams, mesh=mesh,
                               paged_kernel=mode, **KW)
        got = tokens_of(eng.run(requests(cfg)))
        eng.pool.check()
        k = first_attn_leaf(eng.cache, "k")
        res[mode] = {
            "equal": got == ref,
            "decode_path": eng.decode_path,
            "k_spec": str(k.sharding.spec),
            "k_shape": list(k.shape),
            "tokens_out": eng.metrics.counters["tokens_out"],
        }
    print(json.dumps(res))
    """, prelude=_COMMON)
    for mode in ("fused", "gather"):
        r = out[mode]
        assert r["equal"], f"{mode}: sharded tokens diverged from single"
        # kv_heads dim (index 2 of [NB, BS, Hkv, D]) carries the model axis
        assert r["k_spec"] == "PartitionSpec(None, None, 'model')", r
        assert r["tokens_out"] > 0
    assert out["fused"]["decode_path"] == "fused"
    assert out["gather"]["decode_path"] == "gather"


def test_dense_sharded_with_preemption_pressure():
    """Dense params, pool small enough to preempt: the sharded engine's
    preempt-by-recompute must replay to the same tokens as the
    single-device engine (same scheduler, sharded decode)."""
    out = run_sub("""
    cfg = get_reduced("opt_6_7b").replace(
        remat=False, dtype="float32", n_heads=8, n_kv_heads=4, head_dim=16)
    model = Model(cfg)
    params = f32(model.init(jax.random.PRNGKey(0)))
    kw = dict(KW, num_blocks=10)          # 9 usable blocks: forces preempts
    lens = (9, 13, 6, 11)
    base = PagedServeEngine(model, params, **kw)
    ref = tokens_of(base.run(requests(cfg, lens=lens)))
    base.pool.check()
    mesh = make_mesh((2, 4), ("data", "model"))
    eng = PagedServeEngine(model, params, mesh=mesh, paged_kernel="fused",
                           **kw)
    got = tokens_of(eng.run(requests(cfg, lens=lens)))
    eng.pool.check()
    print(json.dumps({"equal": got == ref,
                      "preempted": eng.metrics.counters["preempted"],
                      "path": eng.decode_path}))
    """, prelude=_COMMON)
    assert out["equal"]
    assert out["path"] == "fused"


@pytest.mark.slow
def test_narrow_gqa_falls_back_to_head_dim_and_gather():
    """kv_heads=2 cannot divide tp=4: the pool must shard head_dim over
    the model axis instead (divisibility fallback), the fused kernel
    must NOT be selected even when forced (capability negotiation), and
    tokens still match the single-device engine."""
    out = run_sub("""
    cfg = get_reduced("opt_6_7b").replace(
        remat=False, dtype="float32", n_heads=8, n_kv_heads=2, head_dim=16)
    model = Model(cfg)
    params = f32(model.init(jax.random.PRNGKey(0)))
    base = PagedServeEngine(model, params, **KW)
    ref = tokens_of(base.run(requests(cfg)))
    mesh = make_mesh((2, 4), ("data", "model"))
    eng = PagedServeEngine(model, params, mesh=mesh, paged_kernel="fused",
                           **KW)
    got = tokens_of(eng.run(requests(cfg)))
    eng.pool.check()
    k = first_attn_leaf(eng.cache, "k")
    print(json.dumps({"equal": got == ref, "path": eng.decode_path,
                      "k_spec": str(k.sharding.spec)}))
    """, prelude=_COMMON)
    assert out["equal"]
    assert out["path"] == "gather"        # forced fused still negotiates down
    assert out["k_spec"] == "PartitionSpec(None, None, None, 'model')"


@pytest.mark.slow
def test_sharded_scan_stacked_layers():
    """Scan-stacked layer caches carry a leading layers axis on every
    pool leaf; sharding must land on kv_heads one position later."""
    out = run_sub("""
    cfg = get_reduced("opt_6_7b").replace(
        remat=False, dtype="float32", n_heads=8, n_kv_heads=4, head_dim=16,
        scan_layers=True)
    model = Model(cfg)
    params = f32(model.init(jax.random.PRNGKey(0)))
    base = PagedServeEngine(model, params, **KW)
    ref = tokens_of(base.run(requests(cfg)))
    mesh = make_mesh((2, 4), ("data", "model"))
    eng = PagedServeEngine(model, params, mesh=mesh, paged_kernel="fused",
                           **KW)
    got = tokens_of(eng.run(requests(cfg)))
    k = first_attn_leaf(eng.cache, "k")
    print(json.dumps({"equal": got == ref, "path": eng.decode_path,
                      "k_spec": str(k.sharding.spec)}))
    """, prelude=_COMMON)
    assert out["equal"]
    assert out["path"] == "fused"
    assert out["k_spec"] == "PartitionSpec(None, None, None, 'model')"


def test_sharded_async_engine_matches_sync():
    """The double-buffered async tick composes with the mesh engine: the
    on-device sampler (per-row PRNG keys threaded through the sharded
    ``decode_and_sample`` jit) must reproduce the sync engine's host
    sampling token-for-token over the (2, 4) mesh, for greedy AND
    seeded temperature/top-k rows, and overlap more device time."""
    out = run_sub("""
    cfg = get_reduced("opt_6_7b").replace(
        remat=False, dtype="float32", n_heads=8, n_kv_heads=4, head_dim=16)
    model = Model(cfg)
    params = f32(model.init(jax.random.PRNGKey(0)))

    def sampled():
        rs = requests(cfg)
        for r in rs[1::2]:
            r.temperature, r.top_k, r.seed = 0.7, 8, 99 + r.uid
        return rs

    mesh = make_mesh((2, 4), ("data", "model"))
    ref_eng = PagedServeEngine(model, params, mesh=mesh,
                               paged_kernel="fused", **KW)
    ref = tokens_of(ref_eng.run(sampled()))
    ref_eng.pool.check()
    eng = PagedServeEngine(model, params, mesh=mesh, paged_kernel="fused",
                           **KW)
    got = tokens_of(eng.run_async(sampled()))
    eng.pool.check()
    print(json.dumps({
        "equal": got == ref,
        "path": eng.decode_path,
        "busy_async": eng.metrics.device_busy_fraction(),
        "busy_sync": ref_eng.metrics.device_busy_fraction(),
        "pool_free": eng.pool.free_blocks == eng.pool.capacity,
    }))
    """, prelude=_COMMON)
    assert out["equal"], "sharded async tokens diverged from sync"
    assert out["path"] == "fused"
    assert out["pool_free"]
    assert out["busy_async"] > out["busy_sync"], out


def test_sharded_prefix_cache_matches_single_device_off():
    """Prefix sharing is mesh-transparent: block tables (and the prefix
    index) are replicated host state, so the sharded engine with the
    cache ON must match the single-device engine with the cache OFF
    token-for-token on a shared-prefix stream — while actually hitting
    (adopted blocks are read by every model shard through the same
    replicated table)."""
    out = run_sub("""
    cfg = get_reduced("opt_6_7b").replace(
        remat=False, dtype="float32", n_heads=8, n_kv_heads=4, head_dim=16)
    model = Model(cfg)
    params = f32(model.init(jax.random.PRNGKey(0)))

    def shared(cfg, base_uid=0, max_new=4):
        rng = np.random.default_rng(21)
        prefix = rng.integers(0, cfg.vocab_size, (12,))
        tails = [3, 6, 2, 5]
        return [Request(uid=base_uid + i,
                        prompt=np.concatenate(
                            [prefix, rng.integers(0, cfg.vocab_size,
                                                  (int(t),))]),
                        max_new_tokens=max_new)
                for i, t in enumerate(tails)]

    base = PagedServeEngine(model, params, **KW)
    ref = tokens_of(base.run(shared(cfg)))
    base.pool.check()

    # wave 1 (all admit cold, registering the prefix) then wave 2 (same
    # prompts, fresh uids) through the SAME sharded engine: wave 2 must
    # hit the warm index and still match the cold single-device run
    mesh = make_mesh((2, 4), ("data", "model"))
    eng = PagedServeEngine(model, params, mesh=mesh, paged_kernel="fused",
                           prefix_cache=True, **KW)
    eng.run(shared(cfg))
    got = tokens_of(eng.run(shared(cfg, base_uid=10)))
    eng.pool.check()
    s = eng.metrics.summary()
    eng.prefix.clear()
    want = {}
    for uid, toks in ref.items():
        want[uid] = toks
        want[str(int(uid) + 10)] = toks
    print(json.dumps({
        "equal": got == want,
        "path": eng.decode_path,
        "hit_blocks": s["counters"]["prefix_hit_blocks"],
        "hit_rate": s["prefix_cache"]["hit_rate"],
        "pool_free_after_clear":
            eng.pool.free_blocks == eng.pool.capacity,
    }))
    """, prelude=_COMMON)
    assert out["equal"], "sharded prefix-cache run diverged from baseline"
    assert out["path"] == "fused"
    assert out["hit_blocks"] > 0 and out["hit_rate"] > 0
    assert out["pool_free_after_clear"]
