"""Plane-bundle layout invariants and the dedicated ternary datapath.

Covers: the spec/plane TERNARY_BITS constants agreeing, the ternary
Pallas kernel matching the gathered half-LUT oracle bit-exactly over
the kernel shape matrix (interpret mode), bundle storage-byte honesty
(ternary strictly smaller than generic 2-bit BCQ at equal shape), the
sub-2-bit mixed-precision plan lowering to per-layer ternary bundles,
and serve-level token-for-token equality of the fused ternary kernel
against the XLA fallback backend.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import plane
from repro.kernels.ternary_matmul import dense_ref, ternary_matmul, ternary_ref
from repro.quant import QuantSpec
from repro.quant.formats import quantize_ternary

RNG = np.random.default_rng


def _case(m, n, b, seed, dtype=jnp.float32):
    rng = RNG(seed)
    W = jnp.array(rng.normal(size=(m, n)).astype(np.float32))
    x = jnp.array(rng.normal(size=(b, n)).astype(np.float32), dtype=dtype)
    return W, x


SHAPES = [
    # (M, N, B) — aligned and deliberately ragged cases
    (128, 512, 8),
    (64, 128, 1),
    (96, 200, 5),
    (256, 384, 3),
    (33, 130, 2),
]


def test_ternary_bits_constants_agree():
    """spec.py keeps its own literal to stay import-light; pin them."""
    from repro.quant.spec import TERNARY_BITS as spec_bits
    assert spec_bits == plane.TERNARY_BITS


class TestTernaryKernelExactness:
    """The kernel must be *bit-exact* against the gathered oracle on
    arithmetically exact inputs (pow2 alphas, integer activations):
    there the equality is independent of reduction order and fusion, so
    any mismatch means the in-kernel sign/mask -> (b1, b2) decode
    diverged.  Float inputs may differ by reduction-order ulps only."""

    @pytest.mark.parametrize("m,n,b", SHAPES)
    def test_matches_oracle_exactly(self, m, n, b):
        # pow2 alphas + integer activations make every partial product
        # an exact f32, so the equality is independent of reduction
        # order/fusion — any mismatch is a decode bug, not rounding
        rng = RNG(m + n)
        W = jnp.array(0.5 * rng.integers(-1, 2, size=(m, n)).astype(np.float32))
        x = jnp.array(rng.integers(-8, 9, size=(b, n)).astype(np.float32))
        wq = quantize_ternary(W, group_size=64)
        want = ternary_ref(x, wq)
        got = ternary_matmul(x, wq, interpret=True)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("m,n,b", SHAPES)
    def test_float_case_within_ulp(self, m, n, b):
        W, x = _case(m, n, b, seed=m + n)
        wq = quantize_ternary(W, group_size=64)
        want = np.asarray(ternary_ref(x, wq))
        got = np.asarray(ternary_matmul(x, wq, interpret=True))
        scale = np.abs(want).max() + 1e-6
        np.testing.assert_allclose(got / scale, want / scale, atol=1e-6)

    @pytest.mark.parametrize("read_mode", ["onehot", "select", "gather"])
    def test_read_modes_exact(self, read_mode):
        rng = RNG(7)
        m, n, b = 96, 256, 4
        W = jnp.array(0.5 * rng.integers(-1, 2, size=(m, n)).astype(np.float32))
        x = jnp.array(rng.integers(-8, 9, size=(b, n)).astype(np.float32))
        wq = quantize_ternary(W, group_size=64)
        want = ternary_ref(x, wq)
        got = ternary_matmul(x, wq, read_mode=read_mode, interpret=True)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_matches_dense_dequant(self):
        """And the oracle itself must match plain dequant @ x."""
        W, x = _case(64, 192, 3, seed=1)
        wq = quantize_ternary(W, group_size=64)
        a = np.asarray(ternary_ref(x, wq))
        d = np.asarray(dense_ref(x, wq))
        scale = np.abs(d).max() + 1e-6
        np.testing.assert_allclose(a / scale, d / scale, atol=1e-5)

    def test_integer_exact_case(self):
        """Pow2 alphas + integer activations: every partial product is
        an exact f32, so kernel == oracle == dense regardless of
        accumulation order."""
        rng = RNG(3)
        m, n, b = 64, 128, 4
        w = rng.integers(-1, 2, size=(m, n)).astype(np.float32)
        wq = quantize_ternary(jnp.array(0.5 * w), group_size=64)
        x = jnp.array(rng.integers(-8, 9, size=(b, n)).astype(np.float32))
        got = np.asarray(ternary_matmul(x, wq, interpret=True))
        dense = np.asarray(x) @ (0.5 * w).T
        assert np.array_equal(got, dense)

    def test_rejects_generic_bundles(self):
        from repro.core import bcq
        W, x = _case(32, 64, 2, seed=0)
        wq = bcq.quantize(W, bits=2, group_size=32, iters=1)
        with pytest.raises(ValueError, match="ternary"):
            ternary_matmul(x, wq, interpret=True)


class TestBundleBytes:
    def test_nbytes_counts_stored_arrays_only(self):
        W, _ = _case(48, 160, 1, seed=2)
        wq = quantize_ternary(W, group_size=32)
        want = (wq.packed.size * wq.packed.dtype.itemsize
                + wq.alpha.size * wq.alpha.dtype.itemsize)
        assert wq.z is None and wq.nbytes() == want

    def test_ternary_strictly_smaller_than_bcq2(self):
        """Same shape/groups, same 2 stored planes — the ternary layout
        must win on bytes (1 alpha row vs 2, no offset row)."""
        from repro.core import bcq
        W, _ = _case(48, 160, 1, seed=2)
        t = quantize_ternary(W, group_size=32)
        g = bcq.quantize(W, bits=2, group_size=32, iters=1)
        assert t.packed.shape == g.packed.shape
        assert t.nbytes() < g.nbytes()


class TestMixedPrecisionTernary:
    def test_sub2_plan_lowers_to_ternary_bundles(self):
        """A 1.58-bit average budget must produce at least one ternary
        bundle and charge the budget at the information rate."""
        from repro.quant import quantize_model

        rng = RNG(0)
        params = {f"l{i}": {"up": jnp.array(
            rng.normal(size=(24, 64)).astype(np.float32))} for i in range(3)}
        spec = QuantSpec(bits=1.58, group_size=32, iters=2)
        assert spec.bits == plane.TERNARY_BITS
        qparams, manifest = quantize_model(params, spec)
        kinds = [qparams[f"l{i}"]["up"].kind for i in range(3)]
        assert "ternary" in kinds
        fmts = {l["path"]: l["format"] for l in manifest.layers}
        for i, k in enumerate(kinds):
            assert fmts[f"l{i}/up"] == ("ternary" if k == "ternary" else "bcq")
        # parameter-weighted effective bits must respect the budget
        # (every candidate >= the ternary rate, so >= holds too)
        eff = [qparams[f"l{i}"]["up"].effective_bits for i in range(3)]
        avg = sum(eff) / len(eff)
        assert plane.TERNARY_BITS <= avg <= 2.0 + 1e-9


class TestServeTernary:
    def test_fused_and_fallback_serve_identical_tokens(self):
        """The backend is an execution detail: serving the same ternary
        checkpoint on ternary_pallas (interpret) and on the bcq_xla
        fallback must emit the same tokens for every request."""
        from repro.configs import get_reduced
        from repro.models import Model
        from repro.quant import quantize_model
        from repro.serve import Request, ServeEngine

        cfg = get_reduced("opt_6_7b").replace(
            remat=False, dtype="float32",
            quant=QuantSpec(format="ternary", backend="ternary_pallas"))
        model = Model(cfg)
        params = jax.tree_util.tree_map(
            lambda v: v.astype(jnp.float32) if v.dtype == jnp.bfloat16 else v,
            model.init(jax.random.PRNGKey(0)))
        qparams, _ = quantize_model(params, cfg.quant, model.axes())

        rng = RNG(0)
        prompts = [rng.integers(0, cfg.vocab_size, (int(l),))
                   for l in (5, 9)]
        outs = {}
        for backend in ("ternary_pallas", "bcq_xla"):
            m = Model(cfg.replace(quant=cfg.quant.replace(backend=backend)))
            eng = ServeEngine(m, qparams, slots=2, cache_len=64,
                              prefill_buckets=(16,))
            done = eng.run([Request(uid=i, prompt=p, max_new_tokens=4)
                            for i, p in enumerate(prompts)])
            outs[backend] = {r.uid: list(r.out_tokens) for r in done}
        assert outs["ternary_pallas"] == outs["bcq_xla"]
