"""Paged-KV serving subsystem tests.

Covers: block-pool invariants (no double-booking, exact occupancy),
scheduler policies (FCFS admission by free-block budget, chunked
prefill, preempt-by-recompute) driven model-free by a fake engine loop,
chunked-prefill numerical equivalence against the full forward pass on a
deliberately non-contiguous block table, token-for-token equivalence of
the paged engine vs the contiguous-slot engine on mixed-length request
streams (including under preemption pressure and for MLA), pad
invariance of prefill, >1x effective capacity at equal KV memory, and
streaming + metrics accounting.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import Model
from repro.serve import (BlockPool, PagedServeEngine, PrefixCache, Request,
                         Scheduler, ServeEngine, set_block_tables)

RNG = jax.random.PRNGKey(0)


def _f32(params):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        params)


def _model(arch="opt_6_7b", **over):
    cfg = get_reduced(arch).replace(remat=False, dtype="float32",
                                    capacity_factor=8.0, **over)
    m = Model(cfg)
    return m, _f32(m.init(RNG))


def _requests(vocab, lens, max_new=6, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, vocab, (int(l),)),
                    max_new_tokens=max_new, **kw)
            for i, l in enumerate(lens)]


def _by_uid(reqs):
    return {r.uid: r.out_tokens for r in reqs}


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------


class TestBlockPool:
    def test_alloc_never_hands_out_trash_or_duplicates(self):
        pool = BlockPool(num_blocks=9, block_size=4)
        got = pool.alloc("a", 5) + pool.alloc("b", 3)
        assert 0 not in got
        assert len(set(got)) == 8
        assert pool.free_blocks == 0 and pool.alloc("c", 1) is None
        pool.check()

    def test_occupancy_accounting_exact(self):
        pool = BlockPool(num_blocks=11, block_size=4)    # 10 usable
        a = pool.alloc("a", 4)
        assert pool.used_blocks == 4 and pool.occupancy() == 0.4
        pool.free(a[:2], "a")
        assert pool.used_blocks == 2 and pool.free_blocks == 8
        pool.free(a[2:], "a")
        assert pool.occupancy() == 0.0
        pool.check()

    def test_double_free_and_wrong_owner_rejected(self):
        pool = BlockPool(num_blocks=5, block_size=4)
        a = pool.alloc("a", 1)
        pool.free(a, "a")
        with pytest.raises(AssertionError):
            pool.free(a, "a")
        b = pool.alloc("b", 1)
        with pytest.raises(AssertionError):
            pool.free(b, "a")

    def test_alloc_is_all_or_nothing(self):
        pool = BlockPool(num_blocks=4, block_size=4)     # 3 usable
        assert pool.alloc("a", 5) is None
        assert pool.free_blocks == 3                     # nothing stranded
        assert pool.blocks_for(9) == 3 and pool.blocks_for(0) == 0


# ---------------------------------------------------------------------------
# scheduler (model-free: a fake engine just advances kv_len / appends tokens)
# ---------------------------------------------------------------------------


def _drive(sched, max_ticks=200):
    """Fake engine: execute every plan without a model."""
    finished, preempt_events = [], 0
    for _ in range(max_ticks):
        if not sched.has_work():
            break
        plan = sched.plan_tick()
        finished.extend(plan.rejected)
        preempt_events += len(plan.preempted)
        for seq in plan.failed:
            sched.finish(seq)
            seq.req.done = True
            finished.append(seq.req)
        for seq in plan.decode:
            seq.kv_len += 1
            seq.req.out_tokens.append(0)
            if len(seq.req.out_tokens) >= seq.req.max_new_tokens:
                sched.finish(seq)
                seq.req.done = True
                finished.append(seq.req)
        if plan.prefill is not None:
            seq = plan.prefill.seq
            seq.kv_len += plan.prefill.length
            if seq.kv_len >= seq.prefill_target:
                seq.req.out_tokens.append(0)
                if len(seq.req.out_tokens) >= seq.req.max_new_tokens:
                    sched.finish(seq)
                    seq.req.done = True
                    finished.append(seq.req)
    return finished, preempt_events


class TestScheduler:
    def _sched(self, num_blocks=9, block_size=4, rows=2, buckets=(8,),
               max_blocks_per_seq=8):
        pool = BlockPool(num_blocks, block_size)
        return Scheduler(pool, rows=rows, buckets=buckets,
                         max_blocks_per_seq=max_blocks_per_seq), pool

    def test_fcfs_admission_bounded_by_rows(self):
        sched, _ = self._sched(rows=2)
        reqs = _requests(100, [6, 6, 6], max_new=2)
        for r in reqs:
            sched.submit(r)
        plan = sched.plan_tick()
        assert [s.uid for s in plan.admitted] == [0, 1]
        assert list(sched.waiting) == [reqs[2]]

    def test_admission_blocked_by_budget_no_skip_ahead(self):
        # A consumes most of the tick budget; B (the new queue head)
        # doesn't fit the residual, and the smaller C behind it — which
        # WOULD fit — must NOT jump the queue (FCFS)
        sched, pool = self._sched(num_blocks=7, block_size=4, rows=3,
                                  max_blocks_per_seq=6)
        sched.submit(Request(uid=0, prompt=np.zeros(16, np.int32),
                             max_new_tokens=4))     # budget 5 blocks
        sched.submit(Request(uid=1, prompt=np.zeros(9, np.int32),
                             max_new_tokens=2))     # needs 3 > 1 left
        sched.submit(Request(uid=2, prompt=np.zeros(2, np.int32),
                             max_new_tokens=1))     # needs 1 — would fit
        plan = sched.plan_tick()
        assert [s.uid for s in plan.admitted] == [0]
        assert [r.uid for r in sched.waiting] == [1, 2]

    def test_admission_reserve_capped_by_final_footprint(self):
        # final footprint == pool capacity exactly: the decode-headroom
        # reserve must not push the demand past capacity, or the request
        # can never be admitted (wedge found by the fuzz suite)
        sched, pool = self._sched(num_blocks=4, block_size=8, rows=1,
                                  max_blocks_per_seq=3)
        req = Request(uid=0, prompt=np.zeros(21, np.int32), max_new_tokens=3)
        sched.submit(req)
        finished, _ = _drive(sched)
        assert req.done and req.error is None
        assert len(req.out_tokens) == 3
        pool.check()
        assert pool.free_blocks == pool.capacity

    def test_impossible_request_rejected_not_queued_forever(self):
        sched, pool = self._sched(num_blocks=4, block_size=4,
                                  max_blocks_per_seq=8)
        big = Request(uid=0, prompt=np.zeros(20, np.int32), max_new_tokens=8)
        small = Request(uid=1, prompt=np.zeros(3, np.int32), max_new_tokens=2)
        for r in (big, small):
            sched.submit(r)
        finished, _ = _drive(sched)
        assert big.error == "too_long" and big.done
        assert small.error is None and small.done
        pool.check()

    def test_preemption_picks_youngest_and_recomputes(self):
        # 7 usable blocks (bs=2): two seqs of prompt 6 + 6 new tokens
        # need 6 blocks each at the end -> the pool must run dry during
        # decode and preempt the YOUNGER seq (uid 1), never the older
        sched, pool = self._sched(num_blocks=8, block_size=2, rows=2,
                                  buckets=(8,), max_blocks_per_seq=6)
        reqs = _requests(100, [6, 6], max_new=6)
        for r in reqs:
            sched.submit(r)
        finished, preempts = _drive(sched)
        assert preempts >= 1
        assert {r.uid for r in finished} == {0, 1}
        assert all(len(r.out_tokens) == 6 and r.error is None
                   for r in finished)
        # FCFS priority: the older request finished first, untouched
        assert finished[0].uid == 0
        pool.check()
        assert pool.occupancy() == 0.0

    def test_admission_bounded_by_max_seq_len_not_block_rounding(self):
        # max_seq_len=6 with block_size=4 rounds to 2 blocks = 8 slots;
        # a request totalling 7 tokens fits the BLOCKS but not the
        # sequence bound and must be rejected, not decoded past
        # max_seq_len (overrunning learned-position tables)
        pool = BlockPool(9, 4)
        sched = Scheduler(pool, rows=2, buckets=(8,), max_blocks_per_seq=2,
                          max_seq_len=6)
        fits = Request(uid=0, prompt=np.zeros(2, np.int32), max_new_tokens=4)
        over = Request(uid=1, prompt=np.zeros(3, np.int32), max_new_tokens=4)
        for r in (fits, over):
            sched.submit(r)
        finished, _ = _drive(sched)
        assert over.error == "too_long" and over.done
        assert fits.error is None and len(fits.out_tokens) == 4
        pool.check()

    def test_same_tick_admit_preempt_is_net_noop(self):
        # A1/A2 (old, decoding, both at a block boundary) + D (old,
        # mid-block) leave exactly one free block.  B is admitted this
        # tick (reserve 1 fits), then A1's top-up takes the last block
        # and A2's dry top-up preempts the youngest seqs: first B (the
        # same-tick admit), then D.  B must vanish from plan.admitted
        # and NOT appear in plan.preempted — it never held KV — while D
        # is a genuine preempt.
        from repro.serve.scheduler import SeqState
        pool = BlockPool(9, 4)                       # 8 usable
        sched = Scheduler(pool, rows=4, buckets=(16,), max_blocks_per_seq=8)

        def running(uid, kv, nblocks, admit_seq, row):
            req = Request(uid=uid, prompt=np.zeros(kv, np.int32),
                          max_new_tokens=8)
            req.out_tokens = [0]                     # decoding
            seq = SeqState(req=req, row=row, admit_seq=admit_seq,
                           prefill_target=kv, kv_len=kv,
                           table=pool.alloc(uid, nblocks))
            sched.running.append(seq)
            sched._free_rows.remove(row)
            return seq

        a1 = running(0, kv=12, nblocks=3, admit_seq=0, row=0)
        a2 = running(1, kv=12, nblocks=3, admit_seq=1, row=1)
        d = running(2, kv=1, nblocks=1, admit_seq=2, row=2)
        sched._admit_counter = 3
        assert pool.free_blocks == 1
        b = Request(uid=3, prompt=np.zeros(3, np.int32), max_new_tokens=1)
        sched.submit(b)
        plan = sched.plan_tick()
        admitted = {s.uid for s in plan.admitted}
        preempted = {s.uid for s in plan.preempted}
        assert admitted.isdisjoint(preempted)        # the identity
        assert admitted == set() and preempted == {2}
        assert [r.uid for r in sched.waiting] == [2, 3]   # arrival order
        assert {s.uid for s in plan.decode} == {0, 1}
        pool.check()

    def test_prefill_rides_buckets_and_chunks(self):
        sched, pool = self._sched(num_blocks=20, block_size=4, rows=1,
                                  buckets=(4, 8), max_blocks_per_seq=16)
        req = Request(uid=0, prompt=np.zeros(19, np.int32), max_new_tokens=1)
        sched.submit(req)
        chunks = []
        for _ in range(10):
            plan = sched.plan_tick()
            if plan.prefill is None:
                break
            chunks.append((plan.prefill.start, plan.prefill.length))
            plan.prefill.seq.kv_len += plan.prefill.length
        assert chunks == [(0, 8), (8, 8), (16, 3)]   # capped at top bucket


# ---------------------------------------------------------------------------
# chunked prefill == full forward (non-contiguous physical blocks)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["opt_6_7b", "minicpm3_4b"])
def test_chunked_paged_prefill_matches_forward(arch):
    """prefill_chunk x3 into a scrambled block table + decode must equal
    the full-sequence forward logits (teacher forcing, f32 exact-ish)."""
    m, params = _model(arch)
    cfg = m.cfg
    b, s = 1, 24
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    full = m.forward(params, batch)

    bs, nblk = 4, 10
    cache = m.init_paged_cache(b, num_blocks=16, block_size=bs,
                               max_blocks_per_seq=nblk)
    # deliberately scrambled, non-contiguous physical blocks
    table = np.full((1, nblk), -1, np.int32)
    table[0, :8] = [11, 3, 7, 14, 2, 9, 5, 12]
    cache = set_block_tables(cache, table)

    errs = []
    for c0, c1 in ((0, 7), (7, 15), (15, s - 4)):
        toks = batch["tokens"][:, c0:c1]
        logits, cache = m.prefill_chunk(params, {"tokens": toks}, cache,
                                        jnp.int32(c0), jnp.int32(c1 - c0 - 1))
        errs.append(float(jnp.abs(logits - full[:, c1 - 1]).max()))
    for t in range(s - 4, s - 1):
        logits, cache = m.decode_step(params, batch["tokens"][:, t:t + 1],
                                      cache, t)
        errs.append(float(jnp.abs(logits - full[:, t]).max()))
    assert max(errs) < 2e-4, errs


def test_paged_prefill_right_pad_is_dead_write():
    """Right-padded chunk positions must not corrupt later real tokens:
    padding a chunk to a bucket then writing the real tokens gives the
    same logits as never padding."""
    m, params = _model()
    cfg = m.cfg
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    full = m.forward(params, {"tokens": toks})

    cache = m.init_paged_cache(1, num_blocks=8, block_size=4,
                               max_blocks_per_seq=6)
    table = np.full((1, 6), -1, np.int32)
    table[0, :4] = [2, 5, 1, 6]
    cache = set_block_tables(cache, table)
    # chunk 1: 6 real tokens padded to 8 (pads write junk at pos 6..7)
    chunk = jnp.zeros((1, 8), jnp.int32).at[:, :6].set(toks[:, :6])
    _, cache = m.prefill_chunk(params, {"tokens": chunk}, cache,
                               jnp.int32(0), jnp.int32(5))
    # chunk 2: real tokens 6..11 must overwrite the pad junk exactly
    logits, cache = m.prefill_chunk(params, {"tokens": toks[:, 6:]}, cache,
                                    jnp.int32(6), jnp.int32(5))
    assert float(jnp.abs(logits - full[:, 11]).max()) < 2e-4


# ---------------------------------------------------------------------------
# engine equivalence: paged vs contiguous slots
# ---------------------------------------------------------------------------


def test_paged_engine_matches_contiguous_greedy():
    """Token-for-token greedy equivalence on a mixed-length stream, with
    prompts longer than the largest bucket (forces chunked prefill)."""
    m, params = _model()
    lens = [3, 9, 17, 30, 5, 12]
    ep = PagedServeEngine(m, params, num_blocks=24, block_size=8,
                          max_batch=3, max_seq_len=64,
                          prefill_buckets=(8, 16))
    done_p = ep.run(_requests(m.cfg.vocab_size, lens), max_ticks=400)
    ec = ServeEngine(m, params, slots=3, cache_len=64,
                     prefill_buckets=(8, 16))
    done_c = ec.run(_requests(m.cfg.vocab_size, lens), max_ticks=400)
    assert len(done_p) == len(done_c) == len(lens)
    assert _by_uid(done_p) == _by_uid(done_c)
    ep.pool.check()
    assert ep.pool.occupancy() == 0.0
    assert ep.metrics.counters["prefill_chunks"] > len(lens)  # chunking hit


def test_recycled_block_stale_pos_is_masked():
    """A freed block re-allocated at a different logical index still
    holds the dead owner's pos values; those satisfy kpos <= qpos, so
    the view must mask them (slot live only when stored pos == logical
    index) or the new sequence attends to dead K/V.  Deterministic
    repro: prefill A through physical blocks [1, 2], then hand block 1
    to B as its logical block 1 — B's logits must equal a clean-pool
    run exactly."""
    m, params = _model()
    cfg = m.cfg
    rng = np.random.default_rng(11)
    a_toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    b_toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 5)), jnp.int32)

    dirty = m.init_paged_cache(1, num_blocks=8, block_size=4,
                               max_blocks_per_seq=4)
    ta = np.full((1, 4), -1, np.int32)
    ta[0, :2] = [1, 2]
    dirty = set_block_tables(dirty, ta)
    _, dirty = m.prefill_chunk(params, {"tokens": a_toks}, dirty,
                               jnp.int32(0), jnp.int32(7))
    tb = np.full((1, 4), -1, np.int32)
    tb[0, :2] = [3, 1]                    # block 1 recycled, stale pos 1..3
    dirty = set_block_tables(dirty, tb)
    logits_dirty, _ = m.prefill_chunk(params, {"tokens": b_toks}, dirty,
                                      jnp.int32(0), jnp.int32(4))

    clean = m.init_paged_cache(1, num_blocks=8, block_size=4,
                               max_blocks_per_seq=4)
    clean = set_block_tables(clean, tb)
    logits_clean, _ = m.prefill_chunk(params, {"tokens": b_toks}, clean,
                                      jnp.int32(0), jnp.int32(4))
    np.testing.assert_allclose(np.asarray(logits_dirty),
                               np.asarray(logits_clean), atol=1e-6)


def test_recycled_blocks_never_leak_stale_kv():
    """A freed block re-allocated at a DIFFERENT logical index still
    holds the dead request's pos values; the view must mask them (a slot
    is live only when its stored pos equals its logical index), or a
    later sequence attends to the dead request's K/V.  Short request A
    retires early; long request B's decode top-ups then recycle A's
    blocks at higher logical indices."""
    m, params = _model()
    v = m.cfg.vocab_size

    def mk():       # A: 4 blocks, retires fast; B: grows to 16+ tokens
        rng = np.random.default_rng(7)
        return [Request(uid=0, prompt=rng.integers(0, v, (16,)),
                        max_new_tokens=2),
                Request(uid=1, prompt=rng.integers(0, v, (4,)),
                        max_new_tokens=14)]
    ep = PagedServeEngine(m, params, num_blocks=9, block_size=4,
                          max_batch=2, max_seq_len=32,
                          prefill_buckets=(8, 16))
    done_p = ep.run(mk(), max_ticks=300)
    ec = ServeEngine(m, params, slots=2, cache_len=32,
                     prefill_buckets=(8, 16))
    done_c = ec.run(mk(), max_ticks=300)
    assert _by_uid(done_p) == _by_uid(done_c)
    ep.pool.check()


def test_paged_engine_scan_stacked_layers():
    """scan_layers=True stacks cache leaves with a leading layers axis —
    the paged engine (incl. single-row prefill table slices) must work."""
    m, params = _model(scan_layers=True)
    lens = [3, 9, 17]
    ep = PagedServeEngine(m, params, num_blocks=24, block_size=8,
                          max_batch=2, max_seq_len=64,
                          prefill_buckets=(8, 16))
    done_p = ep.run(_requests(m.cfg.vocab_size, lens), max_ticks=300)
    ec = ServeEngine(m, params, slots=2, cache_len=64,
                     prefill_buckets=(8, 16))
    done_c = ec.run(_requests(m.cfg.vocab_size, lens), max_ticks=300)
    assert _by_uid(done_p) == _by_uid(done_c)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["phi4_mini_3_8b", "minicpm3_4b"])
def test_paged_engine_preemption_still_matches(arch):
    """A pool too small for the whole stream forces preempt-by-recompute;
    greedy outputs must be unchanged (RoPE GQA + MLA paged paths)."""
    m, params = _model(arch)
    lens = [3, 9, 17, 5]
    ep = PagedServeEngine(m, params, num_blocks=10, block_size=4,
                          max_batch=3, max_seq_len=40,
                          prefill_buckets=(8, 16))
    done_p = ep.run(_requests(m.cfg.vocab_size, lens), max_ticks=500)
    ec = ServeEngine(m, params, slots=3, cache_len=40,
                     prefill_buckets=(8, 16))
    done_c = ec.run(_requests(m.cfg.vocab_size, lens), max_ticks=500)
    assert ep.metrics.counters["preempted"] >= 1
    assert _by_uid(done_p) == _by_uid(done_c)
    ep.pool.check()


def test_prefill_pad_invariance():
    """Greedy outputs must not depend on how much padding the length
    bucket adds — pads are masked, not attended (both engines)."""
    m, params = _model()
    outs = []
    for buckets in ((16,), (32,)):
        eng = ServeEngine(m, params, slots=1, cache_len=64,
                          prefill_buckets=buckets)
        done = eng.run(_requests(m.cfg.vocab_size, [9], max_new=5))
        outs.append(done[0].out_tokens)
    for buckets in ((16,), (32,)):
        eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                               max_batch=1, max_seq_len=64,
                               prefill_buckets=buckets)
        done = eng.run(_requests(m.cfg.vocab_size, [9], max_new=5))
        outs.append(done[0].out_tokens)
    assert all(o == outs[0] for o in outs), outs


# ---------------------------------------------------------------------------
# capacity: paged admits beyond the old slot grid at equal KV memory
# ---------------------------------------------------------------------------


def test_paged_capacity_exceeds_slot_grid_at_equal_memory():
    """KV budget = 2 slots x 64 = 128 entries.  The slot grid caps at 2
    concurrent requests; the paged pool (16 usable blocks x 8 = the same
    128 entries) runs ~6 short requests concurrently and completes a
    stream whose old-style reservation (6 x 64 = 384) is 3x the memory."""
    m, params = _model()
    lens = [8, 6, 9, 7, 8, 5]
    eng = PagedServeEngine(m, params, num_blocks=17, block_size=8,
                           max_batch=6, max_seq_len=64,
                           prefill_buckets=(8, 16))
    done = eng.run(_requests(m.cfg.vocab_size, lens, max_new=4),
                   max_ticks=300)
    assert len(done) == len(lens)
    assert all(r.error is None and len(r.out_tokens) == 4 for r in done)
    s = eng.metrics.summary()
    assert s["peak_active"] > 2          # beyond the equal-memory slot grid
    assert s["counters"]["preempted"] == 0   # actual usage fits the pool
    eng.pool.check()


# ---------------------------------------------------------------------------
# streaming + metrics
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-3
        return self.t


def test_streaming_and_metrics_accounting():
    m, params = _model()
    streamed = {}

    def on_token(tok, req):
        streamed.setdefault(req.uid, []).append(tok)

    eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                           max_batch=2, max_seq_len=64,
                           prefill_buckets=(16,), clock=_FakeClock())
    reqs = _requests(m.cfg.vocab_size, [5, 11, 7], max_new=4,
                     on_token=on_token)
    done = eng.run(reqs, max_ticks=200)
    assert len(done) == 3
    for r in done:
        assert streamed[r.uid] == r.out_tokens     # every token, in order
    s = eng.metrics.summary()
    assert s["counters"]["tokens_out"] == sum(len(r.out_tokens) for r in done)
    assert s["counters"]["completed"] == 3
    assert s["ttft_s"]["n"] == 3                   # one TTFT per request
    assert s["per_token_s"]["n"] == s["counters"]["tokens_out"] - 3
    assert 0.0 <= s["occupancy"]["peak"] <= 1.0
    assert eng.pool.occupancy() == 0.0             # fully drained
    blob = json.loads(eng.metrics.to_json())
    assert blob["counters"]["tokens_out"] == s["counters"]["tokens_out"]


def test_empty_prompt_rejected_not_crashed():
    """Zero-length prompts must be rejected by both engines, not crash
    the serving loop mid-run."""
    m, params = _model()
    for make in (lambda: PagedServeEngine(m, params, num_blocks=16,
                                          block_size=8, max_batch=2,
                                          max_seq_len=64,
                                          prefill_buckets=(16,)),
                 lambda: ServeEngine(m, params, slots=2, cache_len=64,
                                     prefill_buckets=(16,))):
        reqs = [Request(uid=0, prompt=np.zeros(0, np.int32),
                        max_new_tokens=3),
                Request(uid=1, prompt=np.arange(5) % m.cfg.vocab_size,
                        max_new_tokens=3)]
        done = make().run(reqs, max_ticks=100)
        assert len(done) == 2
        empty = next(r for r in done if r.uid == 0)
        assert empty.error == "empty_prompt" and empty.out_tokens == []
        assert next(r for r in done if r.uid == 1).error is None


def test_tick_budget_exhaustion_marks_requests_done():
    """``run`` hitting max_ticks must not strand requests neither done
    nor errored (callers polling ``req.done`` would hang forever): the
    drained requests carry error="tick_budget", land in ``finished``,
    and their pool blocks are freed."""
    m, params = _model()
    eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                           max_batch=2, max_seq_len=64,
                           prefill_buckets=(16,))
    reqs = _requests(m.cfg.vocab_size, [5, 7, 4], max_new=50)
    done = eng.run(reqs, max_ticks=2)
    assert len(done) == 3 and all(r.done for r in reqs)
    drained = [r for r in done if r.error == "tick_budget"]
    assert drained, "tick budget hit but nothing marked tick_budget"
    assert eng.metrics.counters["failed"] == len(drained)
    eng.pool.check()
    assert eng.pool.free_blocks == eng.pool.capacity


def test_engine_retires_at_max_seq_len_not_block_capacity():
    """A sequence that (via a deliberately loosened scheduler bound)
    would decode into its last block's slack must be retired by the
    ENGINE at max_seq_len: with max_seq_len=6 and block_size=4 the
    block-rounded capacity is 8, and pre-fix the engine decoded to 8
    tokens — positions 6 and 7 overrun a learned-position table sized
    to max_seq_len."""
    m, params = _model()
    eng = PagedServeEngine(m, params, num_blocks=16, block_size=4,
                           max_batch=2, max_seq_len=6, prefill_buckets=(8,))
    assert eng.max_blocks_per_seq * eng.block_size == 8     # the slack
    eng.sched.max_seq_len = 8        # simulate the old, loose admission
    # total 3 + 5 = 8 fits the loosened bound AND the block budget
    # (blocks_for(8) == 2), so the request is admitted and the ENGINE
    # bound is what must stop it at 6 tokens (pre-fix: decoded all 8)
    req = Request(uid=0, prompt=np.arange(3) % m.cfg.vocab_size,
                  max_new_tokens=5)
    done = eng.run([req], max_ticks=50)
    assert done and done[0].done and req.error is None
    assert len(req.out_tokens) == 3          # stopped at max_seq_len=6
    assert len(req.prompt) + len(req.out_tokens) <= 6
    eng.pool.check()


def test_admission_budget_reserved_within_tick():
    """One tick must not admit two requests whose combined prompt
    footprint exceeds the pool — blocks promised to the first admission
    count against the second's budget."""
    pool = BlockPool(num_blocks=11, block_size=4)     # 10 usable
    sched = Scheduler(pool, rows=2, buckets=(32,), max_blocks_per_seq=10)
    for i in range(2):                                # 8 blocks each
        sched.submit(Request(uid=i, prompt=np.zeros(31, np.int32),
                             max_new_tokens=1))
    plan = sched.plan_tick()
    assert [s.uid for s in plan.admitted] == [0]      # second waits


def test_contiguous_engine_rejects_overlong_prompt():
    """A prompt that can't fit cache_len must be rejected with an error,
    not silently truncated by the ring insert."""
    m, params = _model()
    eng = ServeEngine(m, params, slots=1, cache_len=32, prefill_buckets=(8,))
    reqs = _requests(m.cfg.vocab_size, [40, 6], max_new=3)
    done = eng.run(reqs, max_ticks=100)
    assert len(done) == 2
    big = next(r for r in done if r.uid == 0)
    ok = next(r for r in done if r.uid == 1)
    assert big.error == "too_long" and big.out_tokens == []
    assert ok.error is None and len(ok.out_tokens) == 3


def test_contiguous_engine_streams_too():
    m, params = _model()
    seen = []
    reqs = _requests(m.cfg.vocab_size, [6], max_new=3,
                     on_token=lambda t, r: seen.append(t))
    done = ServeEngine(m, params, slots=1, cache_len=32,
                       prefill_buckets=(8,)).run(reqs)
    assert seen == done[0].out_tokens and len(seen) == 3


# ---------------------------------------------------------------------------
# fuzz: random streams keep paged == contiguous and the books balanced
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fuzz_paged_matches_contiguous_under_pressure(seed):
    """Random prompt lengths / arrival orders / generation budgets on a
    pool small enough to force preemption: the paged engine must stay
    token-for-token equal to the contiguous-slot oracle, the metrics
    token counts must sum to the tokens actually emitted, and the pool
    must drain clean."""
    m, params = _model()
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(4, 8))
    lens = rng.integers(1, 28, n).tolist()
    news = rng.integers(1, 7, n).tolist()
    order = rng.permutation(n).tolist()

    def mk():
        r = np.random.default_rng(seed)
        reqs = [Request(uid=i, prompt=r.integers(0, m.cfg.vocab_size,
                                                 (int(lens[i]),)),
                        max_new_tokens=int(news[i]))
                for i in range(n)]
        return [reqs[i] for i in order]       # shuffled arrival order

    ep = PagedServeEngine(m, params, num_blocks=12, block_size=4,
                          max_batch=3, max_seq_len=48,
                          prefill_buckets=(8, 16))
    done_p = ep.run(mk(), max_ticks=600)
    ec = ServeEngine(m, params, slots=3, cache_len=48,
                     prefill_buckets=(8, 16))
    done_c = ec.run(mk(), max_ticks=600)
    assert len(done_p) == len(done_c) == n
    assert _by_uid(done_p) == _by_uid(done_c)
    s = ep.metrics.summary()
    emitted = sum(len(r.out_tokens) for r in done_p)
    assert s["counters"]["tokens_out"] == emitted
    # every emitted token is either a decode-step token or the token
    # sampled when a prefill completes; preempt-by-recompute adds at
    # most one extra prefill completion per preemption event
    first_toks = sum(1 for r in done_p if r.out_tokens)
    prefill_finishes = emitted - s["counters"]["decode_tokens"]
    assert first_toks <= prefill_finishes \
        <= first_toks + s["counters"]["preempted"]
    ep.pool.check()
    assert ep.pool.occupancy() == 0.0


# ---------------------------------------------------------------------------
# prefix cache: refcounted block sharing, CoW-by-recompute
# ---------------------------------------------------------------------------


def _shared_prefix_requests(vocab, *, prefix_len, tails, max_new=4, seed=3):
    """Requests sharing one random prefix, each with a unique tail."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, (prefix_len,))
    return [Request(uid=i,
                    prompt=np.concatenate(
                        [prefix, rng.integers(0, vocab, (int(t),))]),
                    max_new_tokens=max_new)
            for i, t in enumerate(tails)]


def test_prefix_cache_on_off_token_equivalence():
    """Acceptance: a shared-prefix stream generates token-for-token
    identical outputs with the prefix cache on and off, while the on-run
    actually shares (hits > 0, fewer prefill chunks)."""
    m, params = _model()
    kw = dict(num_blocks=32, block_size=4, max_batch=3, max_seq_len=64,
              prefill_buckets=(8, 16))
    mk = lambda: _shared_prefix_requests(m.cfg.vocab_size, prefix_len=12,
                                         tails=[3, 5, 2, 7, 4])
    off = PagedServeEngine(m, params, **kw)
    done_off = off.run(mk(), max_ticks=400)
    on = PagedServeEngine(m, params, prefix_cache=True, **kw)
    done_on = on.run(mk(), max_ticks=400)
    assert _by_uid(done_on) == _by_uid(done_off)
    s = on.metrics.summary()
    assert s["prefix_cache"]["blocks_saved"] > 0
    assert s["prefix_cache"]["hit_rate"] > 0
    assert on.metrics.counters["prefill_chunks"] \
        < off.metrics.counters["prefill_chunks"]
    # the off-engine emits a neutral prefix section (glossary contract)
    assert off.metrics.summary()["prefix_cache"]["blocks_saved"] == 0
    assert off.metrics.summary()["effective_capacity"]["peak"] == 1.0
    on.pool.check()
    # drain: sequences released everything; only the cache still holds
    assert on.pool.used_blocks == len(on.prefix)
    on.prefix.clear()
    assert on.pool.free_blocks == on.pool.capacity


def test_prefix_cache_warm_probe_skips_prefill():
    """A repeat of an identical prompt adopts every full block short of
    the prefill target: exactly one prefill chunk, tokens_saved ==
    block-aligned cap, and TTFT reflects the skip (fewer ticks to the
    first token)."""
    m, params = _model()
    eng = PagedServeEngine(m, params, num_blocks=16, block_size=4,
                           max_batch=2, max_seq_len=64,
                           prefill_buckets=(8,), prefix_cache=True,
                           clock=_FakeClock())
    prompt = np.random.default_rng(5).integers(0, m.cfg.vocab_size, (16,))
    cold = Request(uid=0, prompt=prompt, max_new_tokens=3)
    eng.run([cold], max_ticks=100)
    chunks_cold = eng.metrics.counters["prefill_chunks"]
    assert chunks_cold == 2                       # 16 tokens / 8-bucket

    warm = Request(uid=1, prompt=prompt, max_new_tokens=3)
    eng.run([warm], max_ticks=100)
    assert warm.out_tokens == cold.out_tokens     # greedy, same prompt
    assert eng.metrics.counters["prefill_chunks"] == chunks_cold + 1
    # cap = (16-1)//4 = 3 full blocks -> 12 of 16 prompt tokens adopted
    assert eng.metrics.counters["prefix_tokens_saved"] == 12
    assert eng.metrics.counters["prefix_hit_requests"] == 1
    eng.pool.check()


def test_admission_budget_counts_only_new_blocks():
    """Regression (the budget bug this PR fixes): a request whose prompt
    is almost fully cache-resident must admit even when the free-block
    count alone could not cover its naive footprint — hit blocks are
    adopted, not allocated, so only NEW blocks count."""
    pool = BlockPool(num_blocks=9, block_size=4)      # 8 usable
    cache = PrefixCache(pool)
    sched = Scheduler(pool, rows=2, buckets=(8,), max_blocks_per_seq=8,
                      prefix_cache=cache)
    prompt = np.arange(16, dtype=np.int32) % 3
    # A prefills 16 tokens and keeps decoding: its 4 prompt blocks are
    # registered and stay PINNED (refcount 2: A + cache), so eviction
    # cannot rescue a naive budget check
    a = Request(uid=0, prompt=prompt, max_new_tokens=16)
    sched.submit(a)
    for _ in range(6):
        plan = sched.plan_tick()
        if plan.prefill is not None:
            plan.prefill.seq.kv_len += plan.prefill.length
        for seq in plan.decode:
            seq.kv_len += 1
            seq.req.out_tokens.append(0)
    assert sched.running and sched.running[0].kv_len > 16
    # A holds 5 blocks (4 prompt + 1 decode): free = 3, evictable = 0,
    # naive need for B = blocks_for(16) + reserve = 5 > 3
    assert pool.free_blocks == 3
    assert cache.evictable() == 0
    b = Request(uid=1, prompt=prompt.copy(), max_new_tokens=2)
    sched.submit(b)
    plan = sched.plan_tick()
    admitted = {s.uid for s in plan.admitted}
    assert 1 in admitted, "cache-resident request was starved"
    bseq = next(s for s in sched.running if s.uid == 1)
    assert bseq.prefix_hit == 3 and bseq.shared_tokens == 12
    # adopted blocks are now held by A, B and the cache
    assert all(pool.refcount(blk) == 3 for blk in bseq.table[:3])
    for seq in list(sched.running):
        sched.finish(seq)
    cache.clear()
    pool.check()
    assert pool.free_blocks == pool.capacity


def test_prefix_cache_cow_divergent_tail_recomputed():
    """Two prompts that diverge INSIDE a block: the divergent request
    must not adopt the partially-matching block (CoW-by-recompute), the
    overlap is reported as cow tokens, and outputs match the cache-off
    run."""
    m, params = _model()
    rng = np.random.default_rng(9)
    base = rng.integers(0, m.cfg.vocab_size, (13,))
    var = base.copy()
    var[9] = (var[9] + 1) % m.cfg.vocab_size      # diverge inside block 2
    kw = dict(num_blocks=16, block_size=4, max_batch=1, max_seq_len=64,
              prefill_buckets=(8,))
    mk = lambda: [Request(uid=0, prompt=base, max_new_tokens=3),
                  Request(uid=1, prompt=var, max_new_tokens=3)]
    off = PagedServeEngine(m, params, **kw)
    done_off = off.run(mk(), max_ticks=200)
    on = PagedServeEngine(m, params, prefix_cache=True, **kw)
    done_on = on.run(mk(), max_ticks=200)
    assert _by_uid(done_on) == _by_uid(done_off)
    # uid 1 adopts blocks 0-1 (8 equal tokens) and hits CoW on block 2:
    # one cached token of overlap (position 8) recomputed, not copied
    assert on.metrics.counters["prefix_cow_events"] == 1
    assert on.metrics.counters["prefix_cow_tokens"] == 1
    assert on.metrics.counters["prefix_hit_blocks"] == 2
    on.pool.check()


@pytest.mark.slow
def test_prefix_cache_equivalence_under_preemption():
    """Acceptance: cache-on == cache-off token-for-token even when the
    pool is small enough to force preempt-by-recompute — victims decref
    (never hard-free shared blocks) and re-probe the index on
    re-admission."""
    m, params = _model()
    kw = dict(num_blocks=11, block_size=4, max_batch=3, max_seq_len=48,
              prefill_buckets=(8, 16))
    mk = lambda: _shared_prefix_requests(m.cfg.vocab_size, prefix_len=9,
                                         tails=[8, 2, 6, 4], max_new=5,
                                         seed=11)
    off = PagedServeEngine(m, params, **kw)
    done_off = off.run(mk(), max_ticks=600)
    on = PagedServeEngine(m, params, prefix_cache=True, **kw)
    done_on = on.run(mk(), max_ticks=600)
    assert _by_uid(done_on) == _by_uid(done_off)
    assert on.metrics.counters["prefix_hit_blocks"] > 0
    on.pool.check()
    on.prefix.clear()
    assert on.pool.free_blocks == on.pool.capacity


def test_evictable_excludes_parents_pinned_under_live_children():
    """Regression: dedup can leave a cache-only PARENT entry above a
    child entry whose block a live sequence pins (refcounts are not
    non-increasing with depth).  Leaf-first eviction cannot free that
    parent, so ``evictable()`` must not count it — an optimistic budget
    made the scheduler over-admit and then crash on a failed alloc."""
    pool = BlockPool(num_blocks=10, block_size=4)
    cache = PrefixCache(pool)
    A, B = (0, 1, 2, 3), (4, 5, 6, 7)
    b1, b2 = pool.alloc(1, 2)                  # seq1's private blocks
    b3, b4 = pool.alloc(2, 2)                  # seq2's private blocks
    # both cold requests write chunk A privately; seq1 registers first,
    # seq2 dedups onto seq1's b1 and keeps its own b3 unindexed
    k0 = cache.register(None, A, b1)
    assert cache.register(None, A, b3) == k0
    # next tick seq2 registers its chunk-B block FIRST, so the CHILD
    # entry points at the second sequence's private block b4
    k1 = cache.register(k0, B, b4)
    assert cache.register(k0, B, b2) == k1
    pool.free([b1, b2], 1)                     # seq1 retires
    # the shape: parent entry -> b1 (cache-only), child entry -> b4
    # (pinned by live seq2) — a cache-only parent above a pinned child
    assert pool.refcount(b1) == 1 and pool.refcount(b4) == 2
    assert cache.evictable() == 0              # was 1: the overcount
    assert cache.evict(5) == 0                 # promise == delivery
    pool.free([b3, b4], 2)                     # seq2 retires
    assert cache.evictable() == 2              # whole chain now freeable
    assert cache.evict(5) == 2
    pool.check()
    assert pool.free_blocks == pool.capacity


def test_prefill_defers_when_eviction_underdelivers():
    """Regression: when ``_available()`` over-promises (historically the
    ``evictable()`` overcount) and ``_alloc`` still comes back empty,
    ``_plan_prefill`` must preempt or defer the chunk — never crash the
    tick extending a table with None."""
    pool = BlockPool(num_blocks=5, block_size=4)      # 4 usable
    cache = PrefixCache(pool)
    sched = Scheduler(pool, rows=2, buckets=(8,), max_blocks_per_seq=4,
                      prefix_cache=cache)
    cache.evictable = lambda: 2        # lie: promise blocks evict() can't free
    a = Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                max_new_tokens=8)
    b = Request(uid=1, prompt=np.arange(8, dtype=np.int32) + 1,
                max_new_tokens=1)
    sched.submit(a)
    sched.submit(b)
    plan = sched.plan_tick()           # over-admits b on the lied budget
    assert {s.uid for s in plan.admitted} == {0, 1}
    assert plan.prefill is not None and plan.prefill.seq.uid == 0
    plan.prefill.seq.kv_len += plan.prefill.length
    # a's decode drains the free list to 0; b's prefill needs 2 blocks,
    # _available() still claims 2, but eviction delivers nothing and b
    # has no younger victim — the chunk must be deferred, not crash
    plan = sched.plan_tick()
    assert [s.uid for s in plan.decode] == [0]
    assert plan.prefill is None
    bseq = next(s for s in sched.running if s.uid == 1)
    assert bseq.kv_len == 0 and bseq.table == []
    sched.finish(next(s for s in sched.running if s.uid == 0))
    plan = sched.plan_tick()           # pressure gone: b prefills now
    assert plan.prefill is not None and plan.prefill.seq.uid == 1
    pool.check()


def test_lookup_and_register_verify_parent_on_key_collision():
    """Regression: a key collision between (parentA, chunk) and
    (parentB, chunk) must degrade to a miss, never adopt KV computed
    under a different prefix.  Forced here with a degenerate chain hash
    that ignores the parent entirely."""
    pool = BlockPool(num_blocks=6, block_size=4)
    cache = PrefixCache(pool)
    cache._key = lambda parent, chunk: hash(chunk)    # drop the chain
    X, Y = (0, 1, 2, 3), (4, 5, 6, 7)
    b1, b2 = pool.alloc("w", 2)
    k0 = cache.register(None, X, b1)
    k1 = cache.register(k0, Y, b2)
    assert k1 is not None
    # querying [Y, ...] collides with the depth-1 entry at depth 0: the
    # tokens match but the parent does not — must be a miss
    hits, last = cache.lookup(list(Y + X), 2)
    assert hits == [] and last is None
    # the genuine chain still serves end-to-end
    hits, last = cache.lookup(list(X + Y), 2)
    assert hits == [b1, b2] and last == k1
    # register's dedup branch applies the same parent check: the same
    # colliding (None, Y) registration must refuse, not alias
    b3 = pool.alloc("v", 1)[0]
    assert cache.register(None, Y, b3) is None
    pool.free([b3], "v")
    pool.free([b1, b2], "w")
    cache.clear()
    pool.check()
    assert pool.free_blocks == pool.capacity


def test_register_with_evicted_parent_stops_chain():
    """Regression: registering under a parent key whose entry has been
    evicted (reachable when a sequence's chain key points at a dedup'd
    entry backed by another, retired sequence's block) must stop the
    chain — an orphaned root would be unreachable by lookup yet pin a
    pool block and pollute the sharing metrics."""
    pool = BlockPool(num_blocks=6, block_size=4)
    cache = PrefixCache(pool)
    b1 = pool.alloc("w", 1)[0]
    k0 = cache.register(None, (0, 1, 2, 3), b1)
    pool.free([b1], "w")                   # writer retires; cache-only
    assert cache.evict(1) == 1             # parent entry evicted
    b2 = pool.alloc("w", 1)[0]
    assert cache.register(k0, (4, 5, 6, 7), b2) is None
    assert len(cache) == 0                 # no orphaned root created
    assert cache.lookup([4, 5, 6, 7], 1) == ([], None)
    pool.free([b2], "w")
    pool.check()
    assert pool.free_blocks == pool.capacity


# ---------------------------------------------------------------------------
# async engine: double-buffered ticks + on-device sampling
# ---------------------------------------------------------------------------


def _mixed_sampling(reqs, base_seed=40):
    """Give every other request a seeded temperature/top-k profile so a
    stream exercises host-greedy AND device-categorical sampling."""
    for r in reqs[::2]:
        r.temperature, r.top_k, r.seed = 0.7, 12, base_seed + r.uid
    return reqs


@pytest.mark.parametrize("scenario", ["mixed_sampling", "preempt", "prefix"])
def test_async_engine_matches_sync(scenario):
    """The tentpole's acceptance bar: the double-buffered async tick is
    token-for-token AND schedule-for-schedule identical to the sync
    engine — under mixed greedy/seeded-sampling streams, under
    preemption pressure, and with the prefix cache adopting blocks."""
    m, params = _model()
    vocab = m.cfg.vocab_size
    kw = dict(num_blocks=16, block_size=8, max_batch=3, max_seq_len=64,
              prefill_buckets=(16,))
    if scenario == "preempt":
        # 9 usable blocks against four 3+-block footprints: forces
        # preempt-by-recompute, which async must replay identically
        kw.update(num_blocks=10, block_size=4)

    def make_reqs():
        if scenario == "prefix":
            rng = np.random.default_rng(5)
            prefix = rng.integers(0, vocab, (16,))
            return [Request(uid=i,
                            prompt=np.concatenate(
                                [prefix, rng.integers(0, vocab, (3 + i,))]),
                            max_new_tokens=5)
                    for i in range(4)]
        reqs = _requests(vocab, [9, 13, 6, 11], max_new=6)
        if scenario == "mixed_sampling":
            _mixed_sampling(reqs)
        return reqs

    runs, counters = {}, {}
    for mode in ("sync", "async"):
        eng = PagedServeEngine(m, params,
                               prefix_cache=(scenario == "prefix"), **kw)
        reqs = make_reqs()
        done = (eng.run(reqs, max_ticks=300) if mode == "sync"
                else eng.run_async(reqs, max_ticks=300))
        assert len(done) == len(reqs)
        assert all(r.error is None for r in done)
        eng.pool.check()
        if eng.prefix is not None:
            eng.prefix.clear()
        assert eng.pool.free_blocks == eng.pool.capacity
        runs[mode] = _by_uid(done)
        counters[mode] = {k: eng.metrics.counters[k]
                         for k in ("admitted", "preempted", "tokens_out",
                                   "prefill_chunks")}
    assert runs["async"] == runs["sync"]
    assert counters["async"] == counters["sync"]
    if scenario == "preempt":
        assert counters["sync"]["preempted"] > 0
    if scenario == "prefix":
        assert counters["sync"]["prefill_chunks"] > 0


def test_async_engine_overlaps_device_windows():
    """The async engine's reason to exist, measured: its union-merged
    dispatch->sync device windows must cover a larger fraction of the
    serving wall time than the sync engine's on the same workload."""
    m, params = _model()
    busy = {}
    for mode in ("sync", "async"):
        eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                               max_batch=3, max_seq_len=64,
                               prefill_buckets=(16,))
        reqs = _requests(m.cfg.vocab_size, [5, 7, 9], max_new=12)
        done = (eng.run(reqs, max_ticks=300) if mode == "sync"
                else eng.run_async(reqs, max_ticks=300))
        assert all(r.error is None for r in done)
        busy[mode] = eng.metrics.device_busy_fraction()
    assert 0.0 < busy["sync"] <= 1.0
    assert busy["async"] > busy["sync"], busy


def test_seeded_sampling_deterministic_and_seed_sensitive():
    """Per-request seeds make sampled decode reproducible run-to-run
    (fresh engine, fresh jit) and actually change tokens when changed."""
    m, params = _model()

    def run_once(base_seed):
        eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                               max_batch=2, max_seq_len=64,
                               prefill_buckets=(16,))
        reqs = _requests(m.cfg.vocab_size, [6, 9], max_new=8,
                         temperature=1.2)
        for r in reqs:
            r.seed = base_seed + r.uid
        return _by_uid(eng.run_async(reqs, max_ticks=200))

    a, b, c = run_once(3), run_once(3), run_once(123)
    assert a == b
    assert a != c


def test_sync_engine_honors_request_seed_like_async():
    """The host-side sampler must derive per-token keys exactly like the
    on-device path: same seeded requests, sync vs async, same tokens."""
    m, params = _model()
    outs = {}
    for mode in ("sync", "async"):
        eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                               max_batch=2, max_seq_len=64,
                               prefill_buckets=(16,))
        reqs = _requests(m.cfg.vocab_size, [6, 9], max_new=8,
                         temperature=0.9, top_k=8)
        for r in reqs:
            r.seed = 77 + r.uid
        done = (eng.run(reqs, max_ticks=200) if mode == "sync"
                else eng.run_async(reqs, max_ticks=200))
        outs[mode] = _by_uid(done)
    assert outs["sync"] == outs["async"]


def test_async_mode_interleaves_with_sync_mode():
    """step() flushes any in-flight async step first, so callers can mix
    tick modes mid-stream without losing or duplicating tokens."""
    m, params = _model()
    eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                           max_batch=2, max_seq_len=64,
                           prefill_buckets=(16,))
    reqs = _requests(m.cfg.vocab_size, [5, 8], max_new=6)
    for r in reqs:
        eng.submit(r)
    for i in range(200):
        if all(r.done for r in reqs):
            break
        (eng.step_async if i % 2 else eng.step)()
    eng.flush()
    assert all(r.done and r.error is None for r in reqs)
    mixed = _by_uid(reqs)

    ref_eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                               max_batch=2, max_seq_len=64,
                               prefill_buckets=(16,))
    ref = _by_uid(ref_eng.run(_requests(m.cfg.vocab_size, [5, 8],
                                        max_new=6), max_ticks=200))
    assert mixed == ref
    assert {len(v) for v in mixed.values()} == {6}


# ---------------------------------------------------------------------------
# callback isolation, deadlines, cancellation
# ---------------------------------------------------------------------------


def test_callback_error_fails_only_that_request():
    """Regression: a raising on_token callback must not wedge the tick —
    the offending request retires with error="callback", everyone else
    decodes to completion, and the pool balances.  All three loops:
    paged sync, paged async, slots."""
    m, params = _model()

    def boom(tok, req):
        raise RuntimeError("client went away")

    def check(done, reqs, pool=None):
        bad = next(r for r in done if r.uid == 0)
        good = [r for r in done if r.uid != 0]
        assert bad.done and bad.error == "callback"
        assert all(r.error is None and len(r.out_tokens) == 4
                   for r in good)
        if pool is not None:
            pool.check()
            assert pool.free_blocks == pool.capacity

    for mode in ("sync", "async"):
        eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                               max_batch=2, max_seq_len=64,
                               prefill_buckets=(16,))
        reqs = _requests(m.cfg.vocab_size, [5, 7, 6], max_new=4)
        reqs[0].on_token = boom
        done = (eng.run(reqs, max_ticks=200) if mode == "sync"
                else eng.run_async(reqs, max_ticks=200))
        check(done, reqs, eng.pool)
        assert eng.metrics.counters["failed"] == 1

    slot_eng = ServeEngine(m, params, slots=2, cache_len=64,
                           prefill_buckets=(16,))
    reqs = _requests(m.cfg.vocab_size, [5, 7, 6], max_new=4)
    reqs[0].on_token = boom
    check(slot_eng.run(reqs, max_ticks=200), reqs)


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_deadline_expiry_frees_blocks_waiting_and_running(mode):
    """Deadline sweep at the top of every tick, both modes: an expired
    WAITING request fails without ever touching the pool; an expired
    RUNNING request keeps its partial output, retires with
    error="deadline", and releases its blocks."""
    m, params = _model()
    eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                           max_batch=2, max_seq_len=64,
                           prefill_buckets=(16,))
    step = eng.step_async if mode == "async" else eng.step
    reqs = _requests(m.cfg.vocab_size, [5, 7], max_new=6)
    expired, live = reqs
    expired.deadline_s = -1.0              # already past on any clock
    eng.submit(expired)
    eng.submit(live)
    step()
    assert expired.done and expired.error == "deadline"
    assert expired.out_tokens == []
    for _ in range(4):                     # let the live one make progress
        step()
    assert live.out_tokens and not live.done
    live.deadline_s = -1.0
    step()
    eng.flush()
    assert live.done and live.error == "deadline"
    assert 0 < len(live.out_tokens) < 6
    eng.pool.check()
    assert eng.pool.free_blocks == eng.pool.capacity
    assert eng.metrics.counters["deadline_expired"] == 2
    assert eng.metrics.counters["failed"] == 2


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_cancel_waiting_and_running_releases_blocks(mode):
    m, params = _model()
    eng = PagedServeEngine(m, params, num_blocks=16, block_size=8,
                           max_batch=1, max_seq_len=64,
                           prefill_buckets=(16,))
    step = eng.step_async if mode == "async" else eng.step
    running, queued = _requests(m.cfg.vocab_size, [5, 7], max_new=8)
    eng.submit(running)
    eng.submit(queued)                     # max_batch=1: stays waiting
    for _ in range(3):
        step()
    assert running.out_tokens and not running.done
    assert eng.cancel(queued)              # still waiting
    assert queued.done and queued.error == "cancelled"
    assert eng.cancel(running)             # mid-decode
    eng.flush()
    assert running.done and running.error == "cancelled"
    assert not eng.cancel(running)         # already finished
    eng.pool.check()
    assert eng.pool.free_blocks == eng.pool.capacity
    assert eng.metrics.counters["cancelled"] == 2
