"""Tests for the OPTQ baseline (the paper's FIGNA-side quantizer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bcq
from repro.core.lut_gemm import bcq_apply
from repro.quant.optq import optq_quantize, uniform_to_bcq


def _aniso(seed, n_samples, n):
    rng = np.random.default_rng(seed)
    scales = 1 + np.abs(rng.normal(size=n)) * 2
    return jnp.array((rng.normal(size=(n_samples, n)) * scales).astype(np.float32))


class TestOPTQ:
    def test_beats_rtn_on_output_error(self):
        """GPTQ's defining property: lower OUTPUT error than RTN on
        anisotropic inputs, possibly at higher weight error."""
        rng = np.random.default_rng(0)
        W = jnp.array(rng.normal(size=(128, 256)).astype(np.float32))
        X = _aniso(1, 512, 256)
        w_optq = optq_quantize(W, X, bits=3, group_size=64)
        w_rtn = bcq.from_uniform(W, bits=3, group_size=64)
        y = X @ W.T
        mse_optq = float(jnp.mean((bcq_apply(X, w_optq, "dense") - y) ** 2))
        mse_rtn = float(jnp.mean((bcq_apply(X, w_rtn, "dense") - y) ** 2))
        assert mse_optq < mse_rtn, (mse_optq, mse_rtn)

    def test_executes_on_figlut_engine(self):
        """OPTQ output is exact BCQ -> the LUT kernel runs it natively
        (Table I interoperability claim)."""
        from repro.kernels.lut_gemm import lut_gemm
        rng = np.random.default_rng(2)
        W = jnp.array(rng.normal(size=(64, 128)).astype(np.float32))
        X = _aniso(3, 64, 128)
        wq = optq_quantize(W, X, bits=4, group_size=64)
        y_dense = bcq_apply(X[:4], wq, "dense")
        y_lut = lut_gemm(X[:4], wq, interpret=True)
        np.testing.assert_allclose(np.asarray(y_lut), np.asarray(y_dense),
                                   rtol=1e-4, atol=1e-3)

    def test_uniform_to_bcq_exact(self):
        rng = np.random.default_rng(4)
        scale = jnp.array(np.abs(rng.normal(size=(8, 2))).astype(np.float32) + 0.1)
        zero = jnp.array(rng.integers(0, 15, size=(8, 2)).astype(np.float32))
        codes = rng.integers(0, 16, size=(8, 2, 64))
        w_q = (jnp.array(codes, jnp.float32) - zero[..., None]) * scale[..., None]
        w_q = w_q.reshape(8, 128)
        wq = uniform_to_bcq(w_q, scale, zero, bits=4, group_size=64,
                            in_features=128)
        np.testing.assert_allclose(np.asarray(bcq.dequantize(wq)),
                                   np.asarray(w_q), atol=1e-4)

    def test_identity_hessian_reduces_to_rtn_quality(self):
        """With isotropic inputs OPTQ ~ RTN (sanity)."""
        rng = np.random.default_rng(5)
        W = jnp.array(rng.normal(size=(64, 128)).astype(np.float32))
        X = jnp.array(rng.normal(size=(512, 128)).astype(np.float32))
        w_optq = optq_quantize(W, X, bits=4, group_size=64)
        w_rtn = bcq.from_uniform(W, bits=4, group_size=64)
        y = X @ W.T
        mse_optq = float(jnp.mean((bcq_apply(X, w_optq, "dense") - y) ** 2))
        mse_rtn = float(jnp.mean((bcq_apply(X, w_rtn, "dense") - y) ** 2))
        assert mse_optq < mse_rtn * 1.3
