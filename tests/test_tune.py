"""Tests for the repro.tune autotuning + dispatch subsystem.

Pins the three contracts the serving path relies on:

  * the JSON cache round-trips deterministically (same entries -> byte-
    identical file; reload -> identical configs),
  * dispatch falls back to the deterministic heuristic when tuning is
    disabled or the cache is cold,
  * every candidate the tuner can emit computes the same answer as the
    ``ref`` oracles in interpret mode — a config can change speed, never
    math.
"""
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro import tune as T
from repro.core import bcq
from repro.kernels.bcq_matmul import bcq_matmul, ref as bref
from repro.kernels.lut_gemm import lut_gemm, ref as lref
from repro.tune import dispatch as tdispatch


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test sees its own empty cache file and default tune mode."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune_cache.json"))
    monkeypatch.delenv("REPRO_TUNE", raising=False)
    T.reset_default_cache()
    yield
    T.reset_default_cache()


def _problem(m=32, n=128, b=4, bits=2, group_size=64, seed=0):
    rng = np.random.default_rng(seed)
    W = jnp.array(rng.normal(size=(m, n)).astype(np.float32))
    x = jnp.array(rng.normal(size=(b, n)).astype(np.float32))
    return x, bcq.from_uniform(W, bits=bits, group_size=group_size)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


class TestCache:
    def test_key_buckets_batch(self):
        kw = dict(m=64, n=128, dtype="float32", mu=4, group_size=64,
                  device="cpu")
        k5 = T.cache_key("lut_gemm", b=5, **kw)
        k7 = T.cache_key("lut_gemm", b=7, **kw)
        k9 = T.cache_key("lut_gemm", b=9, **kw)
        assert k5 == k7          # same pow2 bucket
        assert k5 != k9          # next bucket
        assert T.bucket_batch(1) == 8 and T.bucket_batch(9) == 16

    def test_key_separates_interpret_from_device(self):
        kw = dict(b=8, m=64, n=128, dtype="float32", mu=4, group_size=64)
        assert T.cache_key("lut_gemm", interpret=True, **kw) \
            != T.cache_key("lut_gemm", interpret=False, **kw)

    def test_roundtrip_deterministic(self, tmp_path):
        path = str(tmp_path / "c.json")
        cfg = T.KernelConfig(8, 64, 256, "select", False)
        c1 = T.TuneCache(path)
        c1.store("k1", cfg, time_s=1.0)
        c1.store("k0", T.KernelConfig(), time_s=2.0)
        c1.save()
        first = open(path, "rb").read()
        # reload -> identical configs; save again -> identical bytes
        c2 = T.TuneCache(path)
        assert c2.lookup("k1") == cfg
        assert c2.lookup("k0") == T.KernelConfig()
        assert c2.lookup("missing") is None
        c2.save()
        assert open(path, "rb").read() == first
        blob = json.loads(first)
        assert blob["version"] == T.cache.SCHEMA_VERSION

    def test_corrupt_cache_treated_as_cold(self, tmp_path):
        path = tmp_path / "c.json"
        path.write_text("{not json")
        c = T.TuneCache(str(path))
        assert len(c) == 0 and c.lookup("anything") is None


# ---------------------------------------------------------------------------
# dispatch: heuristic fallback + cache hits
# ---------------------------------------------------------------------------


class TestDispatch:
    KW = dict(b=4, m=32, n=128, dtype="float32", mu=4, group_size=64,
              interpret=True)

    def test_cold_cache_returns_heuristic(self):
        got = T.kernel_config("lut_gemm", **self.KW)
        want = T.heuristic_config("lut_gemm", b=4, m=32, n=128, mu=4,
                                  group_size=64)
        assert got == want

    def test_disabled_ignores_cache(self, monkeypatch):
        tuned = T.KernelConfig(8, 32, 128, "select", False)
        cache = T.default_cache()
        key = T.cache_key("lut_gemm", b=4, m=32, n=128, dtype="float32",
                          mu=4, group_size=64, interpret=True)
        cache.store(key, tuned)
        assert T.kernel_config("lut_gemm", **self.KW) == tuned
        monkeypatch.setenv("REPRO_TUNE", "off")
        assert T.kernel_config("lut_gemm", **self.KW) \
            == T.heuristic_config("lut_gemm", b=4, m=32, n=128, mu=4,
                                  group_size=64)

    def test_cached_entry_is_clamped_to_shape(self):
        # a stale entry tuned for a bigger shape must still launch legally
        cache = T.default_cache()
        key = T.cache_key("lut_gemm", b=4, m=32, n=128, dtype="float32",
                          mu=4, group_size=64, interpret=True)
        cache.store(key, T.KernelConfig(32, 256, 1024, "gather", True))
        got = T.kernel_config("lut_gemm", **self.KW)
        assert got.block_m <= 32 and got.block_n <= 128
        assert got.read_mode == "gather"

    def test_heuristic_is_deterministic_and_legal(self):
        for (b, m, n, g) in [(1, 33, 130, 32), (8, 128, 512, 128),
                             (64, 1024, 4096, 128), (5, 96, 200, 64)]:
            c1 = T.heuristic_config("lut_gemm", b=b, m=m, n=n, group_size=g)
            c2 = T.heuristic_config("lut_gemm", b=b, m=m, n=n, group_size=g)
            assert c1 == c2
            assert c1.block_n % g == 0 and c1.block_m % 8 == 0

    def test_ops_route_through_dispatch(self, monkeypatch):
        calls = []
        real = tdispatch.kernel_config

        def spy(kernel, **kw):
            calls.append(kernel)
            return real(kernel, **kw)

        monkeypatch.setattr(tdispatch, "kernel_config", spy)
        x, wq = _problem()
        want = lref.dense_ref(x, wq)
        got = lut_gemm(x, wq, interpret=True)
        got2 = bcq_matmul(x, wq, interpret=True)
        assert calls == ["lut_gemm", "bcq_matmul"]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                                   rtol=1e-4, atol=2e-4)

    def test_explicit_args_bypass_dispatch(self, monkeypatch):
        def boom(*a, **kw):
            raise AssertionError("dispatch must not be consulted")

        monkeypatch.setattr(tdispatch, "kernel_config", boom)
        x, wq = _problem()
        got = lut_gemm(x, wq, half_lut=True, read_mode="onehot", block_b=8,
                       block_m=32, block_n=128, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(lref.dense_ref(x, wq)),
                                   rtol=1e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# candidate space: every emittable config computes the right answer
# ---------------------------------------------------------------------------


class TestCandidates:
    def test_heuristic_is_candidate_zero(self):
        cands = T.candidate_configs("lut_gemm", b=4, m=32, n=128, mu=4,
                                    group_size=64)
        assert cands[0] == T.heuristic_config("lut_gemm", b=4, m=32, n=128,
                                              mu=4, group_size=64)
        assert len(cands) == len(set(cands))          # deduped

    def test_every_lut_candidate_matches_ref(self):
        x, wq = _problem(m=32, n=128, b=4, bits=2, group_size=64)
        want = np.asarray(lref.lut_ref(x, wq, mu=4, out_dtype=jnp.float32))
        scale = np.abs(want).max() + 1e-6
        cands = T.candidate_configs("lut_gemm", b=4, m=32, n=128, mu=4,
                                    group_size=64)
        assert len(cands) >= 6                        # read modes x half_lut
        for cfg in cands:
            got = np.asarray(lut_gemm(x, wq, mu=4, interpret=True,
                                      out_dtype=jnp.float32,
                                      **cfg.to_kwargs("lut_gemm")))
            np.testing.assert_allclose(got / scale, want / scale, atol=1e-4,
                                       err_msg=f"config {cfg}")

    def test_every_bcq_candidate_matches_ref(self):
        x, wq = _problem(m=40, n=192, b=4, bits=3, group_size=32)
        want = np.asarray(bref.bcq_matmul_ref(x, wq, out_dtype=jnp.float32))
        scale = np.abs(want).max() + 1e-6
        cands = T.candidate_configs("bcq_matmul", b=4, m=40, n=192,
                                    group_size=32)
        for cfg in cands:
            got = np.asarray(bcq_matmul(x, wq, interpret=True,
                                        out_dtype=jnp.float32,
                                        **cfg.to_kwargs("bcq_matmul")))
            np.testing.assert_allclose(got / scale, want / scale, atol=1e-4,
                                       err_msg=f"config {cfg}")


# ---------------------------------------------------------------------------
# tuner end-to-end
# ---------------------------------------------------------------------------


class TestTuner:
    def test_tune_persists_winner_and_dispatch_serves_it(self):
        x, wq = _problem()
        cache = T.default_cache()
        res = T.tune("lut_gemm", x, wq, mu=4, reps=1, warmup=0, cache=cache,
                     interpret=True)
        cache.save()
        # winner is a real candidate and can't lose to the default
        cands = T.candidate_configs("lut_gemm", b=4, m=32, n=128, mu=4,
                                    group_size=64)
        assert res.best in cands
        assert res.best_time <= res.default_time
        assert res.speedup >= 1.0
        assert all(t.ok for t in res.timings)
        # a fresh process-view of the cache serves the tuned config
        T.reset_default_cache()
        got = T.kernel_config("lut_gemm", b=4, m=32, n=128, dtype="float32",
                              mu=4, group_size=64, interpret=True)
        assert got == res.best

    def test_tune_shape_synthesizes_and_buckets(self):
        res = T.tune_shape("bcq_matmul", b=5, m=16, n=64, bits=2,
                           group_size=32, reps=1, warmup=0, interpret=True)
        assert "|b8|" in res.key          # 5 buckets to 8
        assert res.best_time > 0

    def test_collect_bcq_specs_dedupes(self):
        from repro.quant.formats import quantize_ternary
        _, wq = _problem(m=16, n=64, group_size=32, bits=2)
        wt = quantize_ternary(wq.dequantize(), group_size=32)
        params = {"a": {"q": wq, "k": wq}, "b": [wq, wt],
                  "dense": jnp.ones((4,))}
        # same shape, different layout kind -> two distinct GEMM problems
        assert T.collect_bcq_specs(params) == [(16, 64, 2, 32, "bcq"),
                                               (16, 64, 2, 32, "ternary")]
