"""Hypothesis property tests for the plane-bundle layout."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -e .[dev]) — the suite "
           "must collect without it")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import plane
from repro.quant import formats

_SET = dict(max_examples=25, deadline=None,
            suppress_health_check=[HealthCheck.too_slow])


@st.composite
def weight_matrices(draw):
    m = draw(st.integers(8, 48))
    n = draw(st.integers(16, 160))
    seed = draw(st.integers(0, 2**16))
    scale = draw(st.floats(0.01, 10.0))
    rng = np.random.default_rng(seed)
    return jnp.array((rng.normal(size=(m, n)) * scale).astype(np.float32))


def _bundle(fmt_name, W, group_size):
    fmt = formats.get_format(fmt_name)
    bits = fmt.fixed_plane_bits or 3
    return fmt.quantize(W, bits=bits, group_size=group_size, iters=1)


@given(weight_matrices(), st.sampled_from(["bcq", "rtn", "ternary"]),
       st.sampled_from([16, 32, 64]))
@settings(**_SET)
def test_repack_unpack_identity(W, fmt_name, group_size):
    """pack(unpack(planes)) is the identity for every format/group size
    — the bit-plane layout survives a round trip untouched."""
    wq = _bundle(fmt_name, W, group_size)
    planes = plane.unpack_planes(wq.packed)
    repacked = plane.pack_planes(planes)
    np.testing.assert_array_equal(np.asarray(repacked),
                                  np.asarray(wq.packed))
    # unpacked planes are strictly boolean-valued
    assert set(np.unique(np.asarray(planes))) <= {0, 1}


@given(weight_matrices(), st.sampled_from([16, 32, 64]))
@settings(**_SET)
def test_ternary_dequant_is_three_valued(W, group_size):
    """Ternary bundles decode to exactly {-a, 0, +a} per group row."""
    wq = _bundle("ternary", W, group_size)
    assert wq.kind == "ternary" and wq.z is None
    assert wq.alpha.shape[0] == 1
    dense = np.asarray(plane.dequantize(wq))
    a = np.repeat(np.asarray(wq.alpha[0]), group_size,
                  axis=-1)[:, :W.shape[1]]
    ratio = np.where(a > 0, dense / np.maximum(a, 1e-30), 0.0)
    assert np.all(np.isin(np.round(ratio).astype(int), [-1, 0, 1]))
    np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-6)


@given(weight_matrices(), st.sampled_from(["bcq", "rtn", "ternary"]))
@settings(**_SET)
def test_bundle_survives_flatten_unflatten(W, fmt_name):
    """PlaneBundle is a pytree: jit/scan/sharding all flatten it, and
    the static metadata (kind included) must ride the treedef."""
    import jax

    wq = _bundle(fmt_name, W, 32)
    leaves, treedef = jax.tree_util.tree_flatten(wq)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.kind == wq.kind
    assert back.group_size == wq.group_size
    assert (back.z is None) == (wq.z is None)
    np.testing.assert_array_equal(np.asarray(back.packed),
                                  np.asarray(wq.packed))
